"""Quickstart: MOCAP chunked-pipeline prefill on fake local devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py [--arch qwen3-8b]

Builds a reduced model, partitions a prompt into chunks with LBCP, runs the
MBKR-orchestrated pipeline over 4 stages x 2-way TP, and checks the result
against the plain full-sequence forward.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import compat

compat.ensure_host_devices()

import jax
import jax.numpy as jnp
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import lbcp, mbkr, pipeline as pp
from repro.core import costmodel as cm
from repro.launch.mesh import make_test_topology
from repro.models.api import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--chunks", type=int, default=8)
    ap.add_argument("--attn-backend", default="jnp",
                    choices=("jnp", "pallas"))
    args = ap.parse_args()

    cfg = replace(get_smoke_config(args.arch), dtype="float32")
    model = build_model(cfg)
    tp = 2  # old jaxlib takes the manual TP lowering (compat.resolve_tp_lowering)
    topo = make_test_topology(num_stages=8 // tp, tp=tp)
    print(f"arch={args.arch} mesh={dict(topo.mesh.shape)} "
          f"stages={topo.num_stages} tp={topo.tp_size}")

    # 1. the MBKR slot plan: how much pool the cross-half pairing saves
    plan_m = mbkr.plan(args.chunks, topo.num_stages)
    print(f"MBKR: {plan_m.describe()}  -> pool {plan_m.num_slots} slots "
          f"vs Terapipe {args.chunks} "
          f"(max-seq headroom ~{args.chunks/plan_m.peak:.2f}x)")

    # 2. LBCP: latency-balanced chunk sizes (analytic, production scale)
    from repro.configs.base import get_config
    pplan = lbcp.plan_partition(get_config("llama3-70b"), 65536, 8, 16,
                                cm.WSC_PAPER, sa_iters=40)
    print(f"LBCP @70B/64k: chunks={pplan.chunks} (later chunks shrink to "
          f"offset attention growth)")

    # 3. run the pipeline for real and verify
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, args.seq), 0,
                              cfg.vocab_size)
    run = RunConfig(num_chunks=args.chunks, num_stages=topo.num_stages,
                    attn_backend=args.attn_backend)
    plan = pp.build_plan(cfg, topo.num_stages, args.seq, run)
    staged = pp.stage_params(cfg, params, plan)
    with compat.set_mesh(topo.mesh):
        logits = jax.jit(lambda st, tk: pp.prefill_pipeline(
            cfg, st, tk, plan, topo))(staged, toks)
    ref = model.forward(params, toks)[:, -1]
    err = float(jnp.max(jnp.abs(logits - ref)))
    print(f"pipeline vs full-forward: max abs err {err:.2e}  "
          f"next tokens {jnp.argmax(logits, -1).tolist()}")
    assert err < 1e-2
    print("OK")


if __name__ == "__main__":
    main()
