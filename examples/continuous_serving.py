"""Continuous cross-request chunk pipelining under an open-loop Poisson load.

    PYTHONPATH=src python examples/continuous_serving.py

Scenario: a mixed stream of long-context scoring requests (three sequence
buckets, Poisson arrivals, per-request SLOs) hits the continuous engine.
The chunk-level scheduler injects each next request's chunk 0 into stage 0
the moment the previous tail chunk vacates it; the KV lease manager keeps
every stage inside the MBKR slot budget; EDF admission protects deadlines.
The same trace is exportable to chrome://tracing for inspection.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                  PrefillEngine, Request, SimExecutor)
from repro.sched import poisson_arrivals


def build(policy: str, slo: float, trace: bool = False):
    cfg = get_config("llama3-70b")
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                      num_chunks=16, max_batch=4, partition="uniform",
                      buckets=(16384, 65536, 131072),
                      policy=policy, slo=slo, trace=trace)
    return ec, ContinuousEngine(ec, SimExecutor(cfg, ec.hw))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=3.0, help="req/s (Poisson)")
    ap.add_argument("--slo", type=float, default=4.0, help="seconds")
    ap.add_argument("--trace-out", default=None)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    arrivals = poisson_arrivals(args.rate, args.requests, seed=0)
    seqs = rng.choice([12_000, 50_000, 120_000], size=args.requests,
                      p=[0.5, 0.35, 0.15])

    for policy in ("fcfs", "sjf", "edf"):
        ec, eng = build(policy, args.slo, trace=args.trace_out is not None)
        for i in range(args.requests):
            eng.submit(Request(rid=i, arrival=float(arrivals[i]),
                               seq_len=int(seqs[i])))
        eng.run_until_drained()
        m = eng.metrics()
        print(f"[{policy:4s}] {m['completed']:3d} done | "
              f"{m['throughput']:.2f} req/s | avg TTFT {m['avg_ttft']:.2f}s | "
              f"p99 queue {m['p99_queue_wait']:.2f}s | "
              f"SLO {m['slo_met']}/{m['slo_total']} | "
              f"lease peak {m['lease_hwm_frac']*100:.0f}% of budget")
        if args.trace_out and policy == "edf":
            print(f"  trace -> {eng.trace.export(args.trace_out)}")

    # batch-synchronous reference on the same trace
    ec, _ = build("fcfs", args.slo)
    ref = PrefillEngine(ec, SimExecutor(ec.model, ec.hw))
    for i in range(args.requests):
        ref.submit(Request(rid=i, arrival=float(arrivals[i]),
                           seq_len=int(seqs[i])))
    ref.run_until_drained()
    print(f"[batch-synchronous reference] {ref.metrics()['throughput']:.2f} "
          f"req/s")


if __name__ == "__main__":
    main()
