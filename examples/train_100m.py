"""Train a ~100M-parameter model for a few hundred steps on the synthetic
LM stream, with checkpoint/restart.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

This drives the same `launch/train.py` entrypoint the cluster launcher
uses; on the production mesh the identical step function shards FSDP over
"data" and TP over "model" (see launch/cells.py: train_4k).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as ckpt:
        # phase 1: train, checkpointing every 100 steps
        train_main(["--arch", args.arch, "--preset", "100m",
                    "--steps", str(args.steps), "--batch", "8",
                    "--seq", "256", "--ckpt-dir", ckpt,
                    "--ckpt-every", "100"])
        # phase 2: simulate a restart — resumes bit-exact from the last step
        print("\n--- simulated restart (resume from checkpoint) ---")
        train_main(["--arch", args.arch, "--preset", "100m",
                    "--steps", str(args.steps + 50), "--batch", "8",
                    "--seq", "256", "--ckpt-dir", ckpt, "--resume"])


if __name__ == "__main__":
    main()
