"""End-to-end prefill-only serving with fault injection.

    PYTHONPATH=src python examples/prefill_serving.py          # simulator
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/prefill_serving.py --executor jax

Scenario: a stream of long-context scoring requests hits the engine; mid-run
one pipeline stage dies. The engine re-forms the pipeline without it,
re-plans LBCP for the new stage count, replays the in-flight batch, and
drains the queue — nothing is lost.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.runtime.engine import (EngineConfig, PrefillEngine, Request,
                                  SimExecutor)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--executor", default="sim", choices=("sim", "jax"))
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    if args.executor == "jax":
        from repro.launch.serve import main as serve_main
        return serve_main(["--arch", "qwen3-8b", "--requests",
                           str(args.requests), "--seq", "256",
                           "--num-chunks", "8", "--max-batch", "2"])

    cfg = get_config("llama3-70b")
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                      num_chunks=16, max_batch=2, partition="lbcp",
                      sa_iters=20, buckets=(32768, 131072))
    # stage 5 dies while batch #3 is in flight; stage 9 is 40% slow
    executor = SimExecutor(cfg, ec.hw, fail_at={3: 5}, slow={9: 1.4})
    eng = PrefillEngine(ec, executor)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=i, arrival=0.0,
                           seq_len=int(rng.integers(20_000, 120_000))))
    eng.run_until_drained()
    m = eng.metrics()
    print(f"completed={m['completed']}  avg E2E={m['avg_e2e']:.2f}s  "
          f"p99={m['p99_e2e']:.2f}s  thr={m['throughput']:.2f} req/s")
    print(f"faults: remeshes={m['remeshes']} (16 -> {m['num_stages']} "
          f"stages), LBCP replans={m['replans']}, "
          f"replayed={sum(r.replays for r in eng.done)} requests")
    assert m["completed"] == args.requests
    print("OK — no request lost across the stage failure")


if __name__ == "__main__":
    main()
