"""Memory-Balanced KV Reallocation (MBKR), §4.1.

Fixed cross-half pairing (stage i <-> stage i + N/2), threshold-driven
debtor/creditor roles:

    occupancy < p1          : local-only
    p1 <= occupancy < p2    : creditor (hosts the pair's spilled chunks)
    occupancy >= p2         : debtor (chunks with index >= p2 spill at creation)

with p1 = p2 - N/2 (the cross-half invariant: paired occupancies differ by
exactly N/2 chunks at every tick of the back-to-back steady state).

The *slot plan* turns the policy into a static cyclic schedule: a shared pool
of ``num_slots`` chunk-KV slots per stage, with precomputed slot tables
(own_slot / host_slot per phase) proven collision-free over the steady-state
period. This is what makes the reallocation expressible as static JAX arrays
(DESIGN.md §3.3-3.4) and is where the memory saving comes from:

    peak_slots(M, N, p2*)  <  M  (the Terapipe baseline)

e.g. M = N = 16: peak 12 vs 16 — the 1/(1 - N/(4M)) = 1.33x max-seq-len gain.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


def pair_of(stage: int, num_stages: int) -> int:
    return (stage + num_stages // 2) % num_stages


def interleaved_placement(num_stages: int) -> List[int]:
    """Stage -> physical mesh row, placing stage i adjacent to its pair
    (paper: 'MBKR places stage i adjacent to stage i+N/2'). Gray-code-style:
    stage i (i < N/2) at row 2i; stage i + N/2 at row 2i + 1."""
    n2 = num_stages // 2
    rows = [0] * num_stages
    for i in range(n2):
        rows[i] = 2 * i
        rows[i + n2] = 2 * i + 1
    return rows


# ------------------------------------------------------------ occupancy math

def peak_slots(num_chunks: int, num_stages: int, p2: int) -> int:
    """Peak (own-local + hosted) chunk slots over the steady-state cycle,
    max over BOTH pairing directions (first-half stages host while their
    behind-pair spills at my phase phi - N/2; second-half while their
    ahead-pair spills at my phase phi + N/2)."""
    m, n2 = num_chunks, max(num_stages // 2, 1)
    peak = 0
    for phi in range(m):
        own = min(phi + 1, p2)
        for delta in (-n2, n2):
            psi = (phi + delta) % m  # pair's phase seen from my phase
            hosted = max(0, (psi + 1) - p2)
            peak = max(peak, own + hosted)
    return peak


def best_p2(num_chunks: int, num_stages: int) -> Tuple[int, int]:
    """(p2, peak) minimizing peak slots; ties -> larger p2 (less traffic)."""
    best = (num_chunks, peak_slots(num_chunks, num_stages, num_chunks))
    for p2 in range(1, num_chunks + 1):
        pk = peak_slots(num_chunks, num_stages, p2)
        if pk < best[1] or (pk == best[1] and p2 > best[0]):
            best = (p2, pk)
    return best


def max_chunks_for_capacity(num_stages: int, capacity_slots: int,
                            mbkr: bool = True) -> int:
    """Max chunk count M whose steady-state peak fits ``capacity_slots``."""
    if not mbkr:
        return capacity_slots
    m = capacity_slots
    while True:
        nxt = plan(m + 1, num_stages)  # respects the m >= N/2 gate
        if max(nxt.peak, nxt.num_slots) > capacity_slots:
            return m
        m += 1
        if m > capacity_slots * 4:  # safety
            return m


# ------------------------------------------------------------------ slot plan

@dataclass
class MBKRPlan:
    num_stages: int
    num_chunks: int
    p2: int
    p1: int
    num_slots: int                 # shared pool size (excl. the scratch slot)
    own_slot: np.ndarray           # [M] slot for own chunk phi (scratch if spilled)
    host_slot_a: np.ndarray        # [M] host slot, FIRST-half stages (pair behind)
    host_slot_b: np.ndarray        # [M] host slot, SECOND-half stages (pair ahead)
    peak: int = 0

    @property
    def scratch(self) -> int:
        return self.num_slots  # pool allocated with num_slots + 1 entries

    @property
    def spilled_chunks(self) -> List[int]:
        return list(range(self.p2, self.num_chunks))

    def host_slot_for_stage(self, stage: int) -> np.ndarray:
        return self.host_slot_a if stage < self.num_stages // 2 else self.host_slot_b

    def describe(self) -> str:
        return (f"MBKR N={self.num_stages} M={self.num_chunks} p2={self.p2} "
                f"p1={self.p1} slots={self.num_slots} (baseline {self.num_chunks})")


def _color(intervals, m: int) -> Tuple[Dict, int]:
    """Greedy cyclic-interval coloring. intervals: [(key, start, length)]."""
    slot_busy: List[np.ndarray] = []
    assign: Dict = {}
    for key, s, ln in sorted(intervals, key=lambda iv: (-iv[2], iv[1])):
        phases = [(s + k) % m for k in range(ln)]
        for si, busy in enumerate(slot_busy):
            if not busy[phases].any():
                busy[phases] = True
                assign[key] = si
                break
        else:
            busy = np.zeros(m, bool)
            busy[phases] = True
            slot_busy.append(busy)
            assign[key] = len(slot_busy) - 1
    return assign, len(slot_busy)


def plan(num_chunks: int, num_stages: int, p2: Optional[int] = None,
         mbkr: bool = True) -> MBKRPlan:
    """Build the static cyclic slot plan.

    Own chunk phi (phi < p2): live at my phases [phi .. M-1] (non-wrapping).
    Hosted pair chunk phi' (phi' >= p2), in MY phase coordinates:
      first-half host (pair is N/2 ticks BEHIND): arrives (phi' + N/2) mod M
      second-half host (pair is N/2 ticks AHEAD): arrives (phi' - N/2) mod M
    both live m - phi' phases (until the pair finishes its request).

    Own intervals are colored first (shared across halves); each half's host
    intervals are colored against them separately. Pool = max of the halves.
    """
    m, n = num_chunks, num_stages
    n2 = max(n // 2, 1)
    # MBKR needs >= N/2 chunks in flight to realize the cross-half stagger:
    # with m < N/2 the pair offset spans more than a full request period and
    # hosted lifetimes collide — fall back to the Terapipe buffer (the paper
    # never runs this regime; its sweeps use M >= N).
    if m < n2:
        mbkr = False
    if not mbkr or n < 2 or m < 2:
        own = np.arange(m, dtype=np.int32)
        return MBKRPlan(n, m, m, m, m, own, np.full(m, m, np.int32),
                        np.full(m, m, np.int32), peak=m)
    if p2 is None:
        p2, _ = best_p2(m, n)
    p2 = min(p2, m)
    if p2 >= m:
        own = np.arange(m, dtype=np.int32)
        return MBKRPlan(n, m, m, max(m - n2, 0), m, own,
                        np.full(m, m, np.int32), np.full(m, m, np.int32), peak=m)

    own_iv = [(("own", phi), phi, m - phi) for phi in range(p2)]
    host_a = [(("host", phip), (phip + n2) % m, m - phip) for phip in range(p2, m)]
    host_b = [(("host", phip), (phip - n2) % m, m - phip) for phip in range(p2, m)]

    assign_a, slots_a = _color(own_iv + host_a, m)
    assign_b, slots_b = _color(own_iv + host_b, m)
    # force identical own assignment across halves (SPMD-shared table): re-color
    # half B with half A's own assignment pinned.
    own_busy = {}
    for (key, s, ln) in own_iv:
        si = assign_a[key]
        own_busy.setdefault(si, np.zeros(m, bool))
        for k in range(ln):
            own_busy[si][(s + k) % m] = True
    slot_busy = [own_busy.get(i, np.zeros(m, bool)) for i in range(slots_a)]
    assign_b2: Dict = {}
    for key, s, ln in sorted(host_b, key=lambda iv: (-iv[2], iv[1])):
        phases = [(s + k) % m for k in range(ln)]
        for si, busy in enumerate(slot_busy):
            if not busy[phases].any():
                busy[phases] = True
                assign_b2[key] = si
                break
        else:
            busy = np.zeros(m, bool)
            busy[phases] = True
            slot_busy.append(busy)
            assign_b2[key] = len(slot_busy) - 1
    num_slots = len(slot_busy)

    occ = np.zeros(m, np.int64)
    for _, s, ln in own_iv + host_a:
        for k in range(ln):
            occ[(s + k) % m] += 1
    peak = int(occ.max())
    occ_b = np.zeros(m, np.int64)
    for _, s, ln in own_iv + host_b:
        for k in range(ln):
            occ_b[(s + k) % m] += 1
    peak = max(peak, int(occ_b.max()))

    own_slot = np.full(m, num_slots, np.int32)
    hs_a = np.full(m, num_slots, np.int32)
    hs_b = np.full(m, num_slots, np.int32)
    for phi in range(p2):
        own_slot[phi] = assign_a[("own", phi)]
    for phip in range(p2, m):
        hs_a[phip] = assign_a[("host", phip)]
        hs_b[phip] = assign_b2[("host", phip)]
    return MBKRPlan(n, m, p2, max(p2 - n2, 0), num_slots, own_slot, hs_a, hs_b,
                    peak=peak)


def verify_plan(pl: MBKRPlan, periods: int = 4) -> None:
    """Step the steady-state back-to-back schedule on a (stage, pair) couple;
    assert (a) pool writes never clobber LIVE entries, (b) attention always
    finds every needed chunk: j < p2 in my own pool, j >= p2 in the pair's
    host pool. Raises AssertionError on any violation."""
    m, n2 = pl.num_chunks, pl.num_stages // 2
    if pl.p2 >= m:
        return  # no spilling: trivially a Terapipe buffer

    # entry: (kind, owner_stage, req, chunk, death_tick)
    pools: Dict[int, Dict[int, tuple]] = {0: {}, 1: {}}  # 0 = me (s=0), 1 = pair (s=n2)
    stage_of = {0: 0, 1: n2}

    def phase(me: int, t: int) -> Tuple[int, int]:
        tt = t - stage_of[me]
        return tt % m, tt // m

    # host table used by the HOSTING stage: stage 0 is first half (table A),
    # stage n2 is second half (table B).
    host_table = {0: pl.host_slot_a, 1: pl.host_slot_b}

    for t in range(n2, periods * m + n2):
        for me in (0, 1):
            phi, req = phase(me, t)
            if req < 0:
                continue
            other = 1 - me
            # 1. write own chunk (or spill to pair, stored per the HOST's table)
            if phi < pl.p2:
                slot = int(pl.own_slot[phi])
                prev = pools[me].get(slot)
                assert prev is None or prev[4] < t, ("own write clobbers", t, me, phi, prev)
                pools[me][slot] = ("own", me, req, phi, t + (m - 1 - phi))
            else:
                slot = int(host_table[other][phi])
                prev = pools[other].get(slot)
                assert prev is None or prev[4] < t, ("host write clobbers", t, me, phi, prev)
                pools[other][slot] = ("host", me, req, phi, t + (m - 1 - phi))
        for me in (0, 1):
            phi, req = phase(me, t)
            if req < 1:  # check from the first steady request on
                continue
            other = 1 - me
            # 2. attention residency for chunks 0..phi of request `req`
            for j in range(phi + 1):
                if j < pl.p2:
                    e = pools[me].get(int(pl.own_slot[j]))
                    assert e and e[:4] == ("own", me, req, j), ("miss own", t, me, j, e)
                else:
                    e = pools[other].get(int(host_table[other][j]))
                    assert e and e[:4] == ("host", me, req, j), ("miss host", t, me, j, e)
