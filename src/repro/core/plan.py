"""Static pipeline planning: everything decided BEFORE tracing.

A ``PipelinePlan`` is the single immutable object threaded through the
execution stack (staging -> stage programs -> driver). It pins the pipeline
geometry (N stages x M chunks x C tokens), the MBKR slot plan and its static
lookup tables (numpy arrays that become HLO constants), the KV page store
layout (``repro.kvstore``: page size, slot->page table, storage codec), and
the runtime policy knobs every lower layer reads: ``remote_attn`` (fetch |
qship, see core.remote), ``attn_backend`` (jnp | pallas, core.attention)
and ``ssm_backend`` (jnp | pallas, kernels.ops.ssd).

Modes: ``mocap`` (pool + MBKR), ``terapipe`` (pool of M slots, no
reallocation), ``gpipe`` (microbatch pipeline: batch-split, full-sequence
chunks, no pool). See DESIGN.md §2 for the layering.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import mbkr
from repro.kvstore import pages as kvpages
from repro.kvstore import quant as kvquant


@dataclass(frozen=True)
class PipelinePlan:
    """Everything static about one pipeline lowering."""
    mode: str                 # mocap | terapipe | gpipe
    num_stages: int           # N
    num_chunks: int           # M
    chunk_len: int            # C (uniform); gpipe: microbatch size
    layers_per_stage: int     # lps (ceil(L / N)); hybrid: groups per stage
    num_slots: int            # KV pool size (excl. scratch)
    p2: int                   # spill threshold (chunks >= p2 spill); M if no MBKR
    remote_attn: str = "qship"   # fetch | qship
    attn_backend: str = "jnp"    # jnp | pallas (core.attention registry)
    transport: str = "jax"       # core.transport registry entry
    tp_lowering: str = "auto"    # RESOLVED: auto (GSPMD) | manual (explicit
                                 # transport psums, all mesh axes manual) —
                                 # compat.resolve_tp_lowering decides "auto"
    fetch_batch: str = "auto"    # auto | on | off: land fetched chunk-layers
                                 # in a staging buffer and run ONE
                                 # pool_attention launch ("auto" follows the
                                 # pool backend's batched_pool flag; resolved
                                 # at use time in core.remote)
    pool_backend: str = "jnp"    # backend for POOL-sourced partials (own
                                 # pool scan + fetch/qship); resolved from
                                 # RunConfig.pool_backend ("auto" follows
                                 # attn_backend; "paged" = gather-free
                                 # ragged pool kernel) — never "auto" here
    ssm_backend: str = "jnp"     # jnp | pallas (kernels.ops.ssd)
    spill_dtype: str = "bfloat16"  # int8 -> wire-only spill compression
    ship_dtype: str = "bfloat16"   # qship q/acc wire format (= model dtype)
    # KV page store (repro.kvstore): the pool holds fixed-size pages in the
    # codec's storage dtype; slot tables index pages through ``slot_pages``
    kv_dtype: str = "bfloat16"     # resolved storage knob (never "auto")
    page_tokens: int = 0           # tokens per page (0 only in gpipe mode)
    pages_per_chunk: int = 1
    # static tables (numpy; become HLO constants)
    own_slot: Any = None          # [M] chunk -> own slot (scratch if spilled)
    host_slot_a: Any = None       # [M] chunk -> host slot (first-half hosts)
    host_slot_b: Any = None
    slot_own_chunk: Any = None    # [slots+1] slot -> own chunk (-1 none)
    slot_host_chunk_a: Any = None  # [slots+1] slot -> hosted pair chunk (-1)
    slot_host_chunk_b: Any = None
    host_slots_used: Any = None   # [H] the (few) slots host tables touch —
                                  # the creditor-side scan visits ONLY these
    slot_pages: Any = None        # [slots+1, ppc] slot -> physical page ids

    @property
    def scratch(self) -> int:
        return self.num_slots

    @property
    def codec(self) -> kvquant.KVCodec:
        return kvquant.get_codec(self.kv_dtype)

    @property
    def page_geometry(self) -> kvpages.PageGeometry:
        return kvpages.PageGeometry(
            self.chunk_len, self.page_tokens, self.pages_per_chunk,
            self.num_slots, (self.num_slots + 1) * self.pages_per_chunk)

    @property
    def num_ticks(self) -> int:
        return self.num_chunks + self.num_stages - 1

    @property
    def pair_shift(self) -> int:
        return self.num_stages // 2


def _invert(table: np.ndarray, num_slots: int, lo: int, hi: int) -> np.ndarray:
    inv = np.full(num_slots + 1, -1, np.int32)
    for chunk in range(lo, hi):
        s = int(table[chunk])
        if s <= num_slots:
            inv[s] = chunk
    return inv


def build_plan(cfg: ModelConfig, num_stages: int, seq_len: int,
               run: RunConfig, *, mode: Optional[str] = None) -> PipelinePlan:
    """Derive the static pipeline plan for one (arch, shape, run) cell."""
    from repro import compat

    mode = mode or ("mocap" if run.mbkr else "terapipe")
    m = run.num_chunks
    pool_backend = (run.attn_backend if run.pool_backend in ("auto", "", None)
                    else run.pool_backend)
    tp_lowering = compat.resolve_tp_lowering(run.tp_lowering)
    if run.fetch_batch not in ("auto", "on", "off"):
        raise ValueError(f"unknown fetch_batch {run.fetch_batch!r}")
    if mode == "gpipe":
        return PipelinePlan(mode, num_stages, m, 0,
                            _layers_per_stage(cfg, num_stages), 0, m,
                            attn_backend=run.attn_backend,
                            pool_backend=pool_backend,
                            ssm_backend=run.ssm_backend,
                            transport=run.transport,
                            tp_lowering=tp_lowering,
                            fetch_batch=run.fetch_batch)
    assert seq_len % m == 0, f"seq_len {seq_len} must divide into {m} chunks"
    c = seq_len // m
    use_mbkr = mode == "mocap" and not cfg.attn_free and num_stages >= 2 and m >= 2
    mp = mbkr.plan(m, num_stages, mbkr=use_mbkr)
    codec = kvquant.get_codec(run.kv_dtype, cfg.dtype)
    geom = kvpages.page_geometry(c, mp.num_slots, run.kv_page_tokens)
    slot_pages = kvpages.build_slot_pages(geom)
    kvpages.verify_page_plan(slot_pages, geom)
    return PipelinePlan(
        mode=mode, num_stages=num_stages, num_chunks=m, chunk_len=c,
        layers_per_stage=_layers_per_stage(cfg, num_stages),
        num_slots=mp.num_slots, p2=mp.p2,
        remote_attn=run.remote_attn,
        attn_backend=run.attn_backend,
        pool_backend=pool_backend,
        ssm_backend=run.ssm_backend,
        transport=run.transport,
        tp_lowering=tp_lowering,
        fetch_batch=run.fetch_batch,
        spill_dtype=run.kv_spill_dtype,
        ship_dtype=cfg.dtype,   # wire in model precision (bf16 in prod)
        kv_dtype=codec.name, page_tokens=geom.page_tokens,
        pages_per_chunk=geom.pages_per_chunk, slot_pages=slot_pages,
        own_slot=mp.own_slot, host_slot_a=mp.host_slot_a, host_slot_b=mp.host_slot_b,
        slot_own_chunk=_invert(mp.own_slot, mp.num_slots, 0, mp.p2),
        slot_host_chunk_a=_invert(mp.host_slot_a, mp.num_slots, mp.p2, m),
        slot_host_chunk_b=_invert(mp.host_slot_b, mp.num_slots, mp.p2, m),
        host_slots_used=np.unique(np.concatenate(
            [mp.host_slot_a[mp.p2:], mp.host_slot_b[mp.p2:]])).astype(np.int32)
        if mp.p2 < m else np.zeros((0,), np.int32),
    )


def _layers_per_stage(cfg: ModelConfig, n: int) -> int:
    if cfg.family == "hybrid":
        nl = cfg.hybrid.num_groups + 1  # +1 pseudo-group for the SSM tail
    else:
        nl = cfg.num_layers
    return -(-nl // n)
