"""Analytic chunk/pipeline cost model shared by LBCP (Alg. 1), the event
simulator, and the roofline report.

Hardware profiles: the paper's WSC (GR24-class dies, §5.1), an equivalent
HGX-class GPU system (NVLink-limited; Fig. 1(c)), and the TPU v5e target.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, replace as dc_replace
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    flops: float            # peak FLOP/s per die/chip (bf16)
    hbm_bw: float           # bytes/s per die/chip
    hbm_cap: float          # bytes per die/chip
    link_bw: float          # bytes/s per inter-die link (D2D / NVLink / ICI)
    mesh: Tuple[int, int]   # (rows, cols) of dies/chips
    gemm_eff: float = 0.65  # achievable fraction of peak on large GEMMs
    attn_eff: float = 0.45  # achievable fraction on attention
    link_eff: float = 0.85

    @property
    def num_dies(self) -> int:
        return self.mesh[0] * self.mesh[1]


# §5.1: die == Blackwell-class: 4.5 PFLOPS, 180 GB @ 7.7 TB/s; D2D 5 TB/s (SoW-X)
WSC_PAPER = HardwareProfile("wsc-gr24", 4.5e15, 7.7e12, 180e9, 5e12, (4, 4))
# Same dies, NVLink-class 900 GB/s interconnect (Fig. 1(c) comparison)
GPU_HGX = HardwareProfile("hgx-b200", 4.5e15, 7.7e12, 180e9, 0.9e12, (4, 4))
# TPU v5e pod: 197 TFLOP/s bf16, 16 GB @ 819 GB/s, ICI ~50 GB/s/link
TPU_V5E = HardwareProfile("tpu-v5e", 197e12, 819e9, 16e9, 50e9, (16, 16))

PROFILES = {p.name: p for p in (WSC_PAPER, GPU_HGX, TPU_V5E)}

ProfileSpec = Union[HardwareProfile, str]


def profile_to_dict(hw: HardwareProfile) -> Dict:
    """JSON-serializable profile dict. Floats survive a json round-trip
    BIT-IDENTICALLY (json uses repr = shortest round-trip), so a calibrated
    profile written to disk reproduces the exact dp_partition output of the
    in-memory one."""
    d = asdict(hw)
    d["mesh"] = list(hw.mesh)
    return d


def profile_from_dict(d: Dict) -> HardwareProfile:
    d = dict(d)
    d["mesh"] = tuple(int(v) for v in d["mesh"])
    return HardwareProfile(**d)


def resolve_profile(spec: ProfileSpec) -> HardwareProfile:
    """Accept a profile everywhere one is taken: a ``HardwareProfile``
    instance, a registered name (``PROFILES``), or a path to a (calibrated)
    profile JSON written by ``repro.obs.calibrate.save_profile`` — so LBCP,
    ``chunk_cost_arrays`` and the scheduler's admission costs all run off a
    measured fit with no call-site changes."""
    if isinstance(spec, HardwareProfile):
        return spec
    if spec in PROFILES:
        return PROFILES[spec]
    with open(spec) as f:
        blob = json.load(f)
    return profile_from_dict(blob.get("profile", blob))


# ----------------------------------------------------------- model analytics

def layer_linear_flops_per_token(cfg: ModelConfig) -> float:
    """FLOPs/token of the non-attention (GEMM) path of ONE layer (fwd)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qkvo = 2 * d * (cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd + cfg.num_heads * hd)
    if cfg.family == "ssm":
        from repro.models.ssm import dims as ssm_dims
        d_in, nheads, conv_ch = ssm_dims(cfg)
        s = cfg.ssm
        return 2 * d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads) + 2 * d_in * d
    if cfg.moe is not None:
        m = cfg.moe
        fe = m.d_expert or cfg.d_ff
        ffn = 2 * 3 * d * fe * (m.top_k + m.num_shared_experts)
        return qkvo + ffn + 2 * d * m.num_experts
    return qkvo + 2 * 3 * d * cfg.d_ff


def attn_flops(cfg: ModelConfig, c: int, p: int) -> float:
    """Attention score+value FLOPs for a chunk of c tokens with prefix p, ONE
    layer (causal within the chunk)."""
    if cfg.attn_free:
        # SSD intra+inter-chunk cost is linear in c
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        return 2 * c * d_in * s.d_state * 3
    hd = cfg.resolved_head_dim
    eff_len = p + (c + 1) / 2.0
    return 4 * c * eff_len * cfg.num_heads * hd


def kv_bytes_per_token_layer(cfg: ModelConfig, bytes_per_el: int = 2) -> float:
    """KV bytes/token for ONE attention layer (0 for SSM)."""
    if cfg.attn_free:
        return 0.0
    return 2 * cfg.num_kv_heads * cfg.resolved_head_dim * bytes_per_el


def attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.hybrid.num_groups
    return cfg.num_layers


# ------------------------------------------------------------- chunk timing

@dataclass(frozen=True)
class StageModel:
    """One pipeline stage: a slice of the model on ``tp`` dies/chips."""
    cfg: ModelConfig
    layers: int            # layers hosted by this stage
    attn_layers: int       # of which attention layers (hybrid: shared-block apps)
    tp: int = 1            # dies/chips ganged within the stage

    @staticmethod
    def build(cfg: ModelConfig, num_stages: int, tp: int = 1) -> "StageModel":
        nl = cfg.hybrid.num_groups if cfg.family == "hybrid" else cfg.num_layers
        ls = -(-nl // num_stages)
        al = ls if not cfg.attn_free else 0
        if cfg.family == "hybrid":
            al = ls  # one shared-attn application per group
        return StageModel(cfg, ls, al, tp)


def chunk_compute_time(sm: StageModel, c: int, p: int, hw: HardwareProfile) -> float:
    """Seconds for one chunk (c tokens, prefix p) through one stage."""
    cfg = sm.cfg
    peak = sm.tp * hw.flops
    bw = sm.tp * hw.hbm_bw
    gemm = sm.layers * c * layer_linear_flops_per_token(cfg) / (peak * hw.gemm_eff)
    afl = sm.attn_layers * attn_flops(cfg, c, p)
    abytes = sm.attn_layers * (p + c) * kv_bytes_per_token_layer(cfg)
    attn = max(afl / (peak * hw.attn_eff), abytes / bw)
    return gemm + attn


def boundary_comm_time(cfg: ModelConfig, c: int, hw: HardwareProfile) -> float:
    """Stage-boundary activation transfer (1 hop)."""
    return c * cfg.d_model * 2 / (hw.link_bw * hw.link_eff)


def kv_chunk_bytes(sm: StageModel, c: int) -> float:
    return sm.attn_layers * c * kv_bytes_per_token_layer(sm.cfg)


def spill_time(sm: StageModel, c: int, hw: HardwareProfile, hops: int = 1,
               compress: float = 1.0) -> float:
    """Transfer one chunk's stage-KV to the paired stage. ``compress`` < 1
    models int8 KV-spill compression (beyond-paper)."""
    return kv_chunk_bytes(sm, c) * compress * hops / (hw.link_bw * hw.link_eff)


def chunk_cost_arrays(
    sm: StageModel,
    chunks: Sequence[int],
    hw: ProfileSpec,
    *,
    mbkr_plan: Optional["object"] = None,  # core.mbkr.MBKRPlan
    compress: float = 1.0,
    prefix_hit_chunks: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Per-chunk cost vectors shared by the analytic evaluator, the event
    simulator, and the chunk-level scheduler.

    Returns ``(dur, comm, kvb, spill_t, fetch_t)``, each ``[M]``:
      dur     compute seconds of chunk i on one stage (prefix-aware)
      comm    stage-boundary activation transfer seconds
      kvb     stage-KV bytes written by chunk i
      spill_t MBKR debtor spill seconds (chunks with index >= p2)
      fetch_t MBKR remote-KV re-read seconds (prefix chunks hosted at the pair)

    ``prefix_hit_chunks=k`` prices a request whose first ``k`` chunks are
    served by the prefix index (``kvstore.prefix``): their self-block
    compute, boundary hop, spill and fetch wire all vanish — the EFFECTIVE
    sequence is the novel suffix — while later chunks still attend over the
    full (cached) prefix and ``kvb`` still reports the stored bytes the
    pages occupy (lease accounting subtracts sharing separately via
    ``kvlease.chunk_page_bytes(shared_pages=...)``).
    """
    hw = resolve_profile(hw)
    m = len(chunks)
    dur = np.zeros(m)
    comm = np.zeros(m)
    kvb = np.zeros(m)
    spill_t = np.zeros(m)
    fetch_t = np.zeros(m)
    p2 = m if mbkr_plan is None else mbkr_plan.p2
    k = min(max(int(prefix_hit_chunks), 0), m - 1 if m else 0)
    link = hw.link_bw * hw.link_eff
    prefix = 0
    for i, c in enumerate(chunks):
        if i >= k:
            dur[i] = chunk_compute_time(sm, c, prefix, hw)
            comm[i] = boundary_comm_time(sm.cfg, c, hw)
        kvb[i] = kv_chunk_bytes(sm, c)
        prefix += c
    for i, c in enumerate(chunks):
        if i < k:
            continue
        if i >= p2:
            spill_t[i] = spill_time(sm, c, hw, compress=compress)
        if i > p2:
            fetch_t[i] = kvb[p2:i].sum() * compress / link
    return dur, comm, kvb, spill_t, fetch_t


# --------------------------------------------- calibration feature extraction
#
# Every term above is LINEAR in four effective hardware rates (the attention
# max() picks a regime, but WITHIN a regime the time is linear):
#
#   t_chunk = G / (peak*gemm_eff) + A / (peak*attn_eff)   [compute-bound]
#                                 | B / bw                [bandwidth-bound]
#           + W / (link_bw*link_eff)
#
# so a per-chunk feature matrix X [M, 4] of pure WORK quantities (flops,
# bytes) and a rate vector theta = profile_theta(hw) satisfy
# X @ theta == dur + comm + spill_t + fetch_t exactly — the identity
# ``repro.obs.calibrate`` inverts by least squares to fit an effective
# profile from measured spans (DESIGN.md §9).

FEATURE_TERMS = ("gemm_flops", "attn_flops", "attn_bytes", "link_bytes")


def profile_theta(hw: HardwareProfile, tp: int = 1) -> np.ndarray:
    """The 4 effective inverse rates the cost model is linear in:
    seconds-per-unit of each FEATURE_TERMS column at stage width ``tp``."""
    peak = tp * hw.flops
    bw = tp * hw.hbm_bw
    return np.array([1.0 / (peak * hw.gemm_eff), 1.0 / (peak * hw.attn_eff),
                     1.0 / bw, 1.0 / (hw.link_bw * hw.link_eff)])


def profile_from_theta(hw: HardwareProfile, theta: np.ndarray,
                       tp: int = 1, name: Optional[str] = None
                       ) -> HardwareProfile:
    """Fold fitted inverse rates back into a ``HardwareProfile``: peak
    flops/mesh stay nominal, the EFFECTIVE terms (gemm_eff / attn_eff /
    hbm_bw / link_bw) absorb the fit — so the profile drops into every
    existing cost-model call site unchanged."""
    peak = tp * hw.flops
    return dc_replace(
        hw,
        name=name if name is not None else hw.name + "+cal",
        gemm_eff=1.0 / (float(theta[0]) * peak),
        attn_eff=1.0 / (float(theta[1]) * peak),
        hbm_bw=1.0 / (float(theta[2]) * tp),
        link_bw=1.0 / (float(theta[3]) * hw.link_eff),
    )


def chunk_cost_features(
    sm: StageModel,
    chunks: Sequence[int],
    hw: ProfileSpec,
    *,
    mbkr_plan: Optional["object"] = None,
    compress: float = 1.0,
    prefix_hit_chunks: int = 0,
) -> np.ndarray:
    """Per-chunk work-quantity matrix ``X [M, 4]`` (FEATURE_TERMS columns)
    such that ``X @ profile_theta(hw, sm.tp)`` equals the analytic per-chunk
    total ``dur + comm + spill_t + fetch_t`` from ``chunk_cost_arrays``.

    The attention regime (compute- vs bandwidth-bound) is chosen under the
    GIVEN profile: the inactive branch's column is zero for that chunk, so
    the fit stays linear. A calibration that flips a chunk's regime shows up
    as residual, not as a fit failure.

    ``prefix_hit_chunks=k`` zeroes the feature rows of index-served chunks —
    the same effective-sequence discipline as ``chunk_cost_arrays``, so the
    LBCP partition and the calibration identity both price the shorter
    suffix (prefix accumulation for later chunks is unchanged: they still
    attend over the cached prefix)."""
    hw = resolve_profile(hw)
    cfg = sm.cfg
    m = len(chunks)
    x = np.zeros((m, 4))
    theta = profile_theta(hw, sm.tp)
    p2 = m if mbkr_plan is None else mbkr_plan.p2
    k = min(max(int(prefix_hit_chunks), 0), m - 1 if m else 0)
    kvb = np.array([kv_chunk_bytes(sm, c) for c in chunks])
    prefix = 0
    for i, c in enumerate(chunks):
        if i < k:
            prefix += c
            continue
        x[i, 0] = sm.layers * c * layer_linear_flops_per_token(cfg)
        afl = sm.attn_layers * attn_flops(cfg, c, prefix)
        abytes = sm.attn_layers * (prefix + c) * kv_bytes_per_token_layer(cfg)
        if afl * theta[1] >= abytes * theta[2]:
            x[i, 1] = afl
        else:
            x[i, 2] = abytes
        x[i, 3] = c * cfg.d_model * 2    # boundary activation hop
        if i >= p2:
            x[i, 3] += kvb[i] * compress            # MBKR spill
        if i > p2:
            x[i, 3] += kvb[p2:i].sum() * compress   # MBKR remote re-read
        prefix += c
    return x


# ------------------------------------------------- analytic pipeline schedule

@dataclass
class ScheduleResult:
    latency: float                 # single-request prefill makespan (s)
    stage_finish: List[float]
    chunk_times: List[List[float]]  # [stage][chunk]
    realloc_overhead: float        # total MBKR serve+fetch seconds on critical path


def evaluate_prefill(
    chunks: Sequence[int],
    sm: StageModel,
    num_stages: int,
    hw: HardwareProfile,
    *,
    mbkr_plan: Optional["object"] = None,  # core.mbkr.MBKRPlan
    compress: float = 1.0,
) -> ScheduleResult:
    """Analytic pipeline schedule for ONE request partitioned into ``chunks``.

    Chunk i: compute at stage s can start when (a) stage s finished chunk i-1
    plus any MBKR serve time, (b) stage s-1 finished chunk i plus the boundary
    transfer. MBKR adds: spill time for chunks with index >= p2 (overlapped up
    to the link, modeled as serialized on the boundary link of the debtor),
    fetch time for remote chunks re-read each subsequent chunk, and serve time
    on the creditor (paper Fig. 4(b) blue blocks).
    """
    m = len(chunks)
    cfg = sm.cfg
    p2 = m if mbkr_plan is None else mbkr_plan.p2
    n2 = num_stages // 2

    # per (stage, chunk) compute times + mbkr extras (same across stages for a
    # uniform stage slice; serve time appears at the paired stage's schedule)
    dur, _, _, spill_t, fetch_t = chunk_cost_arrays(
        chunks=chunks, sm=sm, hw=hw, mbkr_plan=mbkr_plan, compress=compress)
    t = [[float(dur[i]) for i in range(m)] for _ in range(num_stages)]
    realloc = 0.0

    finish = [[0.0] * m for _ in range(num_stages)]
    for s in range(num_stages):
        for i in range(m):
            ready_prev_chunk = finish[s][i - 1] if i else 0.0
            ready_prev_stage = (finish[s - 1][i] + boundary_comm_time(cfg, chunks[i], hw)
                                if s else 0.0)
            # creditor serve time: when my pair spills/fetches, my HBM+link is
            # busy serving; approximate as added occupancy on this stage for
            # the same chunk index shifted by N/2
            serve = 0.0
            if p2 < m:
                pair_chunk = i - n2
                if 0 <= pair_chunk < m:
                    serve = spill_t[pair_chunk] * 0.5 + fetch_t[pair_chunk] * 0.5
            start = max(ready_prev_chunk, ready_prev_stage)
            dur = t[s][i] + spill_t[i] + fetch_t[i] + serve
            realloc += (spill_t[i] + fetch_t[i] + serve) / num_stages
            finish[s][i] = start + dur
    return ScheduleResult(
        latency=finish[num_stages - 1][m - 1],
        stage_finish=[finish[s][m - 1] for s in range(num_stages)],
        chunk_times=t,
        realloc_overhead=realloc,
    )


def evaluate_e2e(batch: int, t_prefill: float, chunks: Sequence[int],
                 sm: StageModel, num_stages: int, hw: HardwareProfile,
                 *, mbkr_plan=None, compress: float = 1.0) -> Tuple[float, float]:
    """(avg E2E latency, throughput req/s) for ``batch`` back-to-back requests.

    Steady-state: each additional request adds sum_i(t_i + extras) (the
    bottleneck stage is fully busy); E2E of request r = fill + (r+1) * T_req.
    """
    m = len(chunks)
    prefix = [0] * m
    for i in range(1, m):
        prefix[i] = prefix[i - 1] + chunks[i - 1]
    p2 = m if mbkr_plan is None else mbkr_plan.p2
    t_req = 0.0
    for i, c in enumerate(chunks):
        extra = 0.0
        if i >= p2:
            extra += spill_time(sm, c, hw, compress=compress)
        if p2 < i:
            remote_bytes = sum(kv_chunk_bytes(sm, chunks[j]) for j in range(p2, i))
            extra += remote_bytes * compress / (hw.link_bw * hw.link_eff)
        t_req += chunk_compute_time(sm, c, prefix[i], hw) + extra
    fill = t_prefill - t_req if t_prefill > t_req else 0.0
    lat = fill + (batch + 1) / 2.0 * t_req
    thr = batch / (fill + batch * t_req)
    return lat, thr
