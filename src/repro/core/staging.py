"""Parameter staging: flat model params -> per-stage stacked params + specs.

Sits between the model definitions (``repro.models``) and the pipeline
driver: restacks flat ``[L, ...]`` layer params into ``[N, lps, ...]``
(zero-padded — zero-param transformer/SSM blocks are exact identities via the
residual), derives the matching PartitionSpecs for the mesh topology,
allocates the per-stage paged KV pool (``repro.kvstore``) the stage programs
write into, and implements the two exact zero-padding transforms the
kv_split perf variant needs (query-head padding per kv group, routed-expert
padding for EP). See DESIGN.md §2 (layering), §3 (mesh mapping) and §6
(memory tiers).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.plan import PipelinePlan
from repro.kvstore import pages as kvpages
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.topology import Topology

Params = Dict[str, Any]


def alloc_kv_pool(cfg: ModelConfig, plan: PipelinePlan, b: int,
                  topo: Topology = None) -> kvpages.PagedPool:
    """One stage's paged KV pool, zero-initialized in the plan's storage
    codec; kv_split meshes get the pool sharded by kv head (payloads AND
    scales carry kvh on axis 4)."""
    kvh = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    pool = kvpages.alloc_pool(plan.page_geometry, plan.codec,
                              plan.layers_per_stage, b, kvh, hd)
    if topo is not None and isinstance(topo.tp_axis, tuple):
        spec = P(None, None, None, None, topo.tp_axis[0], None)
        shard = lambda a: (jax.lax.with_sharding_constraint(a, spec)
                           if a is not None else None)
        pool = kvpages.PagedPool(shard(pool.k), shard(pool.v),
                                 shard(pool.k_scale), shard(pool.v_scale))
    return pool


def stage_params(cfg: ModelConfig, params: Params, plan: PipelinePlan) -> Params:
    """Restack flat [L, ...] layer params into [N, lps, ...] (zero-padded:
    zero-param transformer/SSM blocks are exact identities via the residual).
    Embedding / head / norms are replicated across stages (SPMD: every stage
    computes the masked embed; only stage 0's result is used)."""
    n, lps = plan.num_stages, plan.layers_per_stage

    def restack(tree, nl):
        def one(a):
            pad = n * lps - nl
            if pad:
                a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
            return a.reshape((n, lps) + a.shape[1:])
        return jax.tree.map(one, tree)

    if cfg.family == "hybrid":
        h = cfg.hybrid
        pg = h.ssm_per_group
        groups = params["mamba_groups"]        # [G, pg, ...]
        tail = params["mamba_tail"]            # [tail, ...]
        # tail becomes pseudo-group G (pad its layer dim to pg)
        def fold(g, t):
            t = jnp.concatenate(
                [t, jnp.zeros((pg - t.shape[0],) + t.shape[1:], t.dtype)])[None]
            g = jnp.concatenate([g, t])        # [G+1, pg, ...]
            pad = n * plan.layers_per_stage - g.shape[0]
            if pad:
                g = jnp.concatenate([g, jnp.zeros((pad,) + g.shape[1:], g.dtype)])
            return g.reshape((n, plan.layers_per_stage) + g.shape[1:])
        staged_groups = jax.tree.map(fold, groups, tail)
        return {
            "embed": params["embed"], "final_norm": params["final_norm"],
            "stage_layers": staged_groups, "shared": params["shared"],
        }
    if cfg.family == "encdec":
        out = {
            "embed": params["embed"], "final_norm": params["final_norm"],
            "stage_layers": restack(params["dec_layers"], cfg.num_layers),
            "enc_layers": params["enc_layers"], "enc_norm": params["enc_norm"],
        }
        return out
    out = {
        "embed": params["embed"], "final_norm": params["final_norm"],
        "stage_layers": restack(params["layers"], cfg.num_layers),
    }
    if "lm_head" in params:
        out["lm_head"] = params["lm_head"]
    return out


def stage_param_specs(cfg: ModelConfig, plan: PipelinePlan, topo: Topology) -> Params:
    """PartitionSpecs for ``stage_params`` output: stage dim over the stage
    axis, TP dims over the model axis, embed d-sharded (gather stays local)."""
    st, md = topo.stage_axis, topo.tp_axis

    def lift(spec: P) -> P:
        return P(st, None, *spec[1:])  # [L,...] -> [N, lps, ...]

    if cfg.family == "hybrid":
        bs = S.block_specs(cfg, fsdp=False)
        g_specs = jax.tree.map(lambda p: P(st, None, None, *p[1:]), bs,
                               is_leaf=lambda x: isinstance(x, P))
        shared = jax.tree.map(
            lambda p: P(*p[1:]), T.specs(_hyb_scfg(cfg), fsdp=False)["layers"],
            is_leaf=lambda x: isinstance(x, P))
        out = {"embed": P(None, md), "final_norm": P(None),
               "stage_layers": g_specs, "shared": shared}
        return _rename_model(out, md)
    if cfg.family == "encdec":
        from repro.models import whisper as W
        ws = W.specs(cfg, fsdp=False)
        dec = jax.tree.map(lift, ws["dec_layers"], is_leaf=lambda x: isinstance(x, P))
        out = {"embed": P(None, md), "final_norm": P(None),
               "stage_layers": dec, "enc_layers": ws["enc_layers"],
               "enc_norm": P(None)}
        return _rename_model(out, md)
    base = T.specs(cfg, fsdp=False)["layers"] if cfg.family != "ssm" \
        else S.block_specs(cfg, fsdp=False)
    layers = jax.tree.map(lift, base, is_leaf=lambda x: isinstance(x, P))
    out = {"embed": P(None, md), "final_norm": P(None), "stage_layers": layers}
    if not cfg.tie_embeddings and cfg.family in ("dense", "moe", "vlm"):
        out["lm_head"] = P(None, md)
    out = _rename_model(out, md)
    if isinstance(md, tuple) and cfg.family in ("dense", "moe", "vlm"):
        # K/V projections shard by KV HEAD only (replicated over "qg") so the
        # [B,C,kvh,hd] reshape keeps full head_dim per chip (no hd split)
        for k in ("wk", "wv"):
            out["stage_layers"][k] = P(topo.stage_axis, None, None, md[0])
        if cfg.moe is not None:
            # EXPERT parallelism: experts over the full TP axis, FFN local
            for k in ("e_wg", "e_wu", "e_wd"):
                out["stage_layers"][k] = P(topo.stage_axis, None, md, None, None)
    return out


def batch_specs(topo: Topology):
    """(manual shard_map axis_names, batch axes outside the stage axis)."""
    pod_axes = tuple(a for a in topo.batch_axes if a != topo.stage_axis)
    manual = set(pod_axes) | {topo.stage_axis}
    return manual, pod_axes


def manual_only(spec: P, manual) -> P:
    """shard_map in_specs may only name MANUAL axes; auto-axis (TP) sharding
    flows through from the argument's actual sharding instead."""
    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in manual)
            return kept if kept else None
        return entry if entry in manual else None
    return P(*(keep(e) for e in spec))


def manual_tree(tree, manual):
    return jax.tree.map(lambda p: manual_only(p, manual), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _hyb_scfg(cfg: ModelConfig) -> ModelConfig:
    from repro.models.hybrid import T_single_cfg
    return T_single_cfg(cfg)


def _rename_model(tree, tp_axis):
    """Model specs hardcode the "model" axis; rename to the topology's TP
    axis (possibly the split ("kv","qg") view)."""
    if tp_axis == "model":
        return tree

    def one(spec: P) -> P:
        return P(*(tp_axis if e == "model" else e for e in spec))
    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, P))


def kv_split_axes(cfg: ModelConfig, tp: int):
    """Factor the TP degree into (kv, qg) so attention shards by kv head and
    query group with NO collectives. Returns (kv_ax, qg_ax, padded_g) —
    padded_g > g means q heads are zero-padded per kv group (wq/wo pads are
    exact identities). None if kv heads don't divide."""
    if cfg.attn_free or cfg.num_kv_heads == 0:
        return None
    kvh, h = cfg.num_kv_heads, cfg.num_heads
    g = h // kvh
    kv_ax = min(kvh, tp)
    if tp % kv_ax or kvh % kv_ax:
        return None
    qg_ax = tp // kv_ax
    g_pad = -(-g // qg_ax) * qg_ax
    return kv_ax, qg_ax, g_pad


def pad_q_heads(cfg: ModelConfig, params: Params, g_pad: int) -> Tuple[ModelConfig, Params]:
    """Zero-pad query heads per kv group: H = kvh*g -> kvh*g_pad. Padded
    heads have zero wq (uniform attention) and zero wo rows (no contribution)
    — bit-exact with the unpadded model."""
    from repro.configs.base import replace as cfg_replace
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // kvh
    if g_pad == g:
        return cfg, params
    lp = dict(params["layers"])
    L_, d = lp["wq"].shape[0], lp["wq"].shape[1]
    wq = lp["wq"].reshape(L_, d, kvh, g, hd)
    wq = jnp.pad(wq, ((0, 0), (0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    lp["wq"] = wq.reshape(L_, d, kvh * g_pad * hd)
    wo = lp["wo"].reshape(L_, kvh, g, hd, d)
    wo = jnp.pad(wo, ((0, 0), (0, 0), (0, g_pad - g), (0, 0), (0, 0)))
    lp["wo"] = wo.reshape(L_, kvh * g_pad * hd, d)
    out = dict(params)
    out["layers"] = lp
    return cfg_replace(cfg, num_heads=kvh * g_pad), out


def pad_experts(cfg: ModelConfig, params: Params, e_pad: int) -> Tuple[ModelConfig, Params]:
    """Zero-pad routed experts to ``e_pad`` for expert parallelism. Padded
    experts' router logits are masked (MoEConfig.num_real_experts), so they
    are never routable — bit-exact."""
    import dataclasses
    from repro.configs.base import replace as cfg_replace
    m = cfg.moe
    if m is None or e_pad == m.num_experts:
        return cfg, params
    e0 = m.num_experts
    lp = dict(params["layers"])
    lp["router"] = jnp.pad(lp["router"], ((0, 0), (0, 0), (0, e_pad - e0)))
    for k in ("e_wg", "e_wu", "e_wd"):
        lp[k] = jnp.pad(lp[k], ((0, 0), (0, e_pad - e0)) + ((0, 0),) * (lp[k].ndim - 2))
    out = dict(params)
    out["layers"] = lp
    moe2 = dataclasses.replace(m, num_experts=e_pad,
                               num_real_experts=m.real_experts)
    return cfg_replace(cfg, moe=moe2), out
