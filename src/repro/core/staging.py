"""Parameter staging: flat model params -> per-stage stacked params + specs.

Sits between the model definitions (``repro.models``) and the pipeline
driver: restacks flat ``[L, ...]`` layer params into ``[N, lps, ...]``
(zero-padded — zero-param transformer/SSM blocks are exact identities via the
residual), derives the matching PartitionSpecs for the mesh topology,
allocates the per-stage paged KV pool (``repro.kvstore``) the stage programs
write into, and implements the two exact zero-padding transforms the
kv_split perf variant needs (query-head padding per kv group, routed-expert
padding for EP). See DESIGN.md §2 (layering), §3 (mesh mapping) and §6
(memory tiers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.plan import PipelinePlan
from repro.kvstore import pages as kvpages
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.topology import Topology

Params = Dict[str, Any]


# ------------------------------------------------- manual TP lowering plan

@dataclass(frozen=True)
class ManualTP:
    """Static description of the MANUAL tensor-parallel lowering
    (``PipelinePlan.tp_lowering == "manual"``, DESIGN.md §3.6): which param
    groups are sharded over the (now fully-manual) TP mesh axes, and hence
    where the stage programs must insert explicit transport psums.

    A group is sharded only when the split is HEAD/ROW-exact (a GSPMD-auto
    axis can shard elementwise; a manual lowering cannot cut a head or an
    expert in half) — otherwise that group's params replicate and its
    compute needs no collective. This keeps the manual path correct for
    every family at any tp, degrading sharding rather than failing."""
    axes: Tuple[str, ...]   # flattened TP mesh axis names (all manual)
    tp: int                 # product of their sizes
    attn: bool              # q/k/v/o head-sharded -> psum after the o-proj
    kv_div: int             # kv-head shard factor (1 when attn is False)
    ffn: bool               # dense SwiGLU f-sharded -> psum after down-proj
    moe_ffn: bool           # expert FFN f-sharded (plain TP axis)
    moe_ep: bool            # experts sharded over the axes (kv_split view)
    shared_moe: bool        # shared-experts SwiGLU f-sharded


def manual_tp_plan(cfg: ModelConfig, plan: PipelinePlan,
                   topo: Optional[Topology]) -> Optional[ManualTP]:
    """None unless the plan asks for manual lowering AND tp > 1."""
    if topo is None or plan.tp_lowering != "manual" or topo.tp_size <= 1:
        return None
    md = topo.tp_axis
    axes = md if isinstance(md, tuple) else (md,)
    tp = topo.tp_size
    kvh, h = cfg.num_kv_heads, cfg.num_heads
    if isinstance(md, tuple):
        kv_ax = topo.mesh.shape[md[0]]
        qg_ax = tp // kv_ax
        attn = (kvh > 0 and kvh % kv_ax == 0
                and (h // max(kvh, 1)) % qg_ax == 0)
        kv_div = kv_ax if attn else 1
    else:
        attn = kvh > 0 and kvh % tp == 0
        kv_div = tp if attn else 1
    ffn = cfg.d_ff > 0 and cfg.d_ff % tp == 0
    moe_ffn = moe_ep = shared_moe = False
    if cfg.moe is not None:
        fe = cfg.moe.d_expert or cfg.d_ff
        if isinstance(md, tuple):
            moe_ep = cfg.moe.num_experts % tp == 0
        else:
            moe_ffn = fe % tp == 0
        if cfg.moe.num_shared_experts:
            shared_moe = (fe * cfg.moe.num_shared_experts) % tp == 0
    if cfg.family == "ssm":
        attn, ffn = False, False
    return ManualTP(axes=tuple(axes), tp=tp, attn=attn, kv_div=kv_div,
                    ffn=ffn, moe_ffn=moe_ffn, moe_ep=moe_ep,
                    shared_moe=shared_moe)


def _strip_axes(spec: P, axes) -> P:
    """Drop the given mesh axes from a PartitionSpec (replicate there)."""
    def keep(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a not in axes)
            return kept if kept else None
        return None if e in axes else e
    return P(*(keep(e) for e in spec))


def _apply_manual_tp(cfg: ModelConfig, out: Params, mtp: ManualTP) -> Params:
    """Replicate every param group the manual lowering does not shard (see
    ``ManualTP``); embed / lm_head always replicate under manual (the gather
    and unembed run replicated inside the body — vocab sharding is a
    GSPMD-auto-only optimization)."""
    drop = set(mtp.axes)
    strip = {"embed", "lm_head"}
    if not mtp.attn:
        strip |= {"wq", "wk", "wv", "wo", "xwq", "xwk", "xwv", "xwo"}
    if not mtp.ffn:
        strip |= {"wg", "wu", "wd"}
    if not (mtp.moe_ffn or mtp.moe_ep):
        strip |= {"e_wg", "e_wu", "e_wd"}
    if not mtp.shared_moe:
        strip |= {"s_wg", "s_wu", "s_wd"}
    # SSM blocks never TP-shard under manual (out_proj's row split would
    # need an activation slice + psum inside the scan; replication is exact)
    ssm_keys = {"in_proj", "out_proj", "conv_w", "conv_b", "a_log",
                "dt_bias", "d_skip", "gate_norm", "ln"}
    strip |= ssm_keys

    def walk(tree):
        if isinstance(tree, dict):
            return {k: (jax.tree.map(
                        lambda p: _strip_axes(p, drop), v,
                        is_leaf=lambda x: isinstance(x, P))
                        if k in strip else walk(v))
                    for k, v in tree.items()}
        return tree
    return walk(out)


def alloc_kv_pool(cfg: ModelConfig, plan: PipelinePlan, b: int,
                  topo: Topology = None, *,
                  mtp: Optional[ManualTP] = None) -> kvpages.PagedPool:
    """One stage's paged KV pool, zero-initialized in the plan's storage
    codec; kv_split meshes get the pool sharded by kv head (payloads AND
    scales carry kvh on axis 4). Under the MANUAL lowering the body is
    mapped over the TP axes too, so the pool is allocated with the LOCAL
    kv-head count and no sharding hint."""
    kvh = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    if mtp is not None:
        kvh //= mtp.kv_div
    pool = kvpages.alloc_pool(plan.page_geometry, plan.codec,
                              plan.layers_per_stage, b, kvh, hd)
    if mtp is None and topo is not None and isinstance(topo.tp_axis, tuple):
        spec = P(None, None, None, None, topo.tp_axis[0], None)
        shard = lambda a: (jax.lax.with_sharding_constraint(a, spec)
                           if a is not None else None)
        pool = kvpages.PagedPool(shard(pool.k), shard(pool.v),
                                 shard(pool.k_scale), shard(pool.v_scale))
    return pool


def stage_params(cfg: ModelConfig, params: Params, plan: PipelinePlan) -> Params:
    """Restack flat [L, ...] layer params into [N, lps, ...] (zero-padded:
    zero-param transformer/SSM blocks are exact identities via the residual).
    Embedding / head / norms are replicated across stages (SPMD: every stage
    computes the masked embed; only stage 0's result is used)."""
    n, lps = plan.num_stages, plan.layers_per_stage

    def restack(tree, nl):
        def one(a):
            pad = n * lps - nl
            if pad:
                a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
            return a.reshape((n, lps) + a.shape[1:])
        return jax.tree.map(one, tree)

    if cfg.family == "hybrid":
        h = cfg.hybrid
        pg = h.ssm_per_group
        groups = params["mamba_groups"]        # [G, pg, ...]
        tail = params["mamba_tail"]            # [tail, ...]
        # tail becomes pseudo-group G (pad its layer dim to pg)
        def fold(g, t):
            t = jnp.concatenate(
                [t, jnp.zeros((pg - t.shape[0],) + t.shape[1:], t.dtype)])[None]
            g = jnp.concatenate([g, t])        # [G+1, pg, ...]
            pad = n * plan.layers_per_stage - g.shape[0]
            if pad:
                g = jnp.concatenate([g, jnp.zeros((pad,) + g.shape[1:], g.dtype)])
            return g.reshape((n, plan.layers_per_stage) + g.shape[1:])
        staged_groups = jax.tree.map(fold, groups, tail)
        return {
            "embed": params["embed"], "final_norm": params["final_norm"],
            "stage_layers": staged_groups, "shared": params["shared"],
        }
    if cfg.family == "encdec":
        out = {
            "embed": params["embed"], "final_norm": params["final_norm"],
            "stage_layers": restack(params["dec_layers"], cfg.num_layers),
            "enc_layers": params["enc_layers"], "enc_norm": params["enc_norm"],
        }
        return out
    out = {
        "embed": params["embed"], "final_norm": params["final_norm"],
        "stage_layers": restack(params["layers"], cfg.num_layers),
    }
    if "lm_head" in params:
        out["lm_head"] = params["lm_head"]
    return out


def stage_param_specs(cfg: ModelConfig, plan: PipelinePlan, topo: Topology) -> Params:
    """PartitionSpecs for ``stage_params`` output: stage dim over the stage
    axis, TP dims over the model axis, embed d-sharded (gather stays local).
    Under the manual TP lowering the sharding degrades per ``ManualTP``
    (head/row-exact splits only; the rest replicates)."""
    out = _stage_param_specs(cfg, plan, topo)
    mtp = manual_tp_plan(cfg, plan, topo)
    if mtp is not None:
        out = _apply_manual_tp(cfg, out, mtp)
    return out


def _stage_param_specs(cfg: ModelConfig, plan: PipelinePlan, topo: Topology) -> Params:
    st, md = topo.stage_axis, topo.tp_axis

    def lift(spec: P) -> P:
        return P(st, None, *spec[1:])  # [L,...] -> [N, lps, ...]

    if cfg.family == "hybrid":
        bs = S.block_specs(cfg, fsdp=False)
        g_specs = jax.tree.map(lambda p: P(st, None, None, *p[1:]), bs,
                               is_leaf=lambda x: isinstance(x, P))
        shared = jax.tree.map(
            lambda p: P(*p[1:]), T.specs(_hyb_scfg(cfg), fsdp=False)["layers"],
            is_leaf=lambda x: isinstance(x, P))
        out = {"embed": P(None, md), "final_norm": P(None),
               "stage_layers": g_specs, "shared": shared}
        return _rename_model(out, md)
    if cfg.family == "encdec":
        from repro.models import whisper as W
        ws = W.specs(cfg, fsdp=False)
        dec = jax.tree.map(lift, ws["dec_layers"], is_leaf=lambda x: isinstance(x, P))
        out = {"embed": P(None, md), "final_norm": P(None),
               "stage_layers": dec, "enc_layers": ws["enc_layers"],
               "enc_norm": P(None)}
        return _rename_model(out, md)
    base = T.specs(cfg, fsdp=False)["layers"] if cfg.family != "ssm" \
        else S.block_specs(cfg, fsdp=False)
    layers = jax.tree.map(lift, base, is_leaf=lambda x: isinstance(x, P))
    out = {"embed": P(None, md), "final_norm": P(None), "stage_layers": layers}
    if not cfg.tie_embeddings and cfg.family in ("dense", "moe", "vlm"):
        out["lm_head"] = P(None, md)
    out = _rename_model(out, md)
    if isinstance(md, tuple) and cfg.family in ("dense", "moe", "vlm"):
        # K/V projections shard by KV HEAD only (replicated over "qg") so the
        # [B,C,kvh,hd] reshape keeps full head_dim per chip (no hd split)
        for k in ("wk", "wv"):
            out["stage_layers"][k] = P(topo.stage_axis, None, None, md[0])
        if cfg.moe is not None:
            # EXPERT parallelism: experts over the full TP axis, FFN local
            for k in ("e_wg", "e_wu", "e_wd"):
                out["stage_layers"][k] = P(topo.stage_axis, None, md, None, None)
    return out


def batch_specs(topo: Topology, mtp: Optional[ManualTP] = None):
    """(manual shard_map axis_names, batch axes outside the stage axis).
    The manual TP lowering adds the TP axes to the manual set — the whole
    mesh is then manual, which is what old jaxlib can partition."""
    pod_axes = tuple(a for a in topo.batch_axes if a != topo.stage_axis)
    manual = set(pod_axes) | {topo.stage_axis}
    if mtp is not None:
        manual |= set(mtp.axes)
    return manual, pod_axes


def manual_only(spec: P, manual) -> P:
    """shard_map in_specs may only name MANUAL axes; auto-axis (TP) sharding
    flows through from the argument's actual sharding instead."""
    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in manual)
            return kept if kept else None
        return entry if entry in manual else None
    return P(*(keep(e) for e in spec))


def manual_tree(tree, manual):
    return jax.tree.map(lambda p: manual_only(p, manual), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _hyb_scfg(cfg: ModelConfig) -> ModelConfig:
    from repro.models.hybrid import T_single_cfg
    return T_single_cfg(cfg)


def _rename_model(tree, tp_axis):
    """Model specs hardcode the "model" axis; rename to the topology's TP
    axis (possibly the split ("kv","qg") view)."""
    if tp_axis == "model":
        return tree

    def one(spec: P) -> P:
        return P(*(tp_axis if e == "model" else e for e in spec))
    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, P))


def kv_split_axes(cfg: ModelConfig, tp: int):
    """Factor the TP degree into (kv, qg) so attention shards by kv head and
    query group with NO collectives. Returns (kv_ax, qg_ax, padded_g) —
    padded_g > g means q heads are zero-padded per kv group (wq/wo pads are
    exact identities). None if kv heads don't divide."""
    if cfg.attn_free or cfg.num_kv_heads == 0:
        return None
    kvh, h = cfg.num_kv_heads, cfg.num_heads
    g = h // kvh
    kv_ax = min(kvh, tp)
    if tp % kv_ax or kvh % kv_ax:
        return None
    qg_ax = tp // kv_ax
    g_pad = -(-g // qg_ax) * qg_ax
    return kv_ax, qg_ax, g_pad


def pad_q_heads(cfg: ModelConfig, params: Params, g_pad: int) -> Tuple[ModelConfig, Params]:
    """Zero-pad query heads per kv group: H = kvh*g -> kvh*g_pad. Padded
    heads have zero wq (uniform attention) and zero wo rows (no contribution)
    — bit-exact with the unpadded model."""
    from repro.configs.base import replace as cfg_replace
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // kvh
    if g_pad == g:
        return cfg, params
    lp = dict(params["layers"])
    L_, d = lp["wq"].shape[0], lp["wq"].shape[1]
    wq = lp["wq"].reshape(L_, d, kvh, g, hd)
    wq = jnp.pad(wq, ((0, 0), (0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    lp["wq"] = wq.reshape(L_, d, kvh * g_pad * hd)
    wo = lp["wo"].reshape(L_, kvh, g, hd, d)
    wo = jnp.pad(wo, ((0, 0), (0, 0), (0, g_pad - g), (0, 0), (0, 0)))
    lp["wo"] = wo.reshape(L_, kvh * g_pad * hd, d)
    out = dict(params)
    out["layers"] = lp
    return cfg_replace(cfg, num_heads=kvh * g_pad), out


def pad_experts(cfg: ModelConfig, params: Params, e_pad: int) -> Tuple[ModelConfig, Params]:
    """Zero-pad routed experts to ``e_pad`` for expert parallelism. Padded
    experts' router logits are masked (MoEConfig.num_real_experts), so they
    are never routable — bit-exact."""
    import dataclasses
    from repro.configs.base import replace as cfg_replace
    m = cfg.moe
    if m is None or e_pad == m.num_experts:
        return cfg, params
    e0 = m.num_experts
    lp = dict(params["layers"])
    lp["router"] = jnp.pad(lp["router"], ((0, 0), (0, 0), (0, e_pad - e0)))
    for k in ("e_wg", "e_wu", "e_wd"):
        lp[k] = jnp.pad(lp[k], ((0, 0), (0, e_pad - e0)) + ((0, 0),) * (lp[k].ndim - 2))
    out = dict(params)
    out["layers"] = lp
    moe2 = dataclasses.replace(m, num_experts=e_pad,
                               num_real_experts=m.real_experts)
    return cfg_replace(cfg, moe=moe2), out
