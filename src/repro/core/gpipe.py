"""GPipe baseline driver: microbatch pipeline over the BATCH dim.

Every microbatch carries the full sequence (full quadratic attention per
tick, no KV pool) — the paper's Fig. 2(a) comparison point against MOCAP's
chunked pipeline. Kept out of ``core.pipeline`` so the hot-path driver stays
a thin scan loop; selected via ``PipelinePlan.mode == "gpipe"``.

Collectives route through the transport registry (``core.transport``; no
ledger — the fetch/qship traffic model is a chunked-pipeline concern), and
the manual TP lowering works here too: ``layer_apply`` takes the same
``ManualTPApply`` psum hooks the stage programs use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core import transport as tx
from repro.core.plan import PipelinePlan
from repro.core.staging import (Params, batch_specs, manual_only, manual_tree,
                                manual_tp_plan, stage_param_specs)
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.topology import Topology
from repro.obs import telemetry as obs_t


def gpipe_prefill(cfg: ModelConfig, staged: Params, tokens: jax.Array,
                  plan: PipelinePlan, topo: Topology, *,
                  return_telemetry: bool = False) -> jax.Array:
    """``return_telemetry``: also return the [N, T] StageTelemetry profile.
    GPipe has no KV pool or MBKR wire, so only ``attn_work`` (full-sequence
    causal attention per microbatch tick) and ``launches`` are non-zero —
    the baseline column of the occupancy comparison."""
    n, m = plan.num_stages, plan.num_chunks
    st_ax = topo.stage_axis
    mtp = manual_tp_plan(cfg, plan, topo)
    manual, pod_axes = batch_specs(topo, mtp)
    transport = tx.get_transport(plan.transport)
    dt = jnp.dtype(cfg.dtype)
    ring_perm = [(i, (i + 1) % n) for i in range(n)]
    tp_apply = None
    if mtp is not None:
        tp_apply = T.manual_tp_apply(
            mtp, lambda y: transport.tp_psum(y, mtp.axes, None)[0])

    def body(stage_layers, embed, final_norm, tokens):
        stage = jax.lax.axis_index(st_ax)
        stage_layers = jax.tree.map(lambda a: jnp.squeeze(a, 0), stage_layers)
        b, s_full = tokens.shape
        assert b % m == 0, f"gpipe: batch {b} must divide into {m} microbatches"
        bm = b // m
        x0 = jnp.zeros((bm, s_full, cfg.d_model), dt)
        out0 = jnp.zeros((b, cfg.d_model), jnp.float32)

        lps = plan.layers_per_stage
        rep = mtp.tp if mtp is not None else 1

        def tick(carry, t):
            x_prev, out, tel = carry
            phase = t - stage
            mb = jnp.clip(t, 0, m - 1)
            tok_mb = jax.lax.dynamic_slice(tokens, (mb * bm, 0), (bm, s_full))
            x_emb = jnp.take(embed, tok_mb, axis=0).astype(dt)
            if cfg.embedding_multiplier != 1.0:
                x_emb = x_emb * cfg.embedding_multiplier
            x = jnp.where(stage == 0, x_emb, x_prev)

            def layer_body(xc, lp):
                xo, _, _ = T.layer_apply(cfg, lp, xc, impl="xla_flash",
                                         topo=None, tp=tp_apply)
                return xo, None
            x_out, _ = jax.lax.scan(layer_body, x, stage_layers)
            take = (stage == n - 1) & (phase >= 0) & (phase < m)
            mbp = jnp.clip(phase, 0, m - 1)
            upd = jnp.where(take, x_out[:, -1].astype(jnp.float32),
                            jax.lax.dynamic_slice(out, (mbp * bm, 0),
                                                  (bm, cfg.d_model)))
            out = jax.lax.dynamic_update_slice(out, upd, (mbp * bm, 0))
            active = (phase >= 0) & (phase < m)
            tel = obs_t.charge(tel, "attn_work",
                               lps * cm.attn_flops(cfg, s_full, 0),
                               active, rep)
            tel = obs_t.charge(tel, "launches", float(lps), None, rep)
            tel_ys = None if tel is None else dict(tel)
            x_next, _ = transport.ring_shift(x_out, st_ax, ring_perm)
            return (x_next, out, tel), tel_ys

        tel0 = obs_t.telemetry_init() if return_telemetry else None
        (xf, out, _), tel_ys = jax.lax.scan(tick, (x0, out0, tel0),
                                            jnp.arange(m + n - 1))
        out, _ = transport.stage_psum(jnp.where(stage == n - 1, out, 0.0),
                                      st_ax)
        if not return_telemetry:
            return out
        tel_ys = obs_t.telemetry_collect(
            tel_ys, mtp.axes if mtp is not None else None)
        return out, {k: v[None, :] for k, v in tel_ys.items()}

    specs = stage_param_specs(cfg, plan, topo)
    sl_specs = manual_tree(specs["stage_layers"], manual)
    tok_spec = P(pod_axes if pod_axes else None, None)
    tel_specs = {k: P(st_ax, None) for k in obs_t.TELEM_KEYS}
    out_specs = (tok_spec, tel_specs) if return_telemetry else tok_spec
    outs = compat.shard_map(
        body, mesh=topo.mesh,
        in_specs=(sl_specs, manual_only(specs["embed"], manual),
                  manual_only(specs["final_norm"], manual), tok_spec),
        out_specs=out_specs, axis_names=manual, check_vma=False,
    )(staged["stage_layers"], staged["embed"], staged["final_norm"], tokens)
    if return_telemetry:
        x_last, telem = outs
    else:
        x_last, telem = outs, None

    x_last = L.rms_norm(x_last[:, None, :].astype(dt), staged["final_norm"],
                        cfg.norm_eps)
    w = staged["embed"].T if ("lm_head" not in staged) else staged["lm_head"]
    logits = L.unembed_logits(x_last, w, scale=cfg.logits_scaling)
    if return_telemetry:
        return logits[:, 0], telem
    return logits[:, 0]
