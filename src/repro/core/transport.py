"""Pluggable transport layer: every cross-stage / cross-rank collective the
pipeline issues, behind one protocol (DESIGN.md §3.6).

MOCAP's premise is that WSC interconnect makes MBKR reallocation traffic
cheap — which makes the COMMUNICATION layer the part worth orchestrating.
Before this module, raw ``ppermute``/``psum`` calls were hard-coded in four
files; now the pipeline path goes through a ``Transport``:

- ``ring_shift``      stage-boundary activation advance (+1 on the stage
                      axis — the paper's 1-hop D2D transfer),
- ``pair_shift``      the fixed cross-half MBKR pairing permute (spill
                      wires, fetch chunk-layer streams, qship q/state ships),
- ``stage_psum``      stage-axis reduction (final hidden-state collect),
- ``tp_psum`` / ``tp_reduce_scatter`` / ``tp_all_gather``
                      tensor-parallel collectives for the MANUAL TP lowering
                      (``RunConfig.tp_lowering="manual"``: explicit psums in
                      the stage programs instead of GSPMD partial-auto, which
                      old jaxlib cannot partition inside shard_map).

Transports are registered like attention backends (``register_transport``),
so future comm optimizations — TPU-native qship DMA, in-pipeline cold
streaming — plug into the registry instead of another monolith. The default
``jax`` transport lowers to ``jax.lax`` collectives.

The **CollectiveLedger** rides along: a carry-threaded pytree of per-category
wire-byte counters (``ring / collect / spill / fetch / qship_q / qship_state
/ tp``). Every transport call charges the bytes IT PUT ON THE WIRE from this
chip, gated by a traced ``active`` predicate (SPMD lockstep runs every
collective every tick; the ledger counts the *useful* bytes — the ones the
§3.4 traffic model prices). Byte counts come from the actual shipped arrays,
so a quantized codec's compression (``repro.kvstore``) is reflected
automatically — payload at storage-dtype width plus the fp32 scale rows.
``ledger_collect`` psums the per-chip counters over the mapped axes at the
end of the pipeline body; ``analytic_wire_bytes`` computes the same totals
in closed form from the plan (DESIGN.md §3.4/§3.6) — dryrun records it and
``tests/test_transport.py`` pins runtime-vs-analytic agreement to <1%.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# "prefix_hit" is SAVED bytes, not wire bytes: the stored KV a prefix-cache
# hit did not re-materialize (one chunk-store per (stage, hit phase); closed
# form in obs.telemetry.prefix_saved_model). The key exists unconditionally —
# same pytree, same psum count whether the prefix path is armed or not — so
# the disabled lowering stays bit-identical with zero extra collectives.
LEDGER_KEYS = ("ring", "collect", "spill", "fetch", "qship_q", "qship_state",
               "tp", "prefix_hit")

Ledger = Optional[Dict[str, jax.Array]]


def ledger_init() -> Dict[str, jax.Array]:
    """Fresh per-chip ledger: one fp32 byte counter per traffic category."""
    return {k: jnp.zeros((), jnp.float32) for k in LEDGER_KEYS}


def nbytes(x: jax.Array) -> float:
    """Wire bytes of one array as shipped (static: shape x itemsize)."""
    return float(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize)


def charge(led: Ledger, key: str, amount: float, active=None) -> Ledger:
    """Add ``amount`` bytes to ``led[key]``, gated by the traced ``active``
    predicate (None = unconditional). No-op on a None ledger."""
    if led is None or amount == 0.0:
        return led
    if active is None:
        add = jnp.float32(amount)
    else:
        add = jnp.where(active, jnp.float32(amount), 0.0)
    out = dict(led)
    out[key] = led[key] + add
    return out


def ledger_collect(led: Ledger, axis_names) -> Ledger:
    """Sum the per-chip counters over the mapped ``axis_names`` (stage + any
    manual TP axes) — after this every chip holds the global totals."""
    if led is None:
        return None
    return {k: jax.lax.psum(v, axis_names) for k, v in led.items()}


def ledger_to_dict(led) -> Dict[str, float]:
    return {k: float(np.asarray(v)) for k, v in led.items()}


# ========================================================== the protocol

class Transport:
    """One way to move bytes between chips. Methods take and return the
    ledger (carry-threaded pytree; None disables accounting) so call sites
    inside ``lax.scan`` bodies stay functional."""

    name = "abstract"

    # -- stage-axis movement -------------------------------------------
    def ring_shift(self, x, axis, perm, led: Ledger = None, *,
                   active=None) -> Tuple[jax.Array, Ledger]:
        """Activation advance to the next stage (ring +1)."""
        raise NotImplementedError

    def pair_shift(self, x, axis, perm, led: Ledger = None, *,
                   tag: str, active=None) -> Tuple[jax.Array, Ledger]:
        """Cross-half MBKR pairing permute. ``tag`` picks the ledger
        category (spill | fetch | qship_q | qship_state)."""
        raise NotImplementedError

    def stage_psum(self, x, axis, led: Ledger = None, *,
                   active=None) -> Tuple[jax.Array, Ledger]:
        """All-reduce over the stage axis (final hidden-state collect)."""
        raise NotImplementedError

    # -- tensor-parallel collectives (manual TP lowering) --------------
    def tp_psum(self, x, axes, led: Ledger = None, *,
                active=None) -> Tuple[jax.Array, Ledger]:
        raise NotImplementedError

    def tp_reduce_scatter(self, x, axes, led: Ledger = None, *,
                          scatter_axis: int = 0,
                          active=None) -> Tuple[jax.Array, Ledger]:
        raise NotImplementedError

    def tp_all_gather(self, x, axes, led: Ledger = None, *,
                      concat_axis: int = 0,
                      active=None) -> Tuple[jax.Array, Ledger]:
        raise NotImplementedError


class JaxCollectiveTransport(Transport):
    """Default transport: ``jax.lax`` collectives, ring-algorithm byte model.

    Wire-byte charges (per CHIP, per call — ``ledger_collect`` sums chips):
      permute (ring/pair):   nbytes(x)                 one send per chip
      all-reduce (psum):     2 * (k-1)/k * nbytes(x)   ring all-reduce
      reduce-scatter:        (k-1)/k * nbytes(x)
      all-gather:            (k-1) * nbytes(x_local)
    """

    name = "jax"

    @staticmethod
    def _axis_size(axes) -> int:
        sizes = jax.lax.psum(1, axes)
        return int(sizes)

    def ring_shift(self, x, axis, perm, led: Ledger = None, *, active=None):
        out = jax.lax.ppermute(x, axis, perm)
        return out, charge(led, "ring", nbytes(x), active)

    def pair_shift(self, x, axis, perm, led: Ledger = None, *,
                   tag: str, active=None):
        out = jax.lax.ppermute(x, axis, perm)
        return out, charge(led, tag, nbytes(x), active)

    def stage_psum(self, x, axis, led: Ledger = None, *, active=None):
        k = self._axis_size(axis)
        out = jax.lax.psum(x, axis)
        return out, charge(led, "collect", 2.0 * (k - 1) / k * nbytes(x),
                            active)

    def tp_psum(self, x, axes, led: Ledger = None, *, active=None):
        k = self._axis_size(axes)
        out = jax.lax.psum(x, axes)
        return out, charge(led, "tp", 2.0 * (k - 1) / k * nbytes(x), active)

    def tp_reduce_scatter(self, x, axes, led: Ledger = None, *,
                          scatter_axis: int = 0, active=None):
        k = self._axis_size(axes)
        out = jax.lax.psum_scatter(x, axes, scatter_dimension=scatter_axis,
                                   tiled=True)
        return out, charge(led, "tp", (k - 1) / k * nbytes(x), active)

    def tp_all_gather(self, x, axes, led: Ledger = None, *,
                      concat_axis: int = 0, active=None):
        k = self._axis_size(axes)
        out = jax.lax.all_gather(x, axes, axis=concat_axis, tiled=True)
        return out, charge(led, "tp", (k - 1) * nbytes(x), active)


# =========================================================== the registry

_TRANSPORTS: Dict[str, Callable[[], Transport]] = {}


def register_transport(name: str, factory: Callable[[], Transport]) -> None:
    _TRANSPORTS[name] = factory


def get_transport(name: str) -> Transport:
    if name not in _TRANSPORTS:
        raise KeyError(f"unknown transport {name!r}; "
                       f"registered: {sorted(_TRANSPORTS)}")
    return _TRANSPORTS[name]()


def available_transports() -> Tuple[str, ...]:
    return tuple(sorted(_TRANSPORTS))


register_transport("jax", JaxCollectiveTransport)


# ================================================== §3.4 analytic model

def analytic_wire_bytes(plan, cfg, b: int, *,
                        dtype_bytes: Optional[float] = None,
                        resident_pages=None) -> Dict[str, float]:
    """Closed-form §3.4 traffic totals for one ``prefill_pipeline`` call of a
    TRANSFORMER-family plan — the model the runtime ledger is validated
    against (``tests/test_transport.py``, <1%).

    Logical bytes, whole run, all stages, useful-gated exactly like the
    ledger: a transfer counts when its payload is consumed (fetch chunk j at
    phase p counts iff j < p; qship counts iff p > p2; spill counts iff the
    shipped chunk index is in [p2, M)). Per-chip TP sharding divides each
    chip's share but the psum over chips restores these logical totals, so
    the model is lowering-independent (auto vs manual TP) except for the
    ``tp`` category, which only the manual lowering puts on the wire (the
    stage programs charge it at the call site; it is not modeled here).

    ``resident_pages``: optional per-chunk RESIDENT page counts ([M] ints,
    each <= pages_per_chunk) — the ragged-occupancy variant for the paged
    pool path (DESIGN.md §3.7), where a chunk's spill/fetch wire carries
    only its resident pages instead of the padded slot stack. ``None`` (or
    all-full) reproduces the dense closed form exactly; today's uniform-
    chunk runtime ships full chunks, so the ledger pins against the dense
    case, and the ragged model prices what partial chunks will save."""
    n, m, c = plan.num_stages, plan.num_chunks, plan.chunk_len
    lps = plan.layers_per_stage
    kvh, hd, h = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    dt = dtype_bytes or float(jnp.dtype(cfg.dtype).itemsize)
    codec = plan.codec
    sto = float(codec.bytes_per_el)
    ppc = plan.pages_per_chunk
    pt = c // ppc
    if resident_pages is None:
        resident_pages = [ppc] * m
    rp = [int(min(max(p, 0), ppc)) for p in resident_pages]
    assert len(rp) == m, (len(rp), m)
    out = {k: 0.0 for k in LEDGER_KEYS}

    # ring: stage s < N-1 forwards its chunk output once per active phase
    out["ring"] = (n - 1) * m * (b * c * cfg.d_model) * dt
    # collect: one [B, d] fp32 all-reduce over the stage axis
    out["collect"] = 2.0 * (n - 1) * (b * cfg.d_model) * 4.0

    if plan.mode != "mocap" or plan.p2 >= m or cfg.attn_free:
        return out

    # --- spill: every stage ships each chunk in [p2, M) once (all lps
    # layers in one end-of-tick permute). Quantized codec: the wire carries
    # the encoded RESIDENT pages + fp32 scales; passthrough + int8
    # spill_dtype: int8 payload + one fp32 scale per (tensor, layer, kv
    # head).
    def spill_wire(pages: int) -> float:
        payload = 2 * lps * b * (pages * pt) * kvh * hd  # k and v elements
        if codec.quantized:
            return payload * sto + 2 * pages * lps * b * kvh * 4.0
        if plan.spill_dtype == "int8":
            return payload * 1.0 + 2 * lps * b * kvh * 4.0
        return payload * dt

    out["spill"] = n * sum(spill_wire(rp[j]) for j in range(plan.p2, m))

    if plan.remote_attn == "fetch":
        # one chunk-layer permute per (stage, layer, phase, remote chunk
        # consumed): chunk j is consumed at every phase p with j < p
        def fetch_wire(pages: int) -> float:
            payload = 2 * b * (pages * pt) * kvh * hd
            if codec.quantized:
                return payload * sto + 2 * pages * b * kvh * 4.0
            return payload * sto
        out["fetch"] = n * lps * sum(
            fetch_wire(rp[j])
            for p in range(m) for j in range(plan.p2, min(p, m)))
    else:
        # qship: one q ship + one (m, l, acc) return per (stage, layer,
        # phase with p > p2)
        phases = max(0, m - 1 - plan.p2)
        ship = float(jnp.dtype(plan.ship_dtype).itemsize)
        out["qship_q"] = n * lps * phases * (b * c * h * hd) * ship
        out["qship_state"] = n * lps * phases * (
            2 * (b * kvh * (h // kvh) * c) * 4.0        # (m, l) fp32 packed
            + (b * kvh * (h // kvh) * c * hd) * ship)   # acc in wire dtype
    return out
