"""Per-family stage programs: what ONE stage computes in ONE pipeline tick.

Three programs share the ``StageCtx`` contract and the backend-routed
``attend_chunk`` attention composition (own-pool prefix + remote prefix +
causal self block):

- ``tfm_stage_step``     transformer families (dense / moe / vlm / encdec
                         decoder with optional cross-attention),
- ``ssm_stage_step``     Mamba2: conv/SSD state carried tick-to-tick,
- ``hybrid_stage_step``  Zamba2: SSM groups + a shared attention block whose
                         KV participates in MBKR (one "layer" per group).

Every cross-chip byte goes through ``ctx.transport`` (core.transport) and
the stage programs thread the CollectiveLedger through their layer scans.
Under the MANUAL TP lowering (``ctx.mtp`` set, DESIGN.md §3.6) the programs
insert the explicit tensor-parallel psums GSPMD would otherwise derive: one
after each attention o-projection, one after each FFN down-projection (the
residual stream stays replicated; head/row counts come from the LOCAL param
shapes, so the same code traces both lowerings).

New model families plug in here without touching the driver (DESIGN.md §2.4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core import remote
from repro.core.attention import (attn_finish, attn_init, get_backend,
                                  group_queries, pool_scan)
from repro.core.plan import PipelinePlan
from repro.core.staging import ManualTP, _hyb_scfg
from repro.core import transport as tx
from repro.core.transport import Ledger, Transport
from repro.obs import telemetry as obs_t
from repro.obs.telemetry import StageTelemetry
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.topology import Topology

Params = Dict[str, Any]


@dataclass
class StageCtx:
    """Per-trace context threaded through the tick body."""
    cfg: ModelConfig
    plan: PipelinePlan
    topo: Topology
    stage: jax.Array          # my stage id (traced)
    phase: jax.Array          # my chunk index this tick (traced; may be OOR)
    first_half: jax.Array     # bool: stage < N/2
    pair_perm: Sequence[Tuple[int, int]]
    scale: float
    transport: Transport = None
    mtp: Optional[ManualTP] = None  # manual TP lowering plan (None = GSPMD)
    x_spec: Any = P(None, None, None)  # residual-stream sharding (SP variant)
    # STATIC hit-prefix length (chunks): the first k chunk writes redirect to
    # the scratch slot because the pool was SEEDED with cached prefix KV
    # (kvstore.prefix / DESIGN.md §11). 0 = prefix path disarmed; the traced
    # program is then byte-identical to pre-prefix builds.
    prefix_chunks: int = 0

    @property
    def active(self):
        """My phase is a real chunk this tick (not fill/drain garbage)."""
        return (self.phase >= 0) & (self.phase < self.plan.num_chunks)


def _tp_apply(ctx: StageCtx) -> Optional[T.ManualTPApply]:
    """Build the model-layer manual-TP hooks (psum closures) from the plan.
    Ledger charges for these reduces happen at the stage-program level (the
    closures stay ledger-free so they can run inside ``models`` code)."""
    mtp = ctx.mtp
    if mtp is None:
        return None
    tr = ctx.transport
    return T.manual_tp_apply(mtp, lambda y: tr.tp_psum(y, mtp.axes, None)[0])


def _psum_bytes(ctx: StageCtx, x: jax.Array) -> float:
    """Ring-all-reduce wire bytes of one manual tp_psum of ``x`` (per chip)."""
    k = ctx.mtp.tp
    return 2.0 * (k - 1) / k * tx.nbytes(x)


def _rep(ctx: StageCtx) -> int:
    """Telemetry count replication: manual TP chips charge 1/tp each so the
    collect psum restores logical per-stage counts."""
    return ctx.mtp.tp if ctx.mtp is not None else 1


def attend_chunk(ctx: StageCtx, l_idx: jax.Array, q: jax.Array,
                 k_new: jax.Array, v_new: jax.Array,
                 pool, led: Ledger = None, tel: StageTelemetry = None):
    """Full MOCAP attention for one layer of the current chunk:
    own-pool prefix + (MBKR) remote prefix + causal self block. Returns
    ``(att, ledger, telemetry)``.

    q [B,C,H,D]; k_new/v_new [B,C,K,D]; ``pool`` is the stage's paged KV
    store (``kvstore.pages.PagedPool``: payloads [P, lps, B, pt, K, D] +
    per-head scales when quantized). Under manual TP the shapes are the
    LOCAL shards (heads grouped per local kv head).

    Backends mix per SOURCE (the combine chain is backend-independent):
    the causal self block runs ``plan.attn_backend``; every POOL-sourced
    partial — the own-pool scan, fetch'd chunks, the creditor-side qship
    scan — runs ``plan.pool_backend`` (= attn_backend unless overridden
    via RunConfig.pool_backend). Under pallas the pool scan is one batched
    slot-grid kernel launch per (layer, tick), O(1) in pool depth."""
    plan = ctx.plan
    backend = get_backend(plan.attn_backend)
    pool_be = backend if plan.pool_backend == plan.attn_backend \
        else get_backend(plan.pool_backend)
    b, c, h, d = q.shape
    kvh = k_new.shape[2]
    qg = group_queries(q, kvh)
    st = attn_init(b, c, kvh, h // kvh, d)

    pool_l = remote._pool_layer(pool, l_idx)

    # telemetry: actual attention work this (layer, tick) — the LBCP cost
    # term with the TRACED prefix (phase * c tokens behind this chunk)
    if tel is not None:
        prefix = jnp.clip(ctx.phase, 0, plan.num_chunks - 1) * c
        tel = obs_t.charge(tel, "attn_work",
                           cm.attn_flops(ctx.cfg, c, prefix),
                           ctx.active, _rep(ctx))

    # 1. own local prefix: chunks j < min(phase, p2)
    limit = jnp.minimum(ctx.phase, plan.p2)
    st = pool_scan(pool_be, qg, pool_l, plan.slot_pages, plan.slot_own_chunk,
                   limit, ctx.scale, st)
    # lockstep: the pool scan launches every tick (batched = one slot-grid
    # block; streamed = one block per slot)
    tel = obs_t.charge(tel, "launches",
                       1.0 if pool_be.batched_pool else float(plan.num_slots),
                       None, _rep(ctx))

    # 2. remote prefix: chunks p2 <= j < phase live at my pair
    if plan.p2 < plan.num_chunks and plan.mode == "mocap":
        if plan.remote_attn == "fetch":
            st, led, tel = remote.fetch_remote(ctx, pool_be, qg, pool_l, st,
                                               led, tel)
        else:
            st, led, tel = remote.qship_remote(ctx, pool_be, qg, pool_l, st,
                                               led, tel)

    # 3. self block (causal)
    st = backend.self_block(qg, k_new, v_new, ctx.scale, st)
    tel = obs_t.charge(tel, "launches", 1.0, None, _rep(ctx))
    att = attn_finish(st, q.dtype)
    return att, led, tel


# --------------------------------------------------------- transformer step

def tfm_stage_step(ctx: StageCtx, layers: Params, x: jax.Array,
                   pool, led: Ledger = None, tel: StageTelemetry = None, *,
                   cross: Optional[Tuple] = None):
    """Apply this stage's layers to chunk ``ctx.phase``. Returns
    (x_out, pool, ledger, telemetry). ``cross`` = (enc_xk, enc_xv)
    [lps,B,F,K,D] for whisper decoder stages."""
    cfg, plan, mtp = ctx.cfg, ctx.plan, ctx.mtp
    tr = ctx.transport
    b, c, dm = x.shape
    hd = cfg.resolved_head_dim
    positions = jnp.clip(ctx.phase, 0, plan.num_chunks - 1) * plan.chunk_len \
        + jnp.arange(c)[None, :]
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)
    tp_apply = _tp_apply(ctx)
    # mirrors ffn_block's psum condition exactly: ONE reduce iff any FFN
    # part is actually sharded for THIS config (dense for non-MoE; expert
    # and/or present shared-expert parts for MoE)
    ffn_reduced = tp_apply is not None and (
        tp_apply.dense if cfg.moe is None else
        (tp_apply.moe or (cfg.moe.num_shared_experts > 0
                          and tp_apply.shared)))

    def layer_body(carry, xs):
        xc, li, led, tel = carry
        lp = xs if cross is None else xs[0]
        hn = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        # LOCAL head counts come from the (possibly TP-sharded) params
        q = jnp.einsum("bcd,dq->bcq", hn, lp["wq"])
        k = jnp.einsum("bcd,dq->bcq", hn, lp["wk"])
        v = jnp.einsum("bcd,dq->bcq", hn, lp["wv"])
        q = q.reshape(b, c, q.shape[-1] // hd, hd)
        k = k.reshape(b, c, k.shape[-1] // hd, hd)
        v = v.reshape(b, c, v.shape[-1] // hd, hd)
        if cfg.qk_norm:
            q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        if mtp is None:
            q = jax.lax.with_sharding_constraint(
                q, P(None, None, ctx.topo.tp_axis, None))
            if isinstance(ctx.topo.tp_axis, tuple):
                kv_ax = ctx.topo.tp_axis[0]
                k = jax.lax.with_sharding_constraint(k, P(None, None, kv_ax, None))
                v = jax.lax.with_sharding_constraint(v, P(None, None, kv_ax, None))
        att, led, tel = attend_chunk(ctx, li, q, k, v, pool, led, tel)
        h_loc = att.shape[2]
        upd = jnp.einsum("bcq,qd->bcd", att.reshape(b, c, h_loc * hd),
                         lp["wo"])
        if mtp is not None and mtp.attn:
            upd, led = tr.tp_psum(upd, mtp.axes, led, active=ctx.active)
        xc = xc + cfg.residual_multiplier * upd
        if cross is not None:
            xk_l = jax.lax.dynamic_index_in_dim(cross[0], li, 0, keepdims=False)
            xv_l = jax.lax.dynamic_index_in_dim(cross[1], li, 0, keepdims=False)
            hnx = L.rms_norm(xc, lp["lnx"], cfg.norm_eps)
            qx = jnp.einsum("bcd,dq->bcq", hnx, lp["xwq"])
            qx = qx.reshape(b, c, qx.shape[-1] // hd, hd)
            if plan.attn_backend == "pallas":
                # non-causal chunk_attention: decoder chunk vs the whole
                # encoder output through the flash kernel (ROADMAP item)
                from repro.kernels import ops as kops
                attx = kops.full_attention(qx, xk_l, xv_l)
            else:
                attx = L.flash_attention_xla(qx, xk_l, xv_l, causal_offset=None)
            hx_loc = attx.shape[2]
            updx = jnp.einsum("bcq,qd->bcd", attx.reshape(b, c, hx_loc * hd),
                              lp["xwo"])
            if mtp is not None and mtp.attn:
                updx, led = tr.tp_psum(updx, mtp.axes, led, active=ctx.active)
            xc = xc + updx
            tel = obs_t.charge(tel, "launches", 1.0, None, _rep(ctx))
        ep_axis = ctx.topo.tp_axis if (cfg.moe is not None and isinstance(
            ctx.topo.tp_axis, tuple) and mtp is None) else None
        if ep_axis is not None:
            # EP dispatch gathers tokens arbitrarily: replicate x first
            xc = jax.lax.with_sharding_constraint(xc, P(None, None, None))
        xc = T.ffn_block(cfg, lp, xc, topo=None, ep_axis=ep_axis, tp=tp_apply)
        if ffn_reduced:
            # one [B,C,d] psum inside ffn_block — charge it here
            led = tx.charge(led, "tp", _psum_bytes(ctx, xc), ctx.active)
        # kv_split: keep the residual stream SEQUENCE-SHARDED between layers
        # (Megatron-SP): psums become reduce-scatters and the stage-boundary
        # ring permute moves C/tp tokens per chip instead of C
        if mtp is None:
            xc = jax.lax.with_sharding_constraint(xc, ctx.x_spec)
        return (xc, li + 1, led, tel), (k, v)

    xs = layers if cross is None else (layers,)
    (x, _, led, tel), (ks, vs) = jax.lax.scan(
        layer_body, (x, jnp.int32(0), led, tel), xs)
    pool, led, tel = remote.write_pools(ctx, pool, ks, vs, led, tel)
    return x, pool, led, tel


# --------------------------------------------------------------- SSM step

def ssm_stage_step(ctx: StageCtx, layers: Params, x: jax.Array, state,
                   led: Ledger = None, tel: StageTelemetry = None):
    """Mamba2 stage: lps blocks; SSM/conv state carried tick-to-tick and
    zeroed at phase 0 (start of the request). The SSD inner loop routes
    through ``plan.ssm_backend`` (jnp reference | kernels.ops.ssd), the same
    knob pattern as attention. SSM blocks replicate under manual TP (no
    collectives — see staging.ManualTP), so the ledger passes through."""
    cfg, impl = ctx.cfg, ctx.plan.ssm_backend
    fresh = ctx.phase <= 0
    if tel is not None:
        lps = ctx.plan.layers_per_stage
        tel = obs_t.charge(tel, "attn_work",
                           lps * cm.attn_flops(cfg, x.shape[1], 0),
                           ctx.active, _rep(ctx))
        if impl == "pallas":
            tel = obs_t.charge(tel, "launches", float(lps), None, _rep(ctx))

    def layer_body(xc, xs):
        lp, conv_st, ssd_st = xs
        conv_st = jnp.where(fresh, jnp.zeros_like(conv_st), conv_st)
        ssd_st = jnp.where(fresh, jnp.zeros_like(ssd_st), ssd_st)
        xo, st2 = S.block_apply(cfg, lp, xc,
                                state={"conv": conv_st, "ssd": ssd_st},
                                ssd_impl=impl)
        return xo, (st2["conv"], st2["ssd"])

    x, (conv2, ssd2) = jax.lax.scan(layer_body, x, (layers, state[0], state[1]))
    return x, (conv2, ssd2), led, tel


# ------------------------------------------------------------- hybrid step

def hybrid_stage_step(ctx: StageCtx, groups: Params, shared: Params,
                      x: jax.Array, state, pool, led: Ledger = None,
                      tel: StageTelemetry = None):
    """Zamba2 stage = up to lps groups of (pg Mamba2 + shared attn block).
    The shared block's KV participates in MBKR (1 'layer' per group)."""
    cfg, plan, mtp = ctx.cfg, ctx.plan, ctx.mtp
    tr = ctx.transport
    ssd_impl = plan.ssm_backend
    scfg = _hyb_scfg(cfg)
    b, c, dm = x.shape
    hd = cfg.resolved_head_dim
    n_groups = cfg.hybrid.num_groups
    fresh = ctx.phase <= 0
    positions = jnp.clip(ctx.phase, 0, plan.num_chunks - 1) * plan.chunk_len \
        + jnp.arange(c)[None, :]
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)
    tp_apply = _tp_apply(ctx)

    def group_body(carry, xs):
        xc, gi, led, tel = carry
        g_lp, conv_st, ssd_st = xs

        def mamba_body(xm, ms):
            lp, cst, sst = ms
            cst = jnp.where(fresh, jnp.zeros_like(cst), cst)
            sst = jnp.where(fresh, jnp.zeros_like(sst), sst)
            xo, st2 = S.block_apply(cfg, lp, xm,
                                    state={"conv": cst, "ssd": sst},
                                    ssd_impl=ssd_impl)
            return xo, (st2["conv"], st2["ssd"])

        xc2, (conv2, ssd2) = jax.lax.scan(mamba_body, xc, (g_lp, conv_st, ssd_st))
        # shared attention: only for REAL groups (global group id < n_groups)
        gid = ctx.stage * plan.layers_per_stage + gi
        has_attn = gid < n_groups
        hn = L.rms_norm(xc2, shared["ln1"], cfg.norm_eps)
        q = jnp.einsum("bcd,dq->bcq", hn, shared["wq"])
        k = jnp.einsum("bcd,dq->bcq", hn, shared["wk"])
        v = jnp.einsum("bcd,dq->bcq", hn, shared["wv"])
        q = q.reshape(b, c, q.shape[-1] // hd, hd)
        k = k.reshape(b, c, k.shape[-1] // hd, hd)
        v = v.reshape(b, c, v.shape[-1] // hd, hd)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        att, led, tel = attend_chunk(ctx, gi, q, k, v, pool, led, tel)
        h_loc = att.shape[2]
        upd = jnp.einsum("bcq,qd->bcd", att.reshape(b, c, h_loc * hd),
                         shared["wo"])
        if mtp is not None and mtp.attn:
            upd, led = tr.tp_psum(upd, mtp.axes, led, active=ctx.active)
        xc3 = xc2 + jnp.where(has_attn, upd, 0.0)
        ffn = T.ffn_block(scfg, shared, xc3, topo=None,
                          tp=tp_apply) - xc3  # isolate update
        if tp_apply is not None and tp_apply.dense:
            led = tx.charge(led, "tp", _psum_bytes(ctx, xc3), ctx.active)
        xc3 = xc3 + jnp.where(has_attn, ffn, 0.0)
        return (xc3, gi + 1, led, tel), (conv2, ssd2, k, v)

    (x, _, led, tel), (conv2, ssd2, ks, vs) = jax.lax.scan(
        group_body, (x, jnp.int32(0), led, tel), (groups, state[0], state[1]))
    pool, led, tel = remote.write_pools(ctx, pool, ks, vs, led, tel)
    return x, (conv2, ssd2), pool, led, tel
