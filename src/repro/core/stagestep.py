"""Per-family stage programs: what ONE stage computes in ONE pipeline tick.

Three programs share the ``StageCtx`` contract and the backend-routed
``attend_chunk`` attention composition (own-pool prefix + remote prefix +
causal self block):

- ``tfm_stage_step``     transformer families (dense / moe / vlm / encdec
                         decoder with optional cross-attention),
- ``ssm_stage_step``     Mamba2: conv/SSD state carried tick-to-tick,
- ``hybrid_stage_step``  Zamba2: SSM groups + a shared attention block whose
                         KV participates in MBKR (one "layer" per group).

New model families plug in here without touching the driver (DESIGN.md §2.4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import remote
from repro.core.attention import (attn_finish, attn_init, get_backend,
                                  group_queries, pool_scan)
from repro.core.plan import PipelinePlan
from repro.core.staging import _hyb_scfg
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.topology import Topology

Params = Dict[str, Any]


@dataclass
class StageCtx:
    """Per-trace context threaded through the tick body."""
    cfg: ModelConfig
    plan: PipelinePlan
    topo: Topology
    stage: jax.Array          # my stage id (traced)
    phase: jax.Array          # my chunk index this tick (traced; may be OOR)
    first_half: jax.Array     # bool: stage < N/2
    pair_perm: Sequence[Tuple[int, int]]
    scale: float
    x_spec: Any = P(None, None, None)  # residual-stream sharding (SP variant)


def attend_chunk(ctx: StageCtx, l_idx: jax.Array, q: jax.Array,
                 k_new: jax.Array, v_new: jax.Array,
                 pool) -> jax.Array:
    """Full MOCAP attention for one layer of the current chunk:
    own-pool prefix + (MBKR) remote prefix + causal self block.
    q [B,C,H,D]; k_new/v_new [B,C,K,D]; ``pool`` is the stage's paged KV
    store (``kvstore.pages.PagedPool``: payloads [P, lps, B, pt, K, D] +
    per-head scales when quantized).

    Backends mix per SOURCE (the combine chain is backend-independent):
    the causal self block runs ``plan.attn_backend``; every POOL-sourced
    partial — the own-pool scan, fetch'd chunks, the creditor-side qship
    scan — runs ``plan.pool_backend`` (= attn_backend unless overridden
    via RunConfig.pool_backend). Under pallas the pool scan is one batched
    slot-grid kernel launch per (layer, tick), O(1) in pool depth."""
    plan = ctx.plan
    backend = get_backend(plan.attn_backend)
    pool_be = backend if plan.pool_backend == plan.attn_backend \
        else get_backend(plan.pool_backend)
    b, c, h, d = q.shape
    kvh = k_new.shape[2]
    qg = group_queries(q, kvh)
    st = attn_init(b, c, kvh, h // kvh, d)

    pool_l = remote._pool_layer(pool, l_idx)

    # 1. own local prefix: chunks j < min(phase, p2)
    limit = jnp.minimum(ctx.phase, plan.p2)
    st = pool_scan(pool_be, qg, pool_l, plan.slot_pages, plan.slot_own_chunk,
                   limit, ctx.scale, st)

    # 2. remote prefix: chunks p2 <= j < phase live at my pair
    if plan.p2 < plan.num_chunks and plan.mode == "mocap":
        if plan.remote_attn == "fetch":
            st = remote.fetch_remote(ctx, pool_be, qg, pool_l, st)
        else:
            st = remote.qship_remote(ctx, pool_be, qg, pool_l, st)

    # 3. self block (causal)
    st = backend.self_block(qg, k_new, v_new, ctx.scale, st)
    return attn_finish(st, q.dtype)


# --------------------------------------------------------- transformer step

def tfm_stage_step(ctx: StageCtx, layers: Params, x: jax.Array,
                   pool, *, cross: Optional[Tuple] = None):
    """Apply this stage's layers to chunk ``ctx.phase``. Returns
    (x_out, pool). ``cross`` = (enc_xk, enc_xv) [lps,B,F,K,D] for
    whisper decoder stages."""
    cfg, plan = ctx.cfg, ctx.plan
    b, c, dm = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.clip(ctx.phase, 0, plan.num_chunks - 1) * plan.chunk_len \
        + jnp.arange(c)[None, :]
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)

    def layer_body(carry, xs):
        xc, li = carry
        lp = xs if cross is None else xs[0]
        hn = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bcd,dq->bcq", hn, lp["wq"]).reshape(b, c, h, hd)
        k = jnp.einsum("bcd,dq->bcq", hn, lp["wk"]).reshape(b, c, kvh, hd)
        v = jnp.einsum("bcd,dq->bcq", hn, lp["wv"]).reshape(b, c, kvh, hd)
        if cfg.qk_norm:
            q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        q = jax.lax.with_sharding_constraint(q, P(None, None, ctx.topo.tp_axis, None))
        if isinstance(ctx.topo.tp_axis, tuple):
            kv_ax = ctx.topo.tp_axis[0]
            k = jax.lax.with_sharding_constraint(k, P(None, None, kv_ax, None))
            v = jax.lax.with_sharding_constraint(v, P(None, None, kv_ax, None))
        att = attend_chunk(ctx, li, q, k, v, pool)
        xc = xc + cfg.residual_multiplier * jnp.einsum(
            "bcq,qd->bcd", att.reshape(b, c, h * hd), lp["wo"])
        if cross is not None:
            xk_l = jax.lax.dynamic_index_in_dim(cross[0], li, 0, keepdims=False)
            xv_l = jax.lax.dynamic_index_in_dim(cross[1], li, 0, keepdims=False)
            hnx = L.rms_norm(xc, lp["lnx"], cfg.norm_eps)
            qx = jnp.einsum("bcd,dq->bcq", hnx, lp["xwq"]).reshape(b, c, h, hd)
            if plan.attn_backend == "pallas":
                # non-causal chunk_attention: decoder chunk vs the whole
                # encoder output through the flash kernel (ROADMAP item)
                from repro.kernels import ops as kops
                attx = kops.full_attention(qx, xk_l, xv_l)
            else:
                attx = L.flash_attention_xla(qx, xk_l, xv_l, causal_offset=None)
            xc = xc + jnp.einsum("bcq,qd->bcd", attx.reshape(b, c, h * hd), lp["xwo"])
        ep_axis = ctx.topo.tp_axis if (cfg.moe is not None and isinstance(
            ctx.topo.tp_axis, tuple)) else None
        if ep_axis is not None:
            # EP dispatch gathers tokens arbitrarily: replicate x first
            xc = jax.lax.with_sharding_constraint(xc, P(None, None, None))
        xc = T.ffn_block(cfg, lp, xc, topo=None, ep_axis=ep_axis)
        # kv_split: keep the residual stream SEQUENCE-SHARDED between layers
        # (Megatron-SP): psums become reduce-scatters and the stage-boundary
        # ring permute moves C/tp tokens per chip instead of C
        xc = jax.lax.with_sharding_constraint(xc, ctx.x_spec)
        return (xc, li + 1), (k, v)

    xs = layers if cross is None else (layers,)
    (x, _), (ks, vs) = jax.lax.scan(layer_body, (x, jnp.int32(0)), xs)
    pool = remote.write_pools(ctx, pool, ks, vs)
    return x, pool


# --------------------------------------------------------------- SSM step

def ssm_stage_step(ctx: StageCtx, layers: Params, x: jax.Array, state):
    """Mamba2 stage: lps blocks; SSM/conv state carried tick-to-tick and
    zeroed at phase 0 (start of the request). The SSD inner loop routes
    through ``plan.ssm_backend`` (jnp reference | kernels.ops.ssd), the same
    knob pattern as attention."""
    cfg, impl = ctx.cfg, ctx.plan.ssm_backend
    fresh = ctx.phase <= 0

    def layer_body(xc, xs):
        lp, conv_st, ssd_st = xs
        conv_st = jnp.where(fresh, jnp.zeros_like(conv_st), conv_st)
        ssd_st = jnp.where(fresh, jnp.zeros_like(ssd_st), ssd_st)
        xo, st2 = S.block_apply(cfg, lp, xc,
                                state={"conv": conv_st, "ssd": ssd_st},
                                ssd_impl=impl)
        return xo, (st2["conv"], st2["ssd"])

    x, (conv2, ssd2) = jax.lax.scan(layer_body, x, (layers, state[0], state[1]))
    return x, (conv2, ssd2)


# ------------------------------------------------------------- hybrid step

def hybrid_stage_step(ctx: StageCtx, groups: Params, shared: Params,
                      x: jax.Array, state, pool):
    """Zamba2 stage = up to lps groups of (pg Mamba2 + shared attn block).
    The shared block's KV participates in MBKR (1 'layer' per group)."""
    cfg, plan = ctx.cfg, ctx.plan
    ssd_impl = plan.ssm_backend
    scfg = _hyb_scfg(cfg)
    b, c, dm = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    n_groups = cfg.hybrid.num_groups
    fresh = ctx.phase <= 0
    positions = jnp.clip(ctx.phase, 0, plan.num_chunks - 1) * plan.chunk_len \
        + jnp.arange(c)[None, :]
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)

    def group_body(carry, xs):
        xc, gi = carry
        g_lp, conv_st, ssd_st = xs

        def mamba_body(xm, ms):
            lp, cst, sst = ms
            cst = jnp.where(fresh, jnp.zeros_like(cst), cst)
            sst = jnp.where(fresh, jnp.zeros_like(sst), sst)
            xo, st2 = S.block_apply(cfg, lp, xm,
                                    state={"conv": cst, "ssd": sst},
                                    ssd_impl=ssd_impl)
            return xo, (st2["conv"], st2["ssd"])

        xc2, (conv2, ssd2) = jax.lax.scan(mamba_body, xc, (g_lp, conv_st, ssd_st))
        # shared attention: only for REAL groups (global group id < n_groups)
        gid = ctx.stage * plan.layers_per_stage + gi
        has_attn = gid < n_groups
        hn = L.rms_norm(xc2, shared["ln1"], cfg.norm_eps)
        q = jnp.einsum("bcd,dq->bcq", hn, shared["wq"]).reshape(b, c, h, hd)
        k = jnp.einsum("bcd,dq->bcq", hn, shared["wk"]).reshape(b, c, kvh, hd)
        v = jnp.einsum("bcd,dq->bcq", hn, shared["wv"]).reshape(b, c, kvh, hd)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        att = attend_chunk(ctx, gi, q, k, v, pool)
        upd = jnp.einsum("bcq,qd->bcd", att.reshape(b, c, h * hd), shared["wo"])
        xc3 = xc2 + jnp.where(has_attn, upd, 0.0)
        ffn = T.ffn_block(scfg, shared, xc3, topo=None) - xc3  # isolate update
        xc3 = xc3 + jnp.where(has_attn, ffn, 0.0)
        return (xc3, gi + 1), (conv2, ssd2, k, v)

    (x, _), (conv2, ssd2, ks, vs) = jax.lax.scan(
        group_body, (x, jnp.int32(0)), (groups, state[0], state[1]))
    pool = remote.write_pools(ctx, pool, ks, vs)
    return x, (conv2, ssd2), pool
