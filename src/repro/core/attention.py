"""Online-softmax attention state + the pluggable backend registry.

MOCAP's attention for one chunk is a COMBINE of partial flash-attention
states over several KV sources (own pool slots, remote fetch/qship partials,
the causal self block). This module owns the state algebra — ``attn_init /
attn_combine / attn_finish`` with state ``(m, l, acc)``: running max,
denominator, unnormalized accumulator, all fp32 — and a registry of
*backends* that compute one partial state:

- ``jnp``    — the pure-jnp streaming reference (``attn_update``): einsum
               scores, masked softmax, accumulate. Runs everywhere; the
               numerics oracle.
- ``pallas`` — the WaferLLM-style flash kernel ``kernels.ops.chunk_attention``
               with ``return_state=True``: the kernel returns (m, l) plus
               the UNNORMALIZED fp32 accumulator straight from VMEM scratch,
               so kernel results join the same combine chain at full
               precision even when the normalized output dtype is bf16
               (interpret mode off-TPU, compiled on TPU).

A backend supplies two block kinds (DESIGN.md §2.3):
- ``self_block``  — causal attention of the chunk over its own fresh KV.
- ``chunk_block`` — full-visibility attention over ONE stored chunk's KV,
  gated by a traced ``valid`` scalar (the chunk participates iff its index is
  below the consumer's phase). Gating must be exact: an invalid chunk
  contributes the identity state (m=-inf, l=0, acc=0).

Stored chunks arrive ENCODED from the KV page store (``repro.kvstore``):
``chunk_block_q`` takes the page payload plus per-head scales and owns the
dequant-on-read — the jnp reference multiplies the scales out before its
block update; the pallas backend hands payload + scales straight to the
kernel, which dequantizes in its epilogue (quantized bytes cross HBM).

Backends are selected per-plan via ``RunConfig.attn_backend`` ->
``PipelinePlan.attn_backend``, and may be MIXED per source:
``RunConfig.pool_backend`` routes the pool-sourced partials (own-pool scan,
fetch/qship) separately from the self block. A backend that advertises
``batched_pool`` additionally fuses the whole pool scan into one
``pool_block`` call — the pallas slot-grid kernel
(``kernels.ops.pool_attention``) makes that a SINGLE launch per (layer,
tick), O(1) in pool depth, vs one ``chunk_attention`` launch per occupied
slot in the per-slot reference order. A backend that advertises
``paged_pool`` (``paged``) goes further: ``pool_scan`` hands it the
page-handle rows themselves and ``pool_block_paged`` launches the ragged
paged kernel (``kernels.ops.pool_attention_paged``) straight off the page
store — no gather, no dense slot stack, HBM traffic O(resident pages).
Registration is open for follow-ons (TPU-native qship kernel — ROADMAP).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvstore import pages as kvpages
from repro.kvstore import quant as kvquant

NEG_INF = float(-1e30)  # finite -inf stand-in: keeps masked softmax NaN-free

State = Tuple[jax.Array, jax.Array, jax.Array]  # (m, l, acc)


# ======================================================= state algebra (fp32)

def group_queries(q: jax.Array, kvh: int) -> jax.Array:
    """[B,C,H,D] -> [B,C,K,G,D] (query heads grouped per kv head)."""
    b, c, h, d = q.shape
    return q.reshape(b, c, kvh, h // kvh, d)


def attn_init(b: int, c: int, kvh: int, g: int, d: int) -> State:
    return (jnp.full((b, kvh, g, c), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, c), jnp.float32),
            jnp.zeros((b, kvh, g, c, d), jnp.float32))


def attn_update(qg, k, v, mask, scale, st: State) -> State:
    """One online-softmax block update (the jnp reference path).
    qg [B,C,K,G,D]; k,v [B,Ck,K,D]; mask broadcastable to [B,K,G,C,Ck];
    st = (m, l, acc) with m,l [B,K,G,C], acc [B,K,G,C,D]."""
    m, l, acc = st
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    # fully-masked rows: exp against a safe max so p == 0 (not exp(0) == 1)
    m_safe = jnp.where(m_new < NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[..., None])
    corr = jnp.exp(m - m_safe)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgcs,bskd->bkgcd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def attn_combine(st1: State, st2: State) -> State:
    m1, l1, a1 = st1
    m2, l2, a2 = st2
    m = jnp.maximum(m1, m2)
    m_safe = jnp.where(m < NEG_INF / 2, 0.0, m)
    c1, c2 = jnp.exp(m1 - m_safe), jnp.exp(m2 - m_safe)
    return m, l1 * c1 + l2 * c2, a1 * c1[..., None] + a2 * c2[..., None]


def attn_finish(st: State, q_dtype) -> jax.Array:
    m, l, acc = st
    b, kvh, g, c, d = acc.shape
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, c, kvh * g, d).astype(q_dtype)


# =========================================================== backend registry

class AttentionBackend:
    """One way to compute a partial attention state. Subclasses implement
    ``self_block`` (causal, within-chunk) and ``chunk_block`` (one stored
    chunk, full visibility, gated by a traced ``valid`` scalar); the combine
    chain and finish are shared module-level functions.

    ``batched_pool`` advertises a fused multi-slot path: when True,
    ``pool_scan`` gathers every visited slot's pages in one shot and calls
    ``pool_block`` ONCE (the pallas backend turns that into a single kernel
    launch); when False the scan stays per-slot (the jnp reference order)."""

    name = "abstract"
    batched_pool = False

    def self_block(self, qg, k, v, scale, st: State) -> State:
        raise NotImplementedError

    def chunk_block(self, qg, k, v, valid, scale, st: State) -> State:
        raise NotImplementedError

    def pool_block(self, qg, kq, vq, ks, vs, valid, scale,
                   st: State) -> State:
        """Attention over a STACK of stored chunks: payloads ``kq``/``vq``
        [S, B, Ck, K, D], per-page scales ``ks``/``vs`` [S, ppc, B, 1, K, 1]
        (None for a passthrough codec), ``valid`` [S] bool (traced). The
        base implementation is the per-slot ``lax.scan`` through
        ``chunk_block_q`` — slot order preserved, numerically identical to
        the unbatched pool scan; backends with a fused multi-slot kernel
        (pallas) override it."""
        def body(carry, xs):
            if ks is None:
                kqi, vqi, vi = xs
                ksi = vsi = None
            else:
                kqi, vqi, ksi, vsi, vi = xs
            return self.chunk_block_q(qg, kqi, vqi, ksi, vsi, vi, scale,
                                      carry), None

        xs = (kq, vq, valid) if ks is None else (kq, vq, ks, vs, valid)
        st, _ = jax.lax.scan(body, st, xs)
        return st

    def chunk_block_q(self, qg, kq, vq, k_scale, v_scale, valid, scale,
                      st: State) -> State:
        """``chunk_block`` over an ENCODED stored chunk: KV-page payload
        [B, Ck, K, D] + per-PAGE scales [ppc, B, 1, K, 1] from
        ``repro.kvstore``. Default: dequantize on read, then the plain
        block. Backends that can consume the payload directly (pallas)
        override this."""
        if k_scale is not None:
            pt = kq.shape[1] // k_scale.shape[0]
            k_scale = kvquant.expand_page_scale(k_scale, pt)  # [B, Ck, K, 1]
            v_scale = kvquant.expand_page_scale(v_scale, pt)
        k = kvquant.decode(kq, k_scale, qg.dtype)
        v = kvquant.decode(vq, v_scale, qg.dtype)
        return self.chunk_block(qg, k, v, valid, scale, st)


class JnpBackend(AttentionBackend):
    """Pure-jnp streaming reference (runs on any jax backend)."""

    name = "jnp"

    def self_block(self, qg, k, v, scale, st: State) -> State:
        c = qg.shape[1]
        tri = jnp.tril(jnp.ones((c, c), bool))
        return attn_update(qg, k, v, tri[None, None, None], scale, st)

    def chunk_block(self, qg, k, v, valid, scale, st: State) -> State:
        mask = valid[None, None, None, None, None]  # whole chunk on/off
        return attn_update(qg, k, v, mask, scale, st)


class PallasBackend(AttentionBackend):
    """Flash kernel backend: ``kernels.ops.chunk_attention`` computes the
    block, ``return_state`` exposes (m, l) plus the fp32 accumulator from
    VMEM scratch (NOT reconstructed from the dtype-rounded normalized
    output) so the result joins the combine chain at full precision.
    Interpret mode off-TPU; real Mosaic lowering on TPU."""

    name = "pallas"
    batched_pool = True

    @staticmethod
    def _to_state(m, l, acc, kvh: int) -> State:
        b, c, h, d = acc.shape
        g = h // kvh
        acc = acc.reshape(b, c, kvh, g, d).transpose(0, 2, 3, 1, 4)
        return m.reshape(b, kvh, g, c), l.reshape(b, kvh, g, c), acc

    def _kernel_state(self, qg, k, v, scale, causal_offset: int,
                      k_scale=None, v_scale=None) -> State:
        from repro.kernels import ops
        b, c, kvh, g, d = qg.shape
        q = qg.reshape(b, c, kvh * g, d)
        _, m, l, acc = ops.chunk_attention(
            q, k, v, causal_offset=causal_offset, scale=float(scale),
            return_state=True, k_scale=k_scale, v_scale=v_scale)
        return self._to_state(m, l, acc, kvh)

    @staticmethod
    def _gate(s2: State, valid) -> State:
        return (jnp.where(valid, s2[0], NEG_INF),
                jnp.where(valid, s2[1], 0.0),
                jnp.where(valid, s2[2], 0.0))

    def self_block(self, qg, k, v, scale, st: State) -> State:
        return attn_combine(st, self._kernel_state(qg, k, v, scale, 0))

    def chunk_block(self, qg, k, v, valid, scale, st: State) -> State:
        # full visibility: every query sees all Ck keys (offset >= Ck)
        s2 = self._kernel_state(qg, k, v, scale, int(k.shape[1]))
        return attn_combine(st, self._gate(s2, valid))

    def chunk_block_q(self, qg, kq, vq, k_scale, v_scale, valid, scale,
                      st: State) -> State:
        """Quantized pages go straight into the kernel: the dequant epilogue
        (chunk_attn.py) multiplies the per-token scale rows after the block
        load, so only payload bytes cross HBM."""
        if k_scale is None:
            return self.chunk_block(qg, kq, vq, valid, scale, st)
        pt = kq.shape[1] // k_scale.shape[0]
        ksc = kvquant.expand_page_scale(k_scale, pt)[..., 0]  # [B, Ck, K]
        vsc = kvquant.expand_page_scale(v_scale, pt)[..., 0]
        s2 = self._kernel_state(qg, kq, vq, scale, int(kq.shape[1]),
                                ksc, vsc)
        return attn_combine(st, self._gate(s2, valid))

    def pool_block(self, qg, kq, vq, ks, vs, valid, scale,
                   st: State) -> State:
        """Fused slot-grid kernel: ONE ``kernels.ops.pool_attention`` launch
        covers every stored chunk (grid = B x H x q-blocks x slots x
        kv-blocks), with per-slot validity gating and the quantized-page
        dequant epilogue inside the kernel — launch count per pool scan is
        O(1) in pool depth instead of O(slots)."""
        if not self.batched_pool:  # flag is authoritative: per-slot order
            return super().pool_block(qg, kq, vq, ks, vs, valid, scale, st)
        from repro.kernels import ops
        b, c, kvh, g, d = qg.shape
        q = qg.reshape(b, c, kvh * g, d)
        ksc = vsc = None
        if ks is not None:
            # per-page scales [S, ppc, B, 1, K, 1] -> per-token rows with a
            # leading slot axis [S, B, Ck, K] (pages axis leading for
            # expand_page_scale, slot axis rides in the batch dims)
            pt = kq.shape[2] // ks.shape[1]
            ksc = kvquant.expand_page_scale(jnp.moveaxis(ks, 1, 0), pt)[..., 0]
            vsc = kvquant.expand_page_scale(jnp.moveaxis(vs, 1, 0), pt)[..., 0]
        m, l, acc = ops.pool_attention(q, kq, vq, valid, scale=float(scale),
                                       k_scale=ksc, v_scale=vsc)
        return attn_combine(st, self._to_state(m, l, acc, kvh))


class PagedPallasBackend(PallasBackend):
    """Ragged paged pool backend (DESIGN.md §3.7): pool-sourced partials go
    through ``kernels.ops.pool_attention_paged`` — the kernel reads KV pages
    in place from the page store via scalar-prefetched handle rows, with
    double-buffered async copies and dequant on the VMEM landing buffer. No
    ``gather_chunks`` call, no dense ``[S, B, C, KVH, D]`` stack in HBM:
    pool HBM traffic is O(resident pages). Self/chunk blocks inherit the
    pallas flash kernel."""

    name = "paged"
    paged_pool = True  # pool_scan feeds page tables, not gathered stacks

    def pool_block_paged(self, qg, pool_l, page_rows, valid, scale,
                         st: State) -> State:
        """ONE paged launch straight off the layer's page store slice.
        ``page_rows`` [S, ppc] page-handle rows of the visited slots (static
        numpy or traced); ``valid`` [S] traced occupancy."""
        from repro.kernels import ops
        k_l, v_l, ks_l, vs_l = pool_l
        b, c, kvh, g, d = qg.shape
        q = qg.reshape(b, c, kvh * g, d)
        ppc = page_rows.shape[1]
        handles = jnp.asarray(page_rows, jnp.int32).reshape(-1)
        m, l, acc = ops.pool_attention_paged(
            q, k_l, v_l, handles, valid, ppc=ppc, scale=float(scale),
            k_scale=ks_l, v_scale=vs_l)
        return attn_combine(st, self._to_state(m, l, acc, kvh))

    def pool_block(self, qg, kq, vq, ks, vs, valid, scale,
                   st: State) -> State:
        """Stacked-interface entry (the batched-fetch landing path): view
        the landed chunk stack [S, B, C, K, D] as a page store with identity
        handles — [S*ppc, B, pt, K, D] pages — and reuse the paged kernel.
        With ppc == 1 (passthrough codec) the view is a free reshape; per-
        page quantized stacks pay one small staging-buffer transpose (the
        staging buffer is n_remote chunks, not the pool)."""
        from repro.kernels import ops
        s, b_, ck, kvh_, d_ = kq.shape
        ppc = 1 if ks is None else ks.shape[1]
        pt = ck // ppc

        def pageize(x):
            x = x.reshape(s, b_, ppc, pt, kvh_, d_)
            return x.transpose(0, 2, 1, 3, 4, 5).reshape(
                s * ppc, b_, pt, kvh_, d_)

        ksc = vsc = None
        if ks is not None:  # [S, ppc, B, 1, K, 1] -> [S*ppc, B, 1, K, 1]
            ksc = ks.reshape(s * ppc, *ks.shape[2:])
            vsc = vs.reshape(s * ppc, *vs.shape[2:])
        handles = jnp.arange(s * ppc, dtype=jnp.int32)
        b, c, kvh, g, d = qg.shape
        q = qg.reshape(b, c, kvh * g, d)
        m, l, acc = ops.pool_attention_paged(
            q, pageize(kq), pageize(vq), handles, valid, ppc=ppc,
            scale=float(scale), k_scale=ksc, v_scale=vsc)
        return attn_combine(st, self._to_state(m, l, acc, kvh))


_BACKENDS: Dict[str, Callable[[], AttentionBackend]] = {}


def register_backend(name: str, factory: Callable[[], AttentionBackend]) -> None:
    _BACKENDS[name] = factory


def get_backend(name: str) -> AttentionBackend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown attention backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}")
    return _BACKENDS[name]()


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


register_backend("jnp", JnpBackend)
register_backend("pallas", PallasBackend)
register_backend("paged", PagedPallasBackend)


# ============================================================ pool traversal

def pool_scan(backend: AttentionBackend, qg, pool_l, slot_pages, slot_chunk,
              limit, scale, st: State, slots: Optional[Any] = None) -> State:
    """Accumulate attention over pool slots whose stored chunk < ``limit``.

    ``pool_l`` = (k_l, v_l, ks_l, vs_l): THIS layer's slices of the paged
    pool — payloads [P, B, page_tokens, K, D] plus per-head scales (None for
    a passthrough codec). ``slot_pages`` [slots+1, ppc] is the page table;
    each visited slot's pages are gathered, and the ENCODED chunk goes to
    ``chunk_block_q`` (dequant-on-read is the backend's business).
    ``slots``: optional static subset of slot indices to visit (the creditor
    scan touches only the few host slots, not the whole pool).

    Three traversal orders, numerically reconciled by tests: a backend with
    ``paged_pool`` gets the page-handle rows DIRECTLY (``handle_rows`` ->
    ``pool_block_paged``) and the kernel reads pages in place — zero gather;
    a backend with ``batched_pool`` gets every visited slot's pages in ONE
    gather and ONE ``pool_block`` call (the pallas slot-grid kernel — a
    single launch over a dense HBM stack); otherwise the per-slot
    ``lax.scan`` below is the reference order (one chunk-layer resident at a
    time, one ``chunk_block_q`` per slot)."""
    k_l, v_l, ks_l, vs_l = pool_l
    if slots is not None:
        if len(slots) == 0:
            return st
        idx = jnp.asarray(np.asarray(slots, np.int32))
        chunk_ids = jnp.asarray(slot_chunk)[idx]
        page_rows = kvpages.handle_rows(slot_pages, slots)
    else:
        nslots = slot_pages.shape[0] - 1
        if nslots <= 0:
            return st
        chunk_ids = jnp.asarray(slot_chunk[:nslots])
        page_rows = kvpages.handle_rows(slot_pages)

    valid = (chunk_ids >= 0) & (chunk_ids < limit)
    if getattr(backend, "paged_pool", False):
        # handle rows go straight into the kernel's scalar-prefetch args —
        # both the full-pool and the creditor ``slots=`` subset paths
        return backend.pool_block_paged(qg, pool_l, page_rows, valid, scale,
                                        st)

    if backend.batched_pool:
        # ORACLE FEED, not a perf path: gather_chunks materializes the dense
        # [S, B, C, KVH, D] stack the paged kernel exists to avoid — kept as
        # the reference input for the slot-grid kernel
        kq, vq, ks, vs = kvpages.gather_chunks(k_l, v_l, ks_l, vs_l,
                                               page_rows)
        return backend.pool_block(qg, kq, vq, ks, vs, valid, scale, st)

    def body(carry, xs):
        pages, cid = xs
        kq, vq, ks, vs = kvpages.gather_chunk(k_l, v_l, ks_l, vs_l, pages)
        valid = (cid >= 0) & (cid < limit)
        return backend.chunk_block_q(qg, kq, vq, ks, vs, valid, scale,
                                     carry), None

    st, _ = jax.lax.scan(body, st, (page_rows, chunk_ids))
    return st
