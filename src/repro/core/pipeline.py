"""Chunked-pipeline prefill driver — MOCAP's execution model in JAX.

This module is the THIN top of a layered execution stack (DESIGN.md §2):

    core.plan       PipelinePlan / build_plan    (static geometry + MBKR)
    core.staging    stage_params / specs / pads  (params -> [N, lps, ...])
    core.attention  online-softmax state + the pluggable backend registry
                    (``jnp`` reference | ``pallas`` flash kernel)
    core.remote     spill / fetch / qship collectives
    core.stagestep  per-family stage programs (tfm / ssm / hybrid)
    core.gpipe      the GPipe microbatch baseline driver
    core.pipeline   (this file) the lax.scan tick loop + shard_map lowering

The paper's WSC pipeline maps onto the TPU mesh as (DESIGN.md §3): pipeline
stage = one slice of the mesh's ``stage`` axis; chunk flow = scan over ticks
with a ring ppermute at stage boundaries (the 1-hop D2D transfer); KV
residency = a per-stage slot pool sized by the MBKR plan; remote access =
fetch or qship (DESIGN.md §3.4). SPMD lockstep: every stage executes every
tick; stages outside their active window compute masked garbage — that is
the pipeline *bubble*, visible in the dry-run's HLO-to-model-FLOPs ratio.

The public planning/staging API is re-exported here so existing callers
(`runtime.engine`, `launch/{serve,dryrun,cells}.py`, roofline, tests) keep
importing ``repro.core.pipeline``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core import transport as tx
from repro.core.attention import NEG_INF  # noqa: F401  (re-export)
from repro.core.gpipe import gpipe_prefill
from repro.core.plan import PipelinePlan, build_plan  # noqa: F401
from repro.core.staging import (Params, alloc_kv_pool,  # noqa: F401
                                batch_specs, kv_split_axes, manual_only,
                                manual_tp_plan, manual_tree, pad_experts,
                                pad_q_heads, stage_param_specs, stage_params)
from repro.kvstore.pages import PagedPool
from repro.core.stagestep import (StageCtx, attend_chunk,  # noqa: F401
                                  hybrid_stage_step, ssm_stage_step,
                                  tfm_stage_step)
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.topology import Topology
from repro.obs import telemetry as obs_t

__all__ = [
    "PipelinePlan", "build_plan", "stage_params", "stage_param_specs",
    "kv_split_axes", "pad_q_heads", "pad_experts", "prefill_pipeline",
    "NEG_INF",
]


# ---------------------------------------------------------------- the driver

def prefill_pipeline(cfg: ModelConfig, staged: Params, tokens: jax.Array,
                     plan: PipelinePlan, topo: Topology, *,
                     embeds: Optional[jax.Array] = None,
                     return_ledger: bool = False,
                     return_telemetry: bool = False,
                     prefix_chunks: int = 0,
                     prefix_pool: Optional[PagedPool] = None,
                     return_kv: bool = False,
                     tick_hook=None, health=None) -> jax.Array:
    """Chunked-pipeline prefill of ``tokens`` [B, S]; returns next-token
    logits [B, Vpad] (prefill-only: ONE output token, KV is discarded).

    ``embeds``: stub frontend embeddings [B, F, d] (vlm / audio); spliced
    in FRONT of the token embeddings chunk-wise (they occupy the first
    F // C chunks; F must be chunk-aligned for the pipeline path).

    ``return_ledger``: also return the CollectiveLedger — per-category wire
    bytes summed over chips (``core.transport``; validated against the §3.4
    analytic model in tests) as a dict of fp32 scalars.

    ``return_telemetry``: also return the StageTelemetry profile
    (``repro.obs.telemetry``) — per-(stage, tick) ``[N, T]`` fp32 arrays of
    pool occupancy, resident KV bytes, spill/fetch/qship events, attention
    work and backend launches. When False (the default) no telemetry math
    is traced at all: the carry threads ``None`` and every charge
    short-circuits, so the compiled program is identical. Return order is
    ``logits[, ledger][, telemetry]``.

    ``tick_hook``: ZERO-ARG host callback fired (via ``jax.debug.callback``)
    at the END of every tick on every shard — the measured-span beacon
    (``obs.profile.TickSpanCollector.note``). It takes no operands because
    this jaxlib's SPMD partitioner rejects operand-carrying callbacks inside
    the manual shard_map region; tick identity is recovered host-side from
    arrival order (the scan runs ticks in order).

    ``health``: an ``obs.health.HealthMonitor``; arms the non-finite
    activation sentinel. Per-(stage, tick) finite-counts of the stage
    output (gated to ACTIVE phases so bubble garbage never pages anyone)
    ride the scan ys out of the manual region as an ``[N, T]`` int32
    profile, delivered by ONE host callback after the shard_map. The tick
    loop itself adds no collectives; the only armed comms cost is that
    end-of-run delivery gather of one tiny int32 array — O(1), not
    O(ticks).

    Both default to None, in which case NOTHING extra is traced — the
    compiled program is bit-identical (proven in tests/test_calibration.py,
    same style as the telemetry-off proof).

    ``prefix_chunks`` / ``prefix_pool`` / ``return_kv``: the device half of
    the prefix KV cache (``repro.kvstore.prefix``, DESIGN.md §11). When
    ``prefix_pool`` is given (a stage-stacked ``PagedPool`` snapshot, leading
    axis = stage) it REPLACES the zero-initialized pool, so the first
    ``prefix_chunks`` chunks of every sequence read cached KV instead of the
    KV they just computed; ``core.remote.write_pools`` redirects those
    chunks' writes to the scratch slot (the cached pages stay authoritative)
    and charges the ``prefix_hit`` ledger/telemetry keys. ``return_kv``
    additionally returns the scan-final pool snapshot so the host can seed
    future calls. All three default off, in which case the lowering is
    bit-identical to a build without this feature (the keys exist in the
    ledger/telemetry pytrees unconditionally, so no collective count
    changes). Return order is ``logits[, ledger][, telemetry][, kv]``.
    """
    if plan.mode == "gpipe":
        assert not return_ledger, "gpipe has no MBKR transport ledger"
        assert tick_hook is None and health is None, \
            "tick_hook/health probe only the chunked-pipeline driver"
        assert prefix_chunks == 0 and prefix_pool is None and not return_kv, \
            "prefix KV cache rides the chunked-pipeline paged pool only"
        return gpipe_prefill(cfg, staged, tokens, plan, topo,
                             return_telemetry=return_telemetry)
    n, m, c = plan.num_stages, plan.num_chunks, plan.chunk_len
    lps = plan.layers_per_stage
    st_ax = topo.stage_axis
    mtp = manual_tp_plan(cfg, plan, topo)
    if prefix_chunks or prefix_pool is not None or return_kv:
        assert cfg.family in ("dense", "moe"), \
            "prefix KV cache needs the pure paged-pool families (dense/moe)"
        # the pool's kvh axis must shard over the FULL manual TP degree, or
        # the host-side snapshot geometry wouldn't round-trip 1:1
        assert mtp is None or mtp.kv_div == mtp.tp, \
            "prefix pool I/O under manual TP requires kv_div == tp"
    if prefix_chunks:
        assert prefix_pool is not None, \
            "prefix_chunks > 0 requires a seeded prefix_pool"
        assert prefix_chunks <= min(plan.p2, plan.num_chunks - 1), \
            "prefix hits must stay within own-resident, non-final chunks"
    manual, pod_axes = batch_specs(topo, mtp)
    transport = tx.get_transport(plan.transport)
    led_axes = (st_ax,) + (mtp.axes if mtp is not None else ())
    attn_free = cfg.family == "ssm"
    kvh = cfg.num_kv_heads if not attn_free else 1
    if mtp is not None and not attn_free:
        kvh //= mtp.kv_div  # pool and stage programs see LOCAL kv heads
    hd = cfg.resolved_head_dim if not attn_free else 1
    dt = jnp.dtype(cfg.dtype)
    pair_perm = [(i, (i + n // 2) % n) for i in range(n)]
    ring_perm = [(i, (i + 1) % n) for i in range(n)]

    is_hybrid = cfg.family == "hybrid"
    is_ssm = cfg.family == "ssm"
    is_encdec = cfg.family == "encdec"

    # whisper: encoder runs OUTSIDE the pipeline (batch-parallel TP pass)
    enc_out = None
    if is_encdec:
        from repro.models import whisper as W
        enc_out = W.encode(cfg, {"enc_layers": staged["enc_layers"],
                                 "enc_norm": staged["enc_norm"]}, embeds)
        embeds = None

    def body(stage_layers, embed, final_norm, extra, tokens):
        stage = jax.lax.axis_index(st_ax)
        b = tokens.shape[0]
        sq = lambda a: jnp.squeeze(a, 0)
        stage_layers = jax.tree.map(sq, stage_layers)
        scale = cfg.attention_multiplier or 1.0 / math.sqrt(hd)

        cross = None
        if is_encdec:
            eo = extra["enc_out"]
            f = eo.shape[1]
            xk = jnp.einsum("bfd,ldq->lbfq", eo,
                            stage_layers["xwk"]).reshape(lps, b, f, kvh, hd)
            xv = jnp.einsum("bfd,ldq->lbfq", eo,
                            stage_layers["xwv"]).reshape(lps, b, f, kvh, hd)
            cross = (xk, xv)

        if is_ssm:  # attention-free: no KV pool at all
            pool = PagedPool(jnp.zeros((0,), dt), jnp.zeros((0,), dt))
        elif "prefix_pool" in extra:
            # seed from the cached snapshot (leading axis = stage, local
            # length 1 under the manual stage mapping) instead of zeros
            pool = jax.tree.map(sq, extra["prefix_pool"])
        else:
            pool = alloc_kv_pool(cfg, plan, b, topo, mtp=mtp)
        x0 = jnp.zeros((b, c, cfg.d_model), dt)
        if is_ssm or is_hybrid:
            d_in, nheads, conv_ch = S.dims(cfg)
            s = cfg.ssm
            if is_hybrid:
                pg = cfg.hybrid.ssm_per_group
                conv0 = jnp.zeros((lps, pg, b, s.conv_kernel - 1, conv_ch), jnp.float32)
                ssd0 = jnp.zeros((lps, pg, b, nheads, s.head_dim, s.d_state), jnp.float32)
            else:
                conv0 = jnp.zeros((lps, b, s.conv_kernel - 1, conv_ch), jnp.float32)
                ssd0 = jnp.zeros((lps, b, nheads, s.head_dim, s.d_state), jnp.float32)
            state0 = (conv0, ssd0)
        else:
            state0 = ()
        x_last0 = jnp.zeros((b, cfg.d_model), jnp.float32)

        # frontend splice: the token stream is [embeds, token-embeddings];
        # chunks may straddle the boundary — exact per-position select below
        emb_in = extra.get("embeds")
        n_front = 0
        embeds_pad = None
        if emb_in is not None:
            n_front = emb_in.shape[1]
            fpad = -(-n_front // c) * c
            embeds_pad = jnp.pad(emb_in, ((0, 0), (0, fpad - n_front), (0, 0)))

        # sequence-parallel residual is a GSPMD-auto-only optimization: the
        # manual lowering keeps the residual stream replicated across TP
        seq_sharded = (mtp is None and isinstance(topo.tp_axis, tuple)
                       and c % topo.tp_size == 0 and not is_ssm)
        x_spec = P(None, topo.tp_axis, None) if seq_sharded \
            else P(None, None, None)

        # one chunk's STORED pool bytes (local shard geometry under manual
        # TP — the telemetry collect psum restores logical stage bytes)
        chunk_bytes = 0.0 if is_ssm else obs_t.chunk_stored_bytes(
            plan, lps, b, c, kvh, hd)
        rep = mtp.tp if mtp is not None else 1

        def tick(carry, t):
            x_prev, pool, state, x_last, led, tel = carry
            phase = t - stage
            ctx = StageCtx(cfg=cfg, plan=plan, topo=topo, stage=stage,
                           phase=phase, first_half=stage < n // 2,
                           pair_perm=pair_perm, scale=scale,
                           transport=transport, mtp=mtp, x_spec=x_spec,
                           prefix_chunks=prefix_chunks)
            # ---- input: stage 0 embeds chunk t; others consume the ring buffer
            tc = jnp.clip(t, 0, m - 1)
            if n_front:
                pos = tc * c + jnp.arange(c)               # global positions
                tok_idx = jnp.clip(pos - n_front, 0, tokens.shape[1] - 1)
                tok_chunk = jnp.take(tokens, tok_idx, axis=1)
                x_tok = jnp.take(embed, tok_chunk, axis=0)
                fstart = jnp.minimum(tc * c, embeds_pad.shape[1] - c)
                x_front = jax.lax.dynamic_slice(
                    embeds_pad, (0, fstart, 0), (b, c, cfg.d_model)).astype(x_tok.dtype)
                x_emb = jnp.where((pos < n_front)[None, :, None], x_front, x_tok)
            else:
                tok_chunk = jax.lax.dynamic_slice(tokens, (0, tc * c), (b, c))
                x_emb = jnp.take(embed, tok_chunk, axis=0)
            if cfg.embedding_multiplier != 1.0:
                x_emb = x_emb * cfg.embedding_multiplier
            x = jnp.where(stage == 0, x_emb.astype(dt), x_prev)
            if mtp is None:
                x = jax.lax.with_sharding_constraint(x, x_spec)
            # ---- stage compute
            if is_ssm:
                x_out, state, led, tel = ssm_stage_step(ctx, stage_layers, x,
                                                        state, led, tel)
            elif is_hybrid:
                x_out, state, pool, led, tel = hybrid_stage_step(
                    ctx, stage_layers, extra["shared"], x, state, pool, led,
                    tel)
            else:
                x_out, pool, led, tel = tfm_stage_step(
                    ctx, stage_layers, x, pool, led, tel, cross=cross)
            # ---- telemetry: this tick's pool-residency deltas + snapshot
            if not is_ssm:
                tel = obs_t.charge_tick_residency(tel, ctx, chunk_bytes, rep)
            tel_ys = None if tel is None else dict(tel)
            # ---- capture the last token's hidden state at the last stage
            take = (stage == n - 1) & (phase == m - 1)
            x_last = jnp.where(take, x_out[:, -1].astype(jnp.float32), x_last)
            # ---- ring transfer to the next stage (useful while my chunk is
            # real and a downstream stage consumes it)
            ring_active = (phase >= 0) & (phase < m) & (stage < n - 1)
            x_next, led = transport.ring_shift(x_out, st_ax, ring_perm, led,
                                               active=ring_active)
            # ---- sentinels / probes: traced ONLY when armed (None = the
            # compiled program is bit-identical, zero extra collectives).
            # Non-finite counts ride the scan ys OUT of the manual region —
            # operand-carrying debug callbacks inside manual shard_map are
            # unsupported by this jaxlib's SPMD partitioner, so the only
            # in-region callback is the zero-arg tick beacon.
            bad = None
            if health is not None:
                nbad = jnp.sum(~jnp.isfinite(x_out.astype(jnp.float32)))
                bad = jnp.where(ctx.active, nbad, 0).astype(jnp.int32)
            if tick_hook is not None:
                jax.debug.callback(tick_hook)
            return (x_next, pool, state, x_last, led, tel), (tel_ys, bad)

        tel0 = obs_t.telemetry_init() if return_telemetry else None
        carry0 = (x0, pool, state0, x_last0, tx.ledger_init(), tel0)
        (xf, pool_f, _, x_last, led, _), (tel_ys, bad_ys) = jax.lax.scan(
            tick, carry0, jnp.arange(plan.num_ticks))
        # replicate the final hidden state across stages
        x_last, led = transport.stage_psum(x_last, st_ax, led)
        led = tx.ledger_collect(led, led_axes)
        outs = [x_last, led]
        if return_telemetry:
            tel_ys = obs_t.telemetry_collect(
                tel_ys, mtp.axes if mtp is not None else None)
            outs.append({k: v[None, :] for k, v in tel_ys.items()})  # [1, T]
        if return_kv:
            # scan-final pool, re-stacked on a leading stage axis for the
            # host-side snapshot (mirrors the prefix_pool input layout)
            outs.append(jax.tree.map(lambda a: a[None], pool_f))
        if health is not None:
            # residual is replicated across manual TP, so the count already
            # agrees on every TP shard — no psum, no extra collective
            outs.append(bad_ys[None, :])  # [1, T] local stage row
        return tuple(outs)

    extra: Params = {}
    if is_hybrid:
        extra["shared"] = staged["shared"]
    if is_encdec:
        extra["enc_out"] = enc_out
    if embeds is not None and not is_encdec:
        extra["embeds"] = embeds
    if prefix_pool is not None:
        extra["prefix_pool"] = prefix_pool

    # one spec covers every pool leaf: [n, P, lps, B, pt|1, kvh, hd|1] —
    # stage axis leads, batch is pod-sharded, kv heads carry the manual TP
    # axes (kv_div == tp is asserted above); under GSPMD-auto the kv-split
    # sharding flows from the argument's actual sharding instead
    kv_leaf_spec = P(st_ax, None, None, pod_axes if pod_axes else None, None,
                     mtp.axes if mtp is not None else None, None)

    specs = stage_param_specs(cfg, plan, topo)
    sl_specs = manual_tree(specs["stage_layers"], manual)
    extra_specs: Params = {}
    if is_hybrid:
        extra_specs["shared"] = manual_tree(specs["shared"], manual)
    if is_encdec:
        extra_specs["enc_out"] = P(pod_axes if pod_axes else None, None, None)
    if "embeds" in extra:
        extra_specs["embeds"] = P(pod_axes if pod_axes else None, None, None)
    if "prefix_pool" in extra:
        extra_specs["prefix_pool"] = jax.tree.map(
            lambda _: kv_leaf_spec, extra["prefix_pool"])
    tok_spec = P(pod_axes if pod_axes else None, None)
    out_spec = P(pod_axes if pod_axes else None, None)
    led_specs = {k: P() for k in tx.LEDGER_KEYS}
    tel_specs = {k: P(st_ax, None) for k in obs_t.TELEM_KEYS}
    out_specs_l: list = [out_spec, led_specs]
    if return_telemetry:
        out_specs_l.append(tel_specs)
    if return_kv:
        out_specs_l.append(PagedPool(
            kv_leaf_spec, kv_leaf_spec,
            kv_leaf_spec if plan.codec.quantized else None,
            kv_leaf_spec if plan.codec.quantized else None))
    if health is not None:
        out_specs_l.append(P(st_ax, None))
    out_specs = tuple(out_specs_l)

    outs = compat.shard_map(
        body, mesh=topo.mesh,
        in_specs=(sl_specs, manual_only(specs["embed"], manual),
                  manual_only(specs["final_norm"], manual),
                  extra_specs, tok_spec),
        out_specs=out_specs, axis_names=manual, check_vma=False,
    )(staged["stage_layers"], staged["embed"], staged["final_norm"],
      extra, tokens)
    outs = list(outs)
    x_last, ledger = outs[0], outs[1]
    telem = outs[2] if return_telemetry else None
    kv_out = outs[2 + int(return_telemetry)] if return_kv else None
    if health is not None:
        # operand callbacks are legal HERE (outside the manual region):
        # one host delivery of the full [N, T] non-finite profile
        jax.debug.callback(health.note_nonfinite_profile, outs[-1])

    # final norm + unembed of the single output token (prefill-only)
    from jax.sharding import NamedSharding
    x_last = L.rms_norm(x_last[:, None, :].astype(dt), staged["final_norm"],
                        cfg.norm_eps)
    w = staged["embed"].T if ("lm_head" not in staged) else staged["lm_head"]
    logits = L.unembed_logits(x_last, w, scale=cfg.logits_scaling)
    logits = jax.lax.with_sharding_constraint(
        logits, NamedSharding(topo.mesh, P(
            tuple(a for a in topo.batch_axes if a != topo.stage_axis) or None,
            None, None if mtp is not None else topo.tp_axis)))
    ret = [logits[:, 0]]
    if return_ledger:
        ret.append(ledger)
    if return_telemetry:
        ret.append(telem)
    if return_kv:
        ret.append(kv_out)
    return ret[0] if len(ret) == 1 else tuple(ret)
