"""Executable chunked-pipeline prefill — MOCAP's execution model in JAX.

The paper's WSC pipeline maps onto the TPU mesh as (DESIGN.md §3):

- pipeline stage  = one slice of the mesh's ``stage`` axis (= ``topo.stage_axis``,
  the 16-wide "data" axis of the production mesh); layers are sliced across
  stages, tensor parallelism inside a stage uses the (GSPMD-auto) "model" axis.
- chunk flow      = ``jax.lax.scan`` over ticks; the stage-boundary activation
  transfer is a ring ``ppermute`` (+1 on the stage axis) — the paper's 1-hop
  nearest-neighbour D2D transfer.
- KV residency    = a per-stage slot POOL sized by the MBKR plan
  (``core.mbkr.plan``): ``num_slots`` chunk-KV slots instead of the Terapipe
  baseline's M. Chunks with index >= p2 are SPILLED at creation: one
  ``ppermute`` by N/2 (the fixed cross-half pairing) moves them to the paired
  stage's host slots.
- remote access   = two modes:
    * ``fetch``  (paper-faithful): the debtor re-reads each spilled chunk from
      its pair at attention time, one chunk-layer slice per ppermute, streamed
      through the online-softmax update (residency = 1 chunk-layer).
    * ``qship``  (beyond-paper, TPU-native): the debtor ships its QUERY to the
      creditor, which computes partial flash-attention over the chunks it
      hosts and ships back (acc, lse). Traffic is O(q + out) instead of
      O(n_remote * kv): cheaper whenever >= 2 chunks are remote under GQA, and
      one round-trip instead of n_remote transfers. See DESIGN.md §3.4.

SPMD lockstep: every stage executes every tick; stages outside their active
window [s, s+M) compute masked garbage — that is the pipeline *bubble*,
directly visible in the dry-run's HLO-FLOPs-to-model-FLOPs ratio (§Roofline).

Modes: ``mocap`` (pool+MBKR), ``terapipe`` (pool of M slots, no reallocation),
``gpipe`` (microbatch pipeline: batch-split, full-sequence chunks, no pool).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, RunConfig
from repro.core import mbkr
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.topology import Topology

Params = Dict[str, Any]

NEG_INF = float(-1e30)  # finite -inf stand-in: keeps masked softmax NaN-free


# =============================================================== static plan

@dataclass(frozen=True)
class PipelinePlan:
    """Everything static about one pipeline lowering."""
    mode: str                 # mocap | terapipe | gpipe
    num_stages: int           # N
    num_chunks: int           # M
    chunk_len: int            # C (uniform); gpipe: microbatch size
    layers_per_stage: int     # lps (ceil(L / N)); hybrid: groups per stage
    num_slots: int            # KV pool size (excl. scratch)
    p2: int                   # spill threshold (chunks >= p2 spill); M if no MBKR
    remote_attn: str = "qship"   # fetch | qship
    spill_dtype: str = "bfloat16"  # int8 -> beyond-paper spill compression
    ship_dtype: str = "bfloat16"   # qship q/acc wire format (= model dtype)
    # static tables (numpy; become HLO constants)
    own_slot: Any = None          # [M] chunk -> own slot (scratch if spilled)
    host_slot_a: Any = None       # [M] chunk -> host slot (first-half hosts)
    host_slot_b: Any = None
    slot_own_chunk: Any = None    # [slots+1] slot -> own chunk (-1 none)
    slot_host_chunk_a: Any = None  # [slots+1] slot -> hosted pair chunk (-1)
    slot_host_chunk_b: Any = None
    host_slots_used: Any = None   # [H] the (few) slots host tables touch —
                                  # the creditor-side scan visits ONLY these

    @property
    def scratch(self) -> int:
        return self.num_slots

    @property
    def num_ticks(self) -> int:
        return self.num_chunks + self.num_stages - 1

    @property
    def pair_shift(self) -> int:
        return self.num_stages // 2


def _invert(table: np.ndarray, num_slots: int, lo: int, hi: int) -> np.ndarray:
    inv = np.full(num_slots + 1, -1, np.int32)
    for chunk in range(lo, hi):
        s = int(table[chunk])
        if s <= num_slots:
            inv[s] = chunk
    return inv


def build_plan(cfg: ModelConfig, num_stages: int, seq_len: int,
               run: RunConfig, *, mode: Optional[str] = None) -> PipelinePlan:
    """Derive the static pipeline plan for one (arch, shape, run) cell."""
    mode = mode or ("mocap" if run.mbkr else "terapipe")
    m = run.num_chunks
    if mode == "gpipe":
        return PipelinePlan(mode, num_stages, m, 0, _layers_per_stage(cfg, num_stages),
                            0, m)
    assert seq_len % m == 0, f"seq_len {seq_len} must divide into {m} chunks"
    c = seq_len // m
    use_mbkr = mode == "mocap" and not cfg.attn_free and num_stages >= 2 and m >= 2
    mp = mbkr.plan(m, num_stages, mbkr=use_mbkr)
    return PipelinePlan(
        mode=mode, num_stages=num_stages, num_chunks=m, chunk_len=c,
        layers_per_stage=_layers_per_stage(cfg, num_stages),
        num_slots=mp.num_slots, p2=mp.p2,
        remote_attn=run.remote_attn,
        spill_dtype=run.kv_spill_dtype,
        ship_dtype=cfg.dtype,   # wire in model precision (bf16 in prod)
        own_slot=mp.own_slot, host_slot_a=mp.host_slot_a, host_slot_b=mp.host_slot_b,
        slot_own_chunk=_invert(mp.own_slot, mp.num_slots, 0, mp.p2),
        slot_host_chunk_a=_invert(mp.host_slot_a, mp.num_slots, mp.p2, m),
        slot_host_chunk_b=_invert(mp.host_slot_b, mp.num_slots, mp.p2, m),
        host_slots_used=np.unique(np.concatenate(
            [mp.host_slot_a[mp.p2:], mp.host_slot_b[mp.p2:]])).astype(np.int32)
        if mp.p2 < m else np.zeros((0,), np.int32),
    )


def _layers_per_stage(cfg: ModelConfig, n: int) -> int:
    if cfg.family == "hybrid":
        nl = cfg.hybrid.num_groups + 1  # +1 pseudo-group for the SSM tail
    else:
        nl = cfg.num_layers
    return -(-nl // n)


# ============================================================ params staging

def stage_params(cfg: ModelConfig, params: Params, plan: PipelinePlan) -> Params:
    """Restack flat [L, ...] layer params into [N, lps, ...] (zero-padded:
    zero-param transformer/SSM blocks are exact identities via the residual).
    Embedding / head / norms are replicated across stages (SPMD: every stage
    computes the masked embed; only stage 0's result is used)."""
    n, lps = plan.num_stages, plan.layers_per_stage

    def restack(tree, nl):
        def one(a):
            pad = n * lps - nl
            if pad:
                a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])
            return a.reshape((n, lps) + a.shape[1:])
        return jax.tree.map(one, tree)

    if cfg.family == "hybrid":
        h = cfg.hybrid
        pg = h.ssm_per_group
        groups = params["mamba_groups"]        # [G, pg, ...]
        tail = params["mamba_tail"]            # [tail, ...]
        # tail becomes pseudo-group G (pad its layer dim to pg)
        def fold(g, t):
            t = jnp.concatenate(
                [t, jnp.zeros((pg - t.shape[0],) + t.shape[1:], t.dtype)])[None]
            g = jnp.concatenate([g, t])        # [G+1, pg, ...]
            pad = n * plan.layers_per_stage - g.shape[0]
            if pad:
                g = jnp.concatenate([g, jnp.zeros((pad,) + g.shape[1:], g.dtype)])
            return g.reshape((n, plan.layers_per_stage) + g.shape[1:])
        staged_groups = jax.tree.map(fold, groups, tail)
        return {
            "embed": params["embed"], "final_norm": params["final_norm"],
            "stage_layers": staged_groups, "shared": params["shared"],
        }
    if cfg.family == "encdec":
        out = {
            "embed": params["embed"], "final_norm": params["final_norm"],
            "stage_layers": restack(params["dec_layers"], cfg.num_layers),
            "enc_layers": params["enc_layers"], "enc_norm": params["enc_norm"],
        }
        return out
    out = {
        "embed": params["embed"], "final_norm": params["final_norm"],
        "stage_layers": restack(params["layers"], cfg.num_layers),
    }
    if "lm_head" in params:
        out["lm_head"] = params["lm_head"]
    return out


def stage_param_specs(cfg: ModelConfig, plan: PipelinePlan, topo: Topology) -> Params:
    """PartitionSpecs for ``stage_params`` output: stage dim over the stage
    axis, TP dims over the model axis, embed d-sharded (gather stays local)."""
    st, md = topo.stage_axis, topo.tp_axis

    def lift(spec: P) -> P:
        return P(st, None, *spec[1:])  # [L,...] -> [N, lps, ...]

    if cfg.family == "hybrid":
        bs = S.block_specs(cfg, fsdp=False)
        g_specs = jax.tree.map(lambda p: P(st, None, None, *p[1:]), bs,
                               is_leaf=lambda x: isinstance(x, P))
        shared = jax.tree.map(
            lambda p: P(*p[1:]), T.specs(_hyb_scfg(cfg), fsdp=False)["layers"],
            is_leaf=lambda x: isinstance(x, P))
        out = {"embed": P(None, md), "final_norm": P(None),
               "stage_layers": g_specs, "shared": shared}
        return _rename_model(out, md)
    if cfg.family == "encdec":
        from repro.models import whisper as W
        ws = W.specs(cfg, fsdp=False)
        dec = jax.tree.map(lift, ws["dec_layers"], is_leaf=lambda x: isinstance(x, P))
        out = {"embed": P(None, md), "final_norm": P(None),
               "stage_layers": dec, "enc_layers": ws["enc_layers"],
               "enc_norm": P(None)}
        return _rename_model(out, md)
    base = T.specs(cfg, fsdp=False)["layers"] if cfg.family != "ssm" \
        else S.block_specs(cfg, fsdp=False)
    layers = jax.tree.map(lift, base, is_leaf=lambda x: isinstance(x, P))
    out = {"embed": P(None, md), "final_norm": P(None), "stage_layers": layers}
    if not cfg.tie_embeddings and cfg.family in ("dense", "moe", "vlm"):
        out["lm_head"] = P(None, md)
    out = _rename_model(out, md)
    if isinstance(md, tuple) and cfg.family in ("dense", "moe", "vlm"):
        # K/V projections shard by KV HEAD only (replicated over "qg") so the
        # [B,C,kvh,hd] reshape keeps full head_dim per chip (no hd split)
        for k in ("wk", "wv"):
            out["stage_layers"][k] = P(topo.stage_axis, None, None, md[0])
        if cfg.moe is not None:
            # EXPERT parallelism: experts over the full TP axis, FFN local
            for k in ("e_wg", "e_wu", "e_wd"):
                out["stage_layers"][k] = P(topo.stage_axis, None, md, None, None)
    return out


def _hyb_scfg(cfg: ModelConfig) -> ModelConfig:
    from repro.models.hybrid import T_single_cfg
    return T_single_cfg(cfg)


def _rename_model(tree, tp_axis):
    """Model specs hardcode the "model" axis; rename to the topology's TP
    axis (possibly the split ("kv","qg") view)."""
    if tp_axis == "model":
        return tree

    def one(spec: P) -> P:
        return P(*(tp_axis if e == "model" else e for e in spec))
    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, P))


def kv_split_axes(cfg: ModelConfig, tp: int):
    """Factor the TP degree into (kv, qg) so attention shards by kv head and
    query group with NO collectives. Returns (kv_ax, qg_ax, padded_g) —
    padded_g > g means q heads are zero-padded per kv group (wq/wo pads are
    exact identities). None if kv heads don't divide."""
    if cfg.attn_free or cfg.num_kv_heads == 0:
        return None
    kvh, h = cfg.num_kv_heads, cfg.num_heads
    g = h // kvh
    kv_ax = min(kvh, tp)
    if tp % kv_ax or kvh % kv_ax:
        return None
    qg_ax = tp // kv_ax
    g_pad = -(-g // qg_ax) * qg_ax
    return kv_ax, qg_ax, g_pad


def pad_q_heads(cfg: ModelConfig, params: Params, g_pad: int) -> Tuple[ModelConfig, Params]:
    """Zero-pad query heads per kv group: H = kvh*g -> kvh*g_pad. Padded
    heads have zero wq (uniform attention) and zero wo rows (no contribution)
    — bit-exact with the unpadded model."""
    from repro.configs.base import replace as cfg_replace
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    g = cfg.num_heads // kvh
    if g_pad == g:
        return cfg, params
    lp = dict(params["layers"])
    L_, d = lp["wq"].shape[0], lp["wq"].shape[1]
    wq = lp["wq"].reshape(L_, d, kvh, g, hd)
    wq = jnp.pad(wq, ((0, 0), (0, 0), (0, 0), (0, g_pad - g), (0, 0)))
    lp["wq"] = wq.reshape(L_, d, kvh * g_pad * hd)
    wo = lp["wo"].reshape(L_, kvh, g, hd, d)
    wo = jnp.pad(wo, ((0, 0), (0, 0), (0, g_pad - g), (0, 0), (0, 0)))
    lp["wo"] = wo.reshape(L_, kvh * g_pad * hd, d)
    out = dict(params)
    out["layers"] = lp
    return cfg_replace(cfg, num_heads=kvh * g_pad), out


def pad_experts(cfg: ModelConfig, params: Params, e_pad: int) -> Tuple[ModelConfig, Params]:
    """Zero-pad routed experts to ``e_pad`` for expert parallelism. Padded
    experts' router logits are masked (MoEConfig.num_real_experts), so they
    are never routable — bit-exact."""
    import dataclasses
    from repro.configs.base import replace as cfg_replace
    m = cfg.moe
    if m is None or e_pad == m.num_experts:
        return cfg, params
    e0 = m.num_experts
    lp = dict(params["layers"])
    lp["router"] = jnp.pad(lp["router"], ((0, 0), (0, 0), (0, e_pad - e0)))
    for k in ("e_wg", "e_wu", "e_wd"):
        lp[k] = jnp.pad(lp[k], ((0, 0), (0, e_pad - e0)) + ((0, 0),) * (lp[k].ndim - 2))
    out = dict(params)
    out["layers"] = lp
    moe2 = dataclasses.replace(m, num_experts=e_pad,
                               num_real_experts=m.real_experts)
    return cfg_replace(cfg, moe=moe2), out


# ====================================================== online-softmax attn

def _gq(q: jax.Array, kvh: int) -> jax.Array:
    b, c, h, d = q.shape
    return q.reshape(b, c, kvh, h // kvh, d)


def _attn_update(qg, k, v, mask, scale, st):
    """One online-softmax block update.
    qg [B,C,K,G,D]; k,v [B,Ck,K,D]; mask broadcastable to [B,K,G,C,Ck];
    st = (m, l, acc) with m,l [B,K,G,C], acc [B,K,G,C,D]."""
    m, l, acc = st
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    # fully-masked rows: exp against a safe max so p == 0 (not exp(0) == 1)
    m_safe = jnp.where(m_new < NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[..., None])
    corr = jnp.exp(m - m_safe)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgcs,bskd->bkgcd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def _attn_init(b, c, kvh, g, d):
    return (jnp.full((b, kvh, g, c), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, c), jnp.float32),
            jnp.zeros((b, kvh, g, c, d), jnp.float32))


def _attn_combine(st1, st2):
    m1, l1, a1 = st1
    m2, l2, a2 = st2
    m = jnp.maximum(m1, m2)
    m_safe = jnp.where(m < NEG_INF / 2, 0.0, m)
    c1, c2 = jnp.exp(m1 - m_safe), jnp.exp(m2 - m_safe)
    return m, l1 * c1 + l2 * c2, a1 * c1[..., None] + a2 * c2[..., None]


def _attn_finish(st, q_dtype):
    m, l, acc = st
    b, kvh, g, c, d = acc.shape
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, c, kvh * g, d).astype(q_dtype)


def _pool_scan(qg, kpool_l, vpool_l, slot_chunk, limit, scale, st,
               slots: Optional[Any] = None):
    """Accumulate attention over pool slots whose stored chunk < ``limit``.
    kpool_l/vpool_l [slots+1, B, C, K, D] (this layer's slices).
    ``slots``: optional static subset of slot indices to visit (the creditor
    scan touches only the few host slots, not the whole pool)."""
    if slots is not None:
        if len(slots) == 0:
            return st
        idx = np.asarray(slots, np.int32)
        kpool_l = kpool_l[idx]
        vpool_l = vpool_l[idx]
        chunk_ids = jnp.asarray(slot_chunk)[jnp.asarray(idx)]
    else:
        nslots = kpool_l.shape[0] - 1
        if nslots <= 0:
            return st
        kpool_l = kpool_l[:nslots]
        vpool_l = vpool_l[:nslots]
        chunk_ids = jnp.asarray(slot_chunk[:nslots])

    def body(carry, xs):
        k, v, cid = xs
        valid = (cid >= 0) & (cid < limit)
        mask = valid[None, None, None, None, None]  # whole slot on/off
        return _attn_update(qg, k, v, mask, scale, carry), None

    st, _ = jax.lax.scan(body, st, (kpool_l, vpool_l, chunk_ids))
    return st


def _self_block(qg, k, v, scale, st):
    c = qg.shape[1]
    tri = jnp.tril(jnp.ones((c, c), bool))
    return _attn_update(qg, k, v, tri[None, None, None], scale, st)


# ========================================================== per-family step

@dataclass
class _StageCtx:
    """Per-trace context threaded through the tick body."""
    cfg: ModelConfig
    plan: PipelinePlan
    topo: Topology
    stage: jax.Array          # my stage id (traced)
    phase: jax.Array          # my chunk index this tick (traced; may be OOR)
    first_half: jax.Array     # bool: stage < N/2
    pair_perm: Sequence[Tuple[int, int]]
    scale: float
    x_spec: Any = P(None, None, None)  # residual-stream sharding (SP variant)


def _pair_phase(ctx: _StageCtx) -> jax.Array:
    n2 = ctx.plan.pair_shift
    return jnp.where(ctx.first_half, ctx.phase - n2, ctx.phase + n2)


def _spill_permute(ctx: "_StageCtx", kv: jax.Array) -> jax.Array:
    """Cross-half spill transfer. int8 mode: the WIRE carries the int8
    payload + one fp32 scale per (tensor, layer, kv head) — half the spill
    bytes; the pool stays in model dtype (dequantized at the creditor)."""
    plan = ctx.plan
    if plan.spill_dtype != "int8":
        return jax.lax.ppermute(kv, ctx.topo.stage_axis, ctx.pair_perm)
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=(-3, -1), keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale), -127, 127)
    q8 = jax.lax.ppermute(q.astype(jnp.int8), ctx.topo.stage_axis, ctx.pair_perm)
    s = jax.lax.ppermute(scale, ctx.topo.stage_axis, ctx.pair_perm)
    return (q8.astype(jnp.float32) * s).astype(kv.dtype)


def _attend_chunk(ctx: _StageCtx, l_idx: jax.Array, q: jax.Array,
                  k_new: jax.Array, v_new: jax.Array,
                  kpool: jax.Array, vpool: jax.Array) -> jax.Array:
    """Full MOCAP attention for one layer of the current chunk:
    own-pool prefix + (MBKR) remote prefix + causal self block.
    q [B,C,H,D]; k_new/v_new [B,C,K,D]; pools [slots+1, lps, B, C, K, D]."""
    plan, cfg = ctx.plan, ctx.cfg
    b, c, h, d = q.shape
    kvh = k_new.shape[2]
    qg = _gq(q, kvh)
    st = _attn_init(b, c, kvh, h // kvh, d)

    kpool_l = jax.lax.dynamic_index_in_dim(kpool, l_idx, axis=1, keepdims=False)
    vpool_l = jax.lax.dynamic_index_in_dim(vpool, l_idx, axis=1, keepdims=False)

    # 1. own local prefix: chunks j < min(phase, p2)
    limit = jnp.minimum(ctx.phase, plan.p2)
    st = _pool_scan(qg, kpool_l, vpool_l, plan.slot_own_chunk, limit, ctx.scale, st)

    # 2. remote prefix: chunks p2 <= j < phase live at my pair
    if plan.p2 < plan.num_chunks and plan.mode == "mocap":
        host_tbl = jnp.where(ctx.first_half,
                             jnp.asarray(plan.host_slot_a),
                             jnp.asarray(plan.host_slot_b))
        if plan.remote_attn == "fetch":
            # stream one chunk-layer per ppermute through the update
            def fetch_body(carry, j):
                stc = carry
                # what I HOST for my pair at index j  ->  what I RECEIVE is
                # my own chunk j (symmetric cross-half exchange)
                slot = host_tbl[j]
                ks = jax.lax.dynamic_index_in_dim(kpool_l, slot, 0, keepdims=False)
                vs = jax.lax.dynamic_index_in_dim(vpool_l, slot, 0, keepdims=False)
                pk = jax.lax.ppermute(jnp.stack([ks, vs]), ctx.topo.stage_axis,
                                      ctx.pair_perm)
                valid = (j < ctx.phase)
                stc = _attn_update(qg, pk[0], pk[1],
                                   valid[None, None, None, None, None],
                                   ctx.scale, stc)
                return stc, None
            st, _ = jax.lax.scan(fetch_body, st,
                                 jnp.arange(plan.p2, plan.num_chunks))
        else:  # qship: send my Q to the creditor; it attends over hosted KV
            sd = jnp.dtype(plan.ship_dtype)
            q_pair = jax.lax.ppermute(qg.astype(sd), ctx.topo.stage_axis,
                                      ctx.pair_perm).astype(qg.dtype)
            host_chunk = jnp.where(ctx.first_half,
                                   jnp.asarray(plan.slot_host_chunk_a),
                                   jnp.asarray(plan.slot_host_chunk_b))
            pair_limit = _pair_phase(ctx)  # pair needs chunks [p2, pair_phase)
            st_r = _attn_init(b, c, kvh, h // kvh, d)
            # creditor-side scan visits ONLY the host slots (compute win)
            st_r = _pool_scan(q_pair, kpool_l, vpool_l, host_chunk,
                              pair_limit, ctx.scale, st_r,
                              slots=plan.host_slots_used)
            # ship (m, l) packed fp32 + acc in the wire dtype
            ml = jax.lax.ppermute(jnp.stack([st_r[0], st_r[1]]),
                                  ctx.topo.stage_axis, ctx.pair_perm)
            a_r = jax.lax.ppermute(st_r[2].astype(sd), ctx.topo.stage_axis,
                                   ctx.pair_perm).astype(jnp.float32)
            st = _attn_combine(st, (ml[0], ml[1], a_r))

    # 3. self block (causal)
    st = _self_block(qg, k_new, v_new, ctx.scale, st)
    return _attn_finish(st, q.dtype)


def _write_pools(ctx: _StageCtx, kpool, vpool, stage_k, stage_v):
    """End-of-tick pool writes: own store (phase < p2) or cross-half spill."""
    plan = ctx.plan
    phase, active = ctx.phase, (ctx.phase >= 0) & (ctx.phase < plan.num_chunks)
    pidx = jnp.clip(phase, 0, plan.num_chunks - 1)

    own_tbl = jnp.asarray(plan.own_slot)
    own_slot = jnp.where(active & (phase < plan.p2), own_tbl[pidx], plan.scratch)
    kpool = jax.lax.dynamic_update_index_in_dim(kpool, stage_k, own_slot, 0)
    vpool = jax.lax.dynamic_update_index_in_dim(vpool, stage_v, own_slot, 0)

    if plan.p2 < plan.num_chunks and plan.mode == "mocap":
        spill = _spill_permute(ctx, jnp.stack([stage_k, stage_v]))
        pp = _pair_phase(ctx)  # the chunk index my pair just computed
        host_tbl = jnp.where(ctx.first_half,
                             jnp.asarray(plan.host_slot_a),
                             jnp.asarray(plan.host_slot_b))
        ppc = jnp.clip(pp, 0, plan.num_chunks - 1)
        hslot = jnp.where((pp >= plan.p2) & (pp < plan.num_chunks),
                          host_tbl[ppc], plan.scratch)
        kpool = jax.lax.dynamic_update_index_in_dim(kpool, spill[0], hslot, 0)
        vpool = jax.lax.dynamic_update_index_in_dim(vpool, spill[1], hslot, 0)
    return kpool, vpool


# --------------------------------------------------------- transformer step

def _tfm_stage_step(ctx: _StageCtx, layers: Params, layer_valid: jax.Array,
                    x: jax.Array, kpool, vpool, *, cross: Optional[Tuple] = None):
    """Apply this stage's layers to chunk ``ctx.phase``. Returns
    (x_out, kpool, vpool). ``cross`` = (enc_xk, enc_xv) [lps,B,F,K,D] for
    whisper decoder stages."""
    cfg, plan = ctx.cfg, ctx.plan
    b, c, dm = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.clip(ctx.phase, 0, plan.num_chunks - 1) * plan.chunk_len \
        + jnp.arange(c)[None, :]
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)

    def layer_body(carry, xs):
        xc, li = carry
        lp = xs if cross is None else xs[0]
        hn = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bcd,dq->bcq", hn, lp["wq"]).reshape(b, c, h, hd)
        k = jnp.einsum("bcd,dq->bcq", hn, lp["wk"]).reshape(b, c, kvh, hd)
        v = jnp.einsum("bcd,dq->bcq", hn, lp["wv"]).reshape(b, c, kvh, hd)
        if cfg.qk_norm:
            q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        q = jax.lax.with_sharding_constraint(q, P(None, None, ctx.topo.tp_axis, None))
        if isinstance(ctx.topo.tp_axis, tuple):
            kv_ax = ctx.topo.tp_axis[0]
            k = jax.lax.with_sharding_constraint(k, P(None, None, kv_ax, None))
            v = jax.lax.with_sharding_constraint(v, P(None, None, kv_ax, None))
        att = _attend_chunk(ctx, li, q, k, v, kpool, vpool)
        xc = xc + cfg.residual_multiplier * jnp.einsum(
            "bcq,qd->bcd", att.reshape(b, c, h * hd), lp["wo"])
        if cross is not None:
            xk_l = jax.lax.dynamic_index_in_dim(cross[0], li, 0, keepdims=False)
            xv_l = jax.lax.dynamic_index_in_dim(cross[1], li, 0, keepdims=False)
            hnx = L.rms_norm(xc, lp["lnx"], cfg.norm_eps)
            qx = jnp.einsum("bcd,dq->bcq", hnx, lp["xwq"]).reshape(b, c, h, hd)
            attx = L.flash_attention_xla(qx, xk_l, xv_l, causal_offset=None)
            xc = xc + jnp.einsum("bcq,qd->bcd", attx.reshape(b, c, h * hd), lp["xwo"])
        ep_axis = ctx.topo.tp_axis if (cfg.moe is not None and isinstance(
            ctx.topo.tp_axis, tuple)) else None
        if ep_axis is not None:
            # EP dispatch gathers tokens arbitrarily: replicate x first
            xc = jax.lax.with_sharding_constraint(xc, P(None, None, None))
        xc = T.ffn_block(cfg, lp, xc, topo=None, ep_axis=ep_axis)
        # kv_split: keep the residual stream SEQUENCE-SHARDED between layers
        # (Megatron-SP): psums become reduce-scatters and the stage-boundary
        # ring permute moves C/tp tokens per chip instead of C
        xc = jax.lax.with_sharding_constraint(xc, ctx.x_spec)
        return (xc, li + 1), (k, v)

    xs = layers if cross is None else (layers,)
    (x, _), (ks, vs) = jax.lax.scan(layer_body, (x, jnp.int32(0)), xs)
    kpool, vpool = _write_pools(ctx, kpool, vpool, ks, vs)
    return x, kpool, vpool


# --------------------------------------------------------------- SSM step

def _ssm_stage_step(ctx: _StageCtx, layers: Params, x: jax.Array, state):
    """Mamba2 stage: lps blocks; SSM/conv state carried tick-to-tick and
    zeroed at phase 0 (start of the request)."""
    cfg = ctx.cfg
    fresh = ctx.phase <= 0

    def layer_body(xc, xs):
        lp, conv_st, ssd_st = xs
        conv_st = jnp.where(fresh, jnp.zeros_like(conv_st), conv_st)
        ssd_st = jnp.where(fresh, jnp.zeros_like(ssd_st), ssd_st)
        xo, st2 = S.block_apply(cfg, lp, xc, state={"conv": conv_st, "ssd": ssd_st})
        return xo, (st2["conv"], st2["ssd"])

    x, (conv2, ssd2) = jax.lax.scan(layer_body, x, (layers, state[0], state[1]))
    return x, (conv2, ssd2)


# ------------------------------------------------------------- hybrid step

def _hybrid_stage_step(ctx: _StageCtx, groups: Params, shared: Params,
                       x: jax.Array, state, kpool, vpool):
    """Zamba2 stage = up to lps groups of (pg Mamba2 + shared attn block).
    The shared block's KV participates in MBKR (1 'layer' per group)."""
    cfg, plan = ctx.cfg, ctx.plan
    scfg = _hyb_scfg(cfg)
    b, c, dm = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    n_groups = cfg.hybrid.num_groups
    fresh = ctx.phase <= 0
    positions = jnp.clip(ctx.phase, 0, plan.num_chunks - 1) * plan.chunk_len \
        + jnp.arange(c)[None, :]
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)

    def group_body(carry, xs):
        xc, gi = carry
        g_lp, conv_st, ssd_st = xs

        def mamba_body(xm, ms):
            lp, cst, sst = ms
            cst = jnp.where(fresh, jnp.zeros_like(cst), cst)
            sst = jnp.where(fresh, jnp.zeros_like(sst), sst)
            xo, st2 = S.block_apply(cfg, lp, xm, state={"conv": cst, "ssd": sst})
            return xo, (st2["conv"], st2["ssd"])

        xc2, (conv2, ssd2) = jax.lax.scan(mamba_body, xc, (g_lp, conv_st, ssd_st))
        # shared attention: only for REAL groups (global group id < n_groups)
        gid = ctx.stage * plan.layers_per_stage + gi
        has_attn = gid < n_groups
        hn = L.rms_norm(xc2, shared["ln1"], cfg.norm_eps)
        q = jnp.einsum("bcd,dq->bcq", hn, shared["wq"]).reshape(b, c, h, hd)
        k = jnp.einsum("bcd,dq->bcq", hn, shared["wk"]).reshape(b, c, kvh, hd)
        v = jnp.einsum("bcd,dq->bcq", hn, shared["wv"]).reshape(b, c, kvh, hd)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        att = _attend_chunk(ctx, gi, q, k, v, kpool, vpool)
        upd = jnp.einsum("bcq,qd->bcd", att.reshape(b, c, h * hd), shared["wo"])
        xc3 = xc2 + jnp.where(has_attn, upd, 0.0)
        ffn = T.ffn_block(scfg, shared, xc3, topo=None) - xc3  # isolate update
        xc3 = xc3 + jnp.where(has_attn, ffn, 0.0)
        return (xc3, gi + 1), (conv2, ssd2, k, v)

    (x, _), (conv2, ssd2, ks, vs) = jax.lax.scan(
        group_body, (x, jnp.int32(0)), (groups, state[0], state[1]))
    kpool, vpool = _write_pools(ctx, kpool, vpool, ks, vs)
    return x, (conv2, ssd2), kpool, vpool


# ========================================================== pipeline driver

def _batch_specs(topo: Topology):
    """(manual axis_names, token spec, batch axes outside the stage axis)."""
    pod_axes = tuple(a for a in topo.batch_axes if a != topo.stage_axis)
    manual = set(pod_axes) | {topo.stage_axis}
    return manual, pod_axes


def _manual_only(spec: P, manual) -> P:
    """shard_map in_specs may only name MANUAL axes; auto-axis (TP) sharding
    flows through from the argument's actual sharding instead."""
    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in manual)
            return kept if kept else None
        return entry if entry in manual else None
    return P(*(keep(e) for e in spec))


def _manual_tree(tree, manual):
    return jax.tree.map(lambda p: _manual_only(p, manual), tree,
                        is_leaf=lambda x: isinstance(x, P))


def prefill_pipeline(cfg: ModelConfig, staged: Params, tokens: jax.Array,
                     plan: PipelinePlan, topo: Topology, *,
                     embeds: Optional[jax.Array] = None) -> jax.Array:
    """Chunked-pipeline prefill of ``tokens`` [B, S]; returns next-token
    logits [B, Vpad] (prefill-only: ONE output token, KV is discarded).

    ``embeds``: stub frontend embeddings [B, F, d] (vlm / audio); spliced
    in FRONT of the token embeddings chunk-wise (they occupy the first
    F // C chunks; F must be chunk-aligned for the pipeline path).
    """
    if plan.mode == "gpipe":
        return _gpipe_prefill(cfg, staged, tokens, plan, topo)
    n, m, c = plan.num_stages, plan.num_chunks, plan.chunk_len
    lps = plan.layers_per_stage
    st_ax = topo.stage_axis
    manual, pod_axes = _batch_specs(topo)
    attn_free = cfg.family == "ssm"
    kvh = cfg.num_kv_heads if not attn_free else 1
    hd = cfg.resolved_head_dim if not attn_free else 1
    dt = jnp.dtype(cfg.dtype)
    pair_perm = [(i, (i + n // 2) % n) for i in range(n)]
    ring_perm = [(i, (i + 1) % n) for i in range(n)]

    is_hybrid = cfg.family == "hybrid"
    is_ssm = cfg.family == "ssm"
    is_encdec = cfg.family == "encdec"
    # attention "layers" per stage: transformer = lps, hybrid = 1 per group
    kv_lps = lps

    # whisper: encoder runs OUTSIDE the pipeline (batch-parallel TP pass)
    enc_out = None
    if is_encdec:
        from repro.models import whisper as W
        enc_out = W.encode(cfg, {"enc_layers": staged["enc_layers"],
                                 "enc_norm": staged["enc_norm"]}, embeds)
        embeds = None

    def body(stage_layers, embed, final_norm, extra, tokens):
        stage = jax.lax.axis_index(st_ax)
        b = tokens.shape[0]
        sq = lambda a: jnp.squeeze(a, 0)
        stage_layers = jax.tree.map(sq, stage_layers)
        scale = cfg.attention_multiplier or 1.0 / math.sqrt(hd)

        cross = None
        if is_encdec:
            eo = extra["enc_out"]
            f = eo.shape[1]
            xk = jnp.einsum("bfd,ldq->lbfq", eo,
                            stage_layers["xwk"]).reshape(lps, b, f, kvh, hd)
            xv = jnp.einsum("bfd,ldq->lbfq", eo,
                            stage_layers["xwv"]).reshape(lps, b, f, kvh, hd)
            cross = (xk, xv)

        if is_ssm:  # attention-free: no KV pool at all
            kpool = vpool = jnp.zeros((0,), dt)
        else:
            kpool = jnp.zeros((plan.num_slots + 1, kv_lps, b, c, kvh, hd), dt)
            vpool = jnp.zeros_like(kpool)
            if isinstance(topo.tp_axis, tuple):  # kv_split: pool by kv head
                pool_spec = P(None, None, None, None, topo.tp_axis[0], None)
                kpool = jax.lax.with_sharding_constraint(kpool, pool_spec)
                vpool = jax.lax.with_sharding_constraint(vpool, pool_spec)
        x0 = jnp.zeros((b, c, cfg.d_model), dt)
        if is_ssm or is_hybrid:
            d_in, nheads, conv_ch = S.dims(cfg)
            s = cfg.ssm
            if is_hybrid:
                pg = cfg.hybrid.ssm_per_group
                conv0 = jnp.zeros((lps, pg, b, s.conv_kernel - 1, conv_ch), jnp.float32)
                ssd0 = jnp.zeros((lps, pg, b, nheads, s.head_dim, s.d_state), jnp.float32)
            else:
                conv0 = jnp.zeros((lps, b, s.conv_kernel - 1, conv_ch), jnp.float32)
                ssd0 = jnp.zeros((lps, b, nheads, s.head_dim, s.d_state), jnp.float32)
            state0 = (conv0, ssd0)
        else:
            state0 = ()
        x_last0 = jnp.zeros((b, cfg.d_model), jnp.float32)

        # frontend splice: the token stream is [embeds, token-embeddings];
        # chunks may straddle the boundary — exact per-position select below
        emb_in = extra.get("embeds")
        n_front = 0
        embeds_pad = None
        if emb_in is not None:
            n_front = emb_in.shape[1]
            fpad = -(-n_front // c) * c
            embeds_pad = jnp.pad(emb_in, ((0, 0), (0, fpad - n_front), (0, 0)))

        seq_sharded = (isinstance(topo.tp_axis, tuple)
                       and c % topo.tp_size == 0 and not is_ssm)
        x_spec = P(None, topo.tp_axis, None) if seq_sharded \
            else P(None, None, None)

        def tick(carry, t):
            x_prev, kpool, vpool, state, x_last = carry
            phase = t - stage
            active = (phase >= 0) & (phase < m)
            ctx = _StageCtx(cfg=cfg, plan=plan, topo=topo, stage=stage,
                            phase=phase, first_half=stage < n // 2,
                            pair_perm=pair_perm, scale=scale, x_spec=x_spec)
            # ---- input: stage 0 embeds chunk t; others consume the ring buffer
            tc = jnp.clip(t, 0, m - 1)
            if n_front:
                pos = tc * c + jnp.arange(c)               # global positions
                tok_idx = jnp.clip(pos - n_front, 0, tokens.shape[1] - 1)
                tok_chunk = jnp.take(tokens, tok_idx, axis=1)
                x_tok = jnp.take(embed, tok_chunk, axis=0)
                fstart = jnp.minimum(tc * c, embeds_pad.shape[1] - c)
                x_front = jax.lax.dynamic_slice(
                    embeds_pad, (0, fstart, 0), (b, c, cfg.d_model)).astype(x_tok.dtype)
                x_emb = jnp.where((pos < n_front)[None, :, None], x_front, x_tok)
            else:
                tok_chunk = jax.lax.dynamic_slice(tokens, (0, tc * c), (b, c))
                x_emb = jnp.take(embed, tok_chunk, axis=0)
            if cfg.embedding_multiplier != 1.0:
                x_emb = x_emb * cfg.embedding_multiplier
            x = jnp.where(stage == 0, x_emb.astype(dt), x_prev)
            x = jax.lax.with_sharding_constraint(x, x_spec)
            # ---- stage compute
            if is_ssm:
                x_out, state = _ssm_stage_step(ctx, stage_layers, x, state)
            elif is_hybrid:
                x_out, state, kpool, vpool = _hybrid_stage_step(
                    ctx, stage_layers, extra["shared"], x, state, kpool, vpool)
            else:
                x_out, kpool, vpool = _tfm_stage_step(
                    ctx, stage_layers, None, x, kpool, vpool, cross=cross)
            # ---- capture the last token's hidden state at the last stage
            take = (stage == n - 1) & (phase == m - 1)
            x_last = jnp.where(take, x_out[:, -1].astype(jnp.float32), x_last)
            # ---- ring transfer to the next stage
            x_next = jax.lax.ppermute(x_out, st_ax, ring_perm)
            return (x_next, kpool, vpool, state, x_last), None

        carry0 = (x0, kpool, vpool, state0, x_last0)
        (xf, _, _, _, x_last), _ = jax.lax.scan(
            tick, carry0, jnp.arange(plan.num_ticks))
        # replicate the final hidden state across stages
        x_last = jax.lax.psum(x_last, st_ax)
        return x_last

    extra: Params = {}
    if is_hybrid:
        extra["shared"] = staged["shared"]
    if is_encdec:
        extra["enc_out"] = enc_out
    if embeds is not None and not is_encdec:
        extra["embeds"] = embeds

    specs = stage_param_specs(cfg, plan, topo)
    sl_specs = _manual_tree(specs["stage_layers"], manual)
    extra_specs: Params = {}
    if is_hybrid:
        extra_specs["shared"] = _manual_tree(specs["shared"], manual)
    if is_encdec:
        extra_specs["enc_out"] = P(pod_axes if pod_axes else None, None, None)
    if "embeds" in extra:
        extra_specs["embeds"] = P(pod_axes if pod_axes else None, None, None)
    tok_spec = P(pod_axes if pod_axes else None, None)
    out_spec = P(pod_axes if pod_axes else None, None)

    x_last = compat.shard_map(
        body, mesh=topo.mesh,
        in_specs=(sl_specs, _manual_only(specs["embed"], manual),
                  _manual_only(specs["final_norm"], manual),
                  extra_specs, tok_spec),
        out_specs=out_spec, axis_names=manual, check_vma=False,
    )(staged["stage_layers"], staged["embed"], staged["final_norm"],
      extra, tokens)

    # final norm + unembed of the single output token (prefill-only)
    from jax.sharding import NamedSharding
    x_last = L.rms_norm(x_last[:, None, :].astype(dt), staged["final_norm"],
                        cfg.norm_eps)
    w = staged["embed"].T if ("lm_head" not in staged) else staged["lm_head"]
    logits = L.unembed_logits(x_last, w, scale=cfg.logits_scaling)
    logits = jax.lax.with_sharding_constraint(
        logits, NamedSharding(topo.mesh, P(
            tuple(a for a in topo.batch_axes if a != topo.stage_axis) or None,
            None, topo.tp_axis)))
    return logits[:, 0]


# ------------------------------------------------------------------- gpipe

def _gpipe_prefill(cfg: ModelConfig, staged: Params, tokens: jax.Array,
                   plan: PipelinePlan, topo: Topology) -> jax.Array:
    """GPipe baseline: microbatch pipeline over the BATCH dim; every
    microbatch carries the full sequence (full quadratic attention per tick,
    no KV pool — the paper's Fig. 2(a) comparison point)."""
    n, m = plan.num_stages, plan.num_chunks
    st_ax = topo.stage_axis
    manual, pod_axes = _batch_specs(topo)
    dt = jnp.dtype(cfg.dtype)
    ring_perm = [(i, (i + 1) % n) for i in range(n)]
    lps = plan.layers_per_stage

    def body(stage_layers, embed, final_norm, tokens):
        stage = jax.lax.axis_index(st_ax)
        stage_layers = jax.tree.map(lambda a: jnp.squeeze(a, 0), stage_layers)
        b, s_full = tokens.shape
        assert b % m == 0, f"gpipe: batch {b} must divide into {m} microbatches"
        bm = b // m
        x0 = jnp.zeros((bm, s_full, cfg.d_model), dt)
        out0 = jnp.zeros((b, cfg.d_model), jnp.float32)

        def tick(carry, t):
            x_prev, out = carry
            phase = t - stage
            mb = jnp.clip(t, 0, m - 1)
            tok_mb = jax.lax.dynamic_slice(tokens, (mb * bm, 0), (bm, s_full))
            x_emb = jnp.take(embed, tok_mb, axis=0).astype(dt)
            if cfg.embedding_multiplier != 1.0:
                x_emb = x_emb * cfg.embedding_multiplier
            x = jnp.where(stage == 0, x_emb, x_prev)

            def layer_body(xc, lp):
                xo, _, _ = T.layer_apply(cfg, lp, xc, impl="xla_flash", topo=None)
                return xo, None
            x_out, _ = jax.lax.scan(layer_body, x, stage_layers)
            take = (stage == n - 1) & (phase >= 0) & (phase < m)
            mbp = jnp.clip(phase, 0, m - 1)
            upd = jnp.where(take, x_out[:, -1].astype(jnp.float32),
                            jax.lax.dynamic_slice(out, (mbp * bm, 0),
                                                  (bm, cfg.d_model)))
            out = jax.lax.dynamic_update_slice(out, upd, (mbp * bm, 0))
            x_next = jax.lax.ppermute(x_out, st_ax, ring_perm)
            return (x_next, out), None

        (xf, out), _ = jax.lax.scan(tick, (x0, out0), jnp.arange(m + n - 1))
        return jax.lax.psum(jnp.where(stage == n - 1, out, 0.0), st_ax)

    specs = stage_param_specs(cfg, plan, topo)
    sl_specs = _manual_tree(specs["stage_layers"], manual)
    tok_spec = P(pod_axes if pod_axes else None, None)
    x_last = compat.shard_map(
        body, mesh=topo.mesh,
        in_specs=(sl_specs, _manual_only(specs["embed"], manual),
                  _manual_only(specs["final_norm"], manual), tok_spec),
        out_specs=tok_spec, axis_names=manual, check_vma=False,
    )(staged["stage_layers"], staged["embed"], staged["final_norm"], tokens)

    x_last = L.rms_norm(x_last[:, None, :].astype(dt), staged["final_norm"],
                        cfg.norm_eps)
    w = staged["embed"].T if ("lm_head" not in staged) else staged["lm_head"]
    logits = L.unembed_logits(x_last, w, scale=cfg.logits_scaling)
    return logits[:, 0]
