"""Remote KV access: the spill / fetch / qship collectives (DESIGN.md §3.4).

MBKR spills chunks with index >= p2 at creation: one pairing permute by N/2
(the fixed cross-half stage pairing) moves their KV to the paired stage's
host slots. At attention time the debtor reaches its remote prefix one of
two ways:

- ``fetch``  (paper-faithful): re-read each spilled chunk from the pair, one
  chunk-layer slice per permute. The streamed order runs each landed chunk
  through the online-softmax combine as it arrives (residency = 1
  chunk-layer); with a ``batched_pool`` backend (and
  ``plan.fetch_batch != "off"``) the landed chunk-layers accumulate in a
  staging buffer instead and go through ONE ``pool_block`` launch — same
  wire traffic, O(1) attention launches per (layer, tick) instead of one
  per remote chunk (``ops.count_launches`` pins it). Under the PAGED pool
  backend the staging buffer is viewed as a page store with identity
  handles and the same ragged paged kernel consumes it
  (``PagedPallasBackend.pool_block`` — no extra copy for passthrough
  codecs, one small staging transpose for per-page-quantized stacks).
- ``qship``  (beyond-paper, TPU-native): ship the QUERY to the creditor,
  which computes partial flash attention over the chunks it hosts and ships
  back (acc, lse). Traffic O(q + out): cheaper whenever >= 2 chunks are
  remote under GQA, and one round-trip instead of n_remote transfers.

ALL wire movement goes through the pluggable transport
(``core.transport``): this module contains no raw collective calls. Every
function takes and returns the ``CollectiveLedger`` — per-category wire
bytes, charged from the actual shipped arrays (quantized codec compression
shows up automatically) and gated by the consumption predicate the §3.4
analytic model prices (a lockstep transfer whose payload is never read does
not count).

KV bytes live in the page store (``repro.kvstore``): slot tables resolve to
page handles through ``plan.slot_pages``, and with a quantized ``kv_dtype``
the spill AND fetch wires carry the encoded payload + per-head scales — the
creditor scatters raw pages under ITS page table (reallocation is handle
movement, and reallocation traffic shrinks by the codec's factor). With a
passthrough codec the legacy ``spill_dtype="int8"`` wire-only compression is
preserved bit-for-bit (quantize on the wire, dequantize into the pool).

All attention math inside both paths routes through the pluggable backend
(``core.attention``), so fetch/qship work identically under jnp and pallas.
The caller passes the plan's POOL backend (``plan.pool_backend``) — remote
partials are pool-sourced, so they follow the pool knob, not the self-block
one; under pallas the creditor-side qship scan is the batched slot-grid
kernel (one launch over ``host_slots_used``). The functions take the
per-trace stage context (``core.stagestep.StageCtx``) duck-typed to keep
this layer import-light.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.attention import (AttentionBackend, State, attn_combine,
                                  attn_init, pool_scan)
from repro.core import transport as tx
from repro.core.transport import Ledger
from repro.kvstore import pages as kvpages
from repro.kvstore import quant as kvquant
from repro.obs import telemetry as obs_t
from repro.obs.telemetry import StageTelemetry


def _rep(ctx) -> int:
    """Telemetry count replication under the manual TP lowering."""
    return ctx.mtp.tp if ctx.mtp is not None else 1


def pair_phase(ctx) -> jax.Array:
    """The chunk index my PAIR stage is computing this tick."""
    n2 = ctx.plan.pair_shift
    return jnp.where(ctx.first_half, ctx.phase - n2, ctx.phase + n2)


def spill_permute(ctx, kv: jax.Array, led: Ledger = None, *,
                  active=None):
    """Cross-half spill transfer for a PASSTHROUGH pool. int8 spill_dtype:
    the WIRE carries the int8 payload + one fp32 scale per (tensor, layer,
    kv head) — half the spill bytes; the pool stays in model dtype
    (dequantized at the creditor)."""
    plan, tr = ctx.plan, ctx.transport
    if plan.spill_dtype != "int8":
        return tr.pair_shift(kv, ctx.topo.stage_axis, ctx.pair_perm, led,
                             tag="spill", active=active)
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=(-3, -1), keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale), -127, 127)
    q8, led = tr.pair_shift(q.astype(jnp.int8), ctx.topo.stage_axis,
                            ctx.pair_perm, led, tag="spill", active=active)
    s, led = tr.pair_shift(scale, ctx.topo.stage_axis, ctx.pair_perm, led,
                           tag="spill", active=active)
    return (q8.astype(jnp.float32) * s).astype(kv.dtype), led


def host_table(ctx) -> jax.Array:
    """chunk -> host slot table for MY half of the pairing."""
    plan = ctx.plan
    return jnp.where(ctx.first_half,
                     jnp.asarray(plan.host_slot_a),
                     jnp.asarray(plan.host_slot_b))


def _pool_layer(pool: kvpages.PagedPool, l_idx: jax.Array):
    """Slice one layer out of the paged pool: payloads [P, B, pt, K, D] +
    scales [P, B, 1, K, 1] (None when passthrough)."""
    sl = lambda a: jax.lax.dynamic_index_in_dim(a, l_idx, axis=1,
                                                keepdims=False)
    ks = sl(pool.k_scale) if pool.k_scale is not None else None
    vs = sl(pool.v_scale) if pool.v_scale is not None else None
    return sl(pool.k), sl(pool.v), ks, vs


def fetch_batched(ctx, backend: AttentionBackend) -> bool:
    """Resolve the batched-fetch knob against the pool backend: "auto"
    batches exactly when the backend fuses multi-slot stacks into one
    launch (``batched_pool``)."""
    fb = ctx.plan.fetch_batch
    return fb == "on" or (fb == "auto" and backend.batched_pool)


def fetch_remote(ctx, backend: AttentionBackend, qg, pool_l, st: State,
                 led: Ledger = None, tel: StageTelemetry = None):
    """Paper-faithful fetch wire: stream one chunk-layer per pairing permute.
    The slot *I* host for my pair at index j holds — after the symmetric
    cross-half exchange — my own chunk j. The wire carries the ENCODED pages
    (quantized codec: the fetch traffic shrinks by the same factor as the
    pool).

    Post-transfer attention order (``fetch_batched``): streamed = one
    online-softmax combine per landed chunk (the reference order, residency
    1 chunk-layer); batched = land every chunk-layer in a staging buffer and
    run ONE ``pool_block`` over the stack (a single slot-grid kernel launch
    under the pallas pool backend — the combine happens inside VMEM). The
    two orders agree to 1e-6 on float pages (``tests/test_transport.py``).
    """
    plan = ctx.plan
    host_tbl = host_table(ctx)
    slot_pages = jnp.asarray(plan.slot_pages)
    quantized = plan.codec.quantized
    js = jnp.arange(plan.p2, plan.num_chunks)

    def wire_one(led, tel, j):
        """Permute chunk j's encoded pages from the pair (ledger-charged
        iff the chunk is actually consumed this tick)."""
        pages = slot_pages[host_tbl[j]]
        kq, vq, ks, vs = kvpages.gather_chunk(*pool_l, pages)
        active = (j < ctx.phase) & (ctx.phase < plan.num_chunks)
        pk, led = ctx.transport.pair_shift(
            jnp.stack([kq, vq]), ctx.topo.stage_axis, ctx.pair_perm, led,
            tag="fetch", active=active)
        if quantized:
            ps, led = ctx.transport.pair_shift(
                jnp.stack([ks, vs]), ctx.topo.stage_axis, ctx.pair_perm, led,
                tag="fetch", active=active)
            ks, vs = ps[0], ps[1]
        # one telemetry event per CONSUMED chunk-layer (same gate as the
        # ledger — wire bytes = events x per_event_wire_bytes["fetch"])
        tel = obs_t.charge(tel, "fetch_events", 1.0, active, _rep(ctx))
        return (pk[0], pk[1], ks, vs), led, tel

    if fetch_batched(ctx, backend):
        def land(carry, j):
            led, tel = carry
            (kq, vq, ks, vs), led, tel = wire_one(led, tel, j)
            ys = (kq, vq, ks, vs) if quantized else (kq, vq)
            return (led, tel), ys

        (led, tel), landed = jax.lax.scan(land, (led, tel), js)
        if quantized:
            kqs, vqs, kss, vss = landed
        else:
            (kqs, vqs), kss, vss = landed, None, None
        valid = js < ctx.phase
        st = backend.pool_block(qg, kqs, vqs, kss, vss, valid, ctx.scale, st)
        tel = obs_t.charge(tel, "launches", 1.0, None, _rep(ctx))
        return st, led, tel

    def fetch_body(carry, j):
        stc, led, tel = carry
        (kq, vq, ks, vs), led, tel = wire_one(led, tel, j)
        stc = backend.chunk_block_q(qg, kq, vq, ks, vs, j < ctx.phase,
                                    ctx.scale, stc)
        tel = obs_t.charge(tel, "launches", 1.0, None, _rep(ctx))
        return (stc, led, tel), None

    (st, led, tel), _ = jax.lax.scan(fetch_body, (st, led, tel), js)
    return st, led, tel


def qship_remote(ctx, backend: AttentionBackend, qg, pool_l, st: State,
                 led: Ledger = None, tel: StageTelemetry = None):
    """Beyond-paper qship: ship my Q to the creditor, which runs the backend
    over ONLY the host slots it holds for me, then ships back (m, l, acc).
    With a ``batched_pool`` backend the creditor-side scan is ONE slot-grid
    kernel launch over the host-slot subset (``pool_scan`` handles both)."""
    plan, tr = ctx.plan, ctx.transport
    b, c, kvh, g, d = qg.shape
    sd = jnp.dtype(plan.ship_dtype)
    # useful iff I actually have a remote prefix this tick (phase > p2)
    active = (ctx.phase > plan.p2) & (ctx.phase < plan.num_chunks)
    q_pair, led = tr.pair_shift(qg.astype(sd), ctx.topo.stage_axis,
                                ctx.pair_perm, led, tag="qship_q",
                                active=active)
    q_pair = q_pair.astype(qg.dtype)
    host_chunk = jnp.where(ctx.first_half,
                           jnp.asarray(plan.slot_host_chunk_a),
                           jnp.asarray(plan.slot_host_chunk_b))
    pair_limit = pair_phase(ctx)  # pair needs chunks [p2, pair_phase)
    st_r = attn_init(b, c, kvh, g, d)
    # creditor-side scan visits ONLY the host slots (compute win)
    st_r = pool_scan(backend, q_pair, pool_l, plan.slot_pages, host_chunk,
                     pair_limit, ctx.scale, st_r,
                     slots=plan.host_slots_used)
    # ship (m, l) packed fp32 + acc in the wire dtype
    ml, led = tr.pair_shift(jnp.stack([st_r[0], st_r[1]]),
                            ctx.topo.stage_axis, ctx.pair_perm, led,
                            tag="qship_state", active=active)
    a_r, led = tr.pair_shift(st_r[2].astype(sd), ctx.topo.stage_axis,
                             ctx.pair_perm, led, tag="qship_state",
                             active=active)
    # one event per useful round-trip; launches = the creditor-side scan
    tel = obs_t.charge(tel, "qship_events", 1.0, active, _rep(ctx))
    tel = obs_t.charge(tel, "launches",
                       1.0 if backend.batched_pool
                       else float(len(plan.host_slots_used)),
                       None, _rep(ctx))
    return attn_combine(st, (ml[0], ml[1], a_r.astype(jnp.float32))), led, tel


def write_pools(ctx, pool: kvpages.PagedPool, stage_k, stage_v,
                led: Ledger = None, tel: StageTelemetry = None):
    """End-of-tick page writes: encode the fresh chunk once, scatter its
    pages to the own slot (phase < p2) or ship the payload cross-half and
    scatter under the creditor's page table. Inactive phases write to the
    scratch slot's pages (write-garbage land, never read).

    With the prefix path armed (``ctx.prefix_chunks = k > 0``) the first
    ``k`` phases ALSO redirect to scratch: the pool was seeded with the
    cached prefix KV (``kvstore.prefix.DeviceSeedCache``), so the fresh
    recompute of a hit chunk must not clobber the authoritative pages —
    copy-on-write at the device. Each redirected store charges the
    ``prefix_hit`` saved-bytes category (ledger: the chunk's stored bytes;
    telemetry: one event), pinned against ``obs.telemetry.
    prefix_saved_model``. ``k`` is STATIC: the disarmed program is
    byte-identical to pre-prefix builds."""
    plan = ctx.plan
    codec = plan.codec
    slot_pages = jnp.asarray(plan.slot_pages)
    phase, active = ctx.phase, (ctx.phase >= 0) & (ctx.phase < plan.num_chunks)
    pidx = jnp.clip(phase, 0, plan.num_chunks - 1)

    own_tbl = jnp.asarray(plan.own_slot)
    own_slot = jnp.where(active & (phase < plan.p2), own_tbl[pidx], plan.scratch)
    if ctx.prefix_chunks > 0:
        hit = active & (phase < ctx.prefix_chunks)
        own_slot = jnp.where(hit, plan.scratch, own_slot)
        lps, b, c, kvh, hd = stage_k.shape
        led = tx.charge(led, "prefix_hit",
                        obs_t.chunk_stored_bytes(plan, lps, b, c, kvh, hd),
                        hit)
        tel = obs_t.charge(tel, "prefix_hit", 1.0, hit, _rep(ctx))
    kq, ksc = kvquant.encode(codec, stage_k, pages=plan.pages_per_chunk)
    vq, vsc = kvquant.encode(codec, stage_v, pages=plan.pages_per_chunk)
    pool = kvpages.scatter_chunk_raw(pool, slot_pages[own_slot],
                                     kq, vq, ksc, vsc)

    if plan.p2 < plan.num_chunks and plan.mode == "mocap":
        pp = pair_phase(ctx)  # the chunk index my pair just computed
        host_tbl = host_table(ctx)
        ppc = jnp.clip(pp, 0, plan.num_chunks - 1)
        hslot = jnp.where((pp >= plan.p2) & (pp < plan.num_chunks),
                          host_tbl[ppc], plan.scratch)
        # I ship MY chunk; it is useful iff MY phase needs hosting
        ship_active = (phase >= plan.p2) & (phase < plan.num_chunks)
        tel = obs_t.charge(tel, "spill_events", 1.0, ship_active, _rep(ctx))
        if codec.quantized:
            # the wire carries the already-encoded pages + scales
            sq, led = ctx.transport.pair_shift(
                jnp.stack([kq, vq]), ctx.topo.stage_axis, ctx.pair_perm,
                led, tag="spill", active=ship_active)
            ss, led = ctx.transport.pair_shift(
                jnp.stack([ksc, vsc]), ctx.topo.stage_axis, ctx.pair_perm,
                led, tag="spill", active=ship_active)
            pool = kvpages.scatter_chunk_raw(pool, slot_pages[hslot],
                                             sq[0], sq[1], ss[0], ss[1])
        else:
            spill, led = spill_permute(ctx, jnp.stack([stage_k, stage_v]),
                                       led, active=ship_active)
            pool = kvpages.scatter_chunk_raw(pool, slot_pages[hslot],
                                             spill[0].astype(pool.k.dtype),
                                             spill[1].astype(pool.v.dtype),
                                             None, None)
    return pool, led, tel
