"""Latency-Balanced Chunk Partitioning (LBCP), §4.2 / Alg. 1.

Stage 1: dynamic programming over quantized chunk boundaries minimizing the
pipeline-makespan proxy  t_sum + (N-1) * t_max  using the deterministic
compute cost only (EVALUATECHUNK).

Stage 2: simulated annealing refinement under the FULL MBKR-enabled execution
model (EVALUATEPREFILL -> feasible batch + prefill latency; EVALUATEE2E), one
boundary perturbed per iteration, temperature-controlled acceptance.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core import mbkr as mb


@dataclass
class PartitionPlan:
    chunks: List[int]            # token counts, sum == S
    quantum: int
    t_prefill: float             # seconds (analytic, MBKR-enabled model)
    t_e2e: float
    throughput: float
    batch: int
    dp_objective: float          # stage-1 proxy value
    sa_iters: int = 0
    sa_accepted: int = 0
    mbkr_plan: Optional[mb.MBKRPlan] = None

    @property
    def num_chunks(self) -> int:
        return len(self.chunks)


def uniform_partition(seq_len: int, num_chunks: int) -> List[int]:
    base = seq_len // num_chunks
    rem = seq_len % num_chunks
    return [base + (1 if i < rem else 0) for i in range(num_chunks)]


# ------------------------------------------------------------------ stage 1

def dp_partition(
    s_quanta: int,
    num_chunks: int,
    num_stages: int,
    eval_chunk_vec: Callable[[np.ndarray, int], np.ndarray],
    *,
    objective_only: bool = False,
) -> Tuple[List[int], float]:
    """Alg. 1 lines 1-7 over quantized positions.

    ``eval_chunk_vec(k_array, s)`` -> compute seconds for chunks of k quanta
    starting at quantum position s (prefix = s quanta).

    Returns (chunk sizes in quanta, proxy objective).
    """
    m_tot, sq, n = num_chunks, s_quanta, num_stages
    inf = float("inf")
    # suffix DP: t_max[m][s], t_sum[m][s] = best over partitions of [s..S) into
    # chunks m..M. m+1 row is the previously computed row.
    t_max = np.full((m_tot + 2, sq + 1), inf)
    t_sum = np.full((m_tot + 2, sq + 1), inf)
    t_max[m_tot + 1][sq] = 0.0
    t_sum[m_tot + 1][sq] = 0.0
    ss = np.zeros((m_tot + 1, sq + 1), np.int32)
    for m in range(m_tot, 0, -1):
        chunks_left = m_tot - m  # chunks after this one
        for s in range(sq - 1, -1, -1):
            kmax = sq - s - chunks_left
            if kmax < 1:
                continue
            ks = np.arange(1, kmax + 1)
            t = eval_chunk_vec(ks, s)
            nxt_max = t_max[m + 1][s + ks]
            nxt_sum = t_sum[m + 1][s + ks]
            cand_max = np.maximum(nxt_max, t)
            cand_sum = nxt_sum + t
            obj = cand_sum + (n - 1) * cand_max
            feasible = np.isfinite(obj)
            if not feasible.any():
                continue
            best = int(np.nanargmin(np.where(feasible, obj, inf)))
            t_max[m][s] = cand_max[best]
            t_sum[m][s] = cand_sum[best]
            ss[m][s] = int(ks[best])
    obj0 = t_sum[1][0] + (n - 1) * t_max[1][0]
    if not math.isfinite(obj0):
        raise ValueError(f"infeasible DP: S={s_quanta} quanta, M={num_chunks}")
    # reconstruct
    chunks, s = [], 0
    for m in range(1, m_tot + 1):
        k = int(ss[m][s])
        chunks.append(k)
        s += k
    assert s == sq, (chunks, sq)
    return chunks, float(obj0)


# ------------------------------------------------------------------ stage 2

def _evaluate_full(chunks_tokens: Sequence[int], sm: cm.StageModel,
                   num_stages: int, hw: cm.HardwareProfile,
                   mbkr_plan: Optional[mb.MBKRPlan], batch_cap: int,
                   compress: float = 1.0) -> Tuple[int, float, float, float]:
    """EVALUATEPREFILL + EVALUATEE2E: (B, T_prefill, T_e2e, throughput)."""
    res = cm.evaluate_prefill(chunks_tokens, sm, num_stages, hw,
                              mbkr_plan=mbkr_plan, compress=compress)
    # feasible batch: weights + KV slot pool must fit per-die HBM
    cfg = sm.cfg
    weights = cfg.param_count() * 2 / (num_stages * max(sm.tp, 1))
    cmax = max(chunks_tokens)
    slots = mbkr_plan.num_slots if mbkr_plan else len(chunks_tokens)
    pool = slots * cm.kv_chunk_bytes(sm, cmax) / max(sm.tp, 1)
    spare = hw.hbm_cap - weights - pool
    if spare < 0:
        return 0, math.inf, math.inf, 0.0
    batch = batch_cap
    lat, thr = cm.evaluate_e2e(batch, res.latency, chunks_tokens, sm, num_stages,
                               hw, mbkr_plan=mbkr_plan, compress=compress)
    return batch, res.latency, lat, thr


def plan_partition(
    cfg: ModelConfig,
    seq_len: int,
    num_chunks: int,
    num_stages: int,
    hw: cm.ProfileSpec = cm.WSC_PAPER,
    *,
    tp: int = 1,
    quantum: Optional[int] = None,
    mbkr: bool = True,
    compress: float = 1.0,
    sa_iters: int = 400,
    sa_rounds: int = 8,
    temp0: float = 0.1,
    alpha: float = 0.7,
    batch_cap: int = 8,
    seed: int = 0,
) -> PartitionPlan:
    """Full LBCP: DP init + SA refinement. Returns token-level chunk sizes.

    ``hw`` takes a ``HardwareProfile``, a registered profile name, or a path
    to a calibrated-profile JSON (``obs.calibrate.save_profile``) — the DP
    and SA then partition against MEASURED effective rates."""
    hw = cm.resolve_profile(hw)
    if quantum is None:
        quantum = max(seq_len // max(num_chunks * 16, 1), 1)
        quantum = min(quantum, max(seq_len // num_chunks, 1))
    sq = seq_len // quantum
    assert sq >= num_chunks, (seq_len, quantum, num_chunks)
    rem_tokens = seq_len - sq * quantum  # folded into the last chunk

    sm = cm.StageModel.build(cfg, num_stages, tp)
    mplan = mb.plan(num_chunks, num_stages) if mbkr else None

    def eval_chunk_vec(ks: np.ndarray, s: int) -> np.ndarray:
        c = ks.astype(np.float64) * quantum
        p = float(s * quantum)
        peak = sm.tp * hw.flops
        bw = sm.tp * hw.hbm_bw
        gemm = sm.layers * c * cm.layer_linear_flops_per_token(cfg) / (peak * hw.gemm_eff)
        if cfg.attn_free:
            afl = np.array([cm.attn_flops(cfg, int(ci), 0) for ci in c]) * sm.layers
            return gemm + afl / (peak * hw.attn_eff)
        hd = cfg.resolved_head_dim
        afl = sm.attn_layers * 4 * c * (p + (c + 1) / 2.0) * cfg.num_heads * hd
        abytes = sm.attn_layers * (p + c) * cm.kv_bytes_per_token_layer(cfg)
        attn = np.maximum(afl / (peak * hw.attn_eff), abytes / bw)
        return gemm + attn

    dp_chunks_q, dp_obj = dp_partition(sq, num_chunks, num_stages, eval_chunk_vec)

    def to_tokens(chunks_q: Sequence[int]) -> List[int]:
        out = [int(k) * quantum for k in chunks_q]
        out[-1] += rem_tokens
        return out

    rng = np.random.default_rng(seed)
    cur = list(dp_chunks_q)
    _, tpre, te2e, thr = _evaluate_full(to_tokens(cur), sm, num_stages, hw,
                                        mplan, batch_cap, compress)
    cur_score = te2e
    best, best_score, best_stats = list(cur), cur_score, (tpre, te2e, thr)
    temp = temp0 * max(cur_score, 1e-9)
    accepted = total = 0
    temp_min = temp0 * max(cur_score, 1e-9) * (alpha ** sa_rounds)
    while temp > temp_min:
        for _ in range(sa_iters // max(sa_rounds, 1)):
            total += 1
            nxt = list(cur)
            # perturb one boundary, preserving S and M (Alg. 1 line 10)
            i = int(rng.integers(0, num_chunks - 1)) if num_chunks > 1 else 0
            delta = int(rng.integers(1, 3)) * (1 if rng.random() < 0.5 else -1)
            if num_chunks == 1:
                continue
            if nxt[i] + delta < 1 or nxt[i + 1] - delta < 1:
                continue
            nxt[i] += delta
            nxt[i + 1] -= delta
            _, tpre_n, te2e_n, thr_n = _evaluate_full(
                to_tokens(nxt), sm, num_stages, hw, mplan, batch_cap, compress)
            if te2e_n < cur_score or rng.random() < math.exp(
                    -(te2e_n - cur_score) / max(temp, 1e-12)):
                cur, cur_score = nxt, te2e_n
                accepted += 1
                if te2e_n < best_score:
                    best, best_score = list(nxt), te2e_n
                    best_stats = (tpre_n, te2e_n, thr_n)
        temp *= alpha

    tpre, te2e, thr = best_stats
    return PartitionPlan(
        chunks=to_tokens(best), quantum=quantum, t_prefill=tpre, t_e2e=te2e,
        throughput=thr, batch=batch_cap, dp_objective=dp_obj,
        sa_iters=total, sa_accepted=accepted, mbkr_plan=mplan)
