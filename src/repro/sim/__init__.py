from repro.sim.engine import (SimConfig, SimResult, max_seq_len,
                              schedule_request, simulate)
