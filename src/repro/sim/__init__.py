from repro.sim.engine import SimConfig, SimResult, simulate, max_seq_len
