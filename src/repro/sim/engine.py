"""Event-driven wafer-scale-chip pipeline simulator (paper §5: custom
event-driven simulator; we rebuild it on the shared analytic cost model in
``core.costmodel`` so LBCP's EvaluatePrefill and the simulator agree).

Three schedulers:

- ``gpipe``    microbatch pipeline (Fig. 2(a)): one task per (request, stage),
               full-sequence compute; KV retained until the request exits the
               pipeline (the standard-engine baseline — this is what OOMs
               first, the red crosses of Fig. 6(a)).
- ``terapipe`` chunked pipeline, uniform chunks, no reallocation: per-stage
               KV peaks at M chunks (one full request per stage).
- ``mocap``    chunked pipeline + MBKR spill/fetch/serve traffic + optional
               LBCP partitioning; per-stage KV peaks at the slot-plan's
               ``peak`` (< M), extending the feasible sequence length.

Memory is tracked as timestamped alloc/free events; feasibility = peak
occupancy <= per-stage capacity (weights subtracted). The makespan machinery
is a deterministic list-scheduling pass over task dependency + stage/link
FIFOs — faithful to the paper's in-order chunk execution.
"""
from __future__ import annotations

import math
from bisect import insort
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core import lbcp
from repro.core import mbkr


@dataclass(frozen=True)
class SimConfig:
    scheduler: str                 # gpipe | terapipe | mocap
    model: ModelConfig
    hw: cm.HardwareProfile = cm.WSC_PAPER
    num_stages: int = 16
    num_chunks: int = 16
    batch: int = 8                 # closed-loop back-to-back requests
    seq_len: int = 65536
    partition: str = "uniform"     # uniform | lbcp   (mocap only)
    mbkr: bool = True              # mocap only
    compress: float = 1.0          # spill-byte multiplier (int8 -> 0.5)
    sa_iters: int = 120            # LBCP refinement budget
    # lockstep  = tick-synchronous stages (the paper's Fig. 5 analysis and our
    #             SPMD executable pipeline — barrier per chunk tick)
    # eventdriven = free-running stages (MIMD WSC dies). KEY FINDING: with
    #             uniform chunks the steady-state stage offset is
    #             max_i(dur_i)+comm, which COLLAPSES the cross-half phase
    #             stagger MBKR needs — LBCP's balancing is what restores it.
    execution: str = "lockstep"


@dataclass
class SimResult:
    feasible: bool
    makespan: float = math.inf
    e2e_latency: float = math.inf   # avg request arrival->completion (s)
    throughput: float = 0.0         # req/s
    peak_mem: float = 0.0           # bytes, worst stage (KV only)
    capacity: float = 0.0           # bytes available for KV per stage
    stage_busy: Optional[np.ndarray] = None
    link_bytes: float = 0.0         # total reallocation traffic
    chunks: Optional[List[int]] = None
    detail: str = ""


# ------------------------------------------------------------- memory track

class _MemTrack:
    """Per-stage timestamped alloc/free; post-hoc peak."""

    def __init__(self, num_stages: int):
        self.events: List[List[Tuple[float, float]]] = [[] for _ in range(num_stages)]

    def alloc(self, stage: int, t: float, nbytes: float):
        self.events[stage].append((t, nbytes))

    def free(self, stage: int, t: float, nbytes: float):
        self.events[stage].append((t, -nbytes))

    def peaks(self) -> np.ndarray:
        out = np.zeros(len(self.events))
        for s, ev in enumerate(self.events):
            ev.sort(key=lambda e: (e[0], e[1]))  # frees before allocs at ties
            cur = peak = 0.0
            for _, d in ev:
                cur += d
                peak = max(peak, cur)
            out[s] = peak
        return out


# ---------------------------------------------------------- list scheduling

def schedule_request(
    task_cost: Sequence[float],
    comm: Sequence[float],
    num_stages: int,
    stage_free: np.ndarray,
    *,
    release: float = 0.0,
    stage_scale: Optional[Sequence[float]] = None,
    extra_of=None,
    on_task=None,
) -> np.ndarray:
    """Deterministic list-scheduling core: append ONE request's in-order chunk
    tasks to free-running per-stage FIFOs.

    Chunk i at stage s starts at max(stage s free, chunk i done at stage s-1
    plus the boundary transfer, chunk i-1 done at stage s, and ``release`` for
    the head task (0, 0) — the request's tokens are not available earlier).

    ``stage_free`` is MUTATED: calling this back-to-back for a stream of
    requests yields the continuously-pipelined (bubble-free across request
    boundaries) schedule; this is the shared core under the event-driven
    simulator branch, ``SimExecutor``, and ``sched.ChunkScheduler``.

    Optional hooks: ``stage_scale[s]`` multiplies stage s's task durations
    (straggler modeling); ``extra_of(s, t0)`` returns extra busy seconds due
    before the task (MBKR creditor serve obligations); ``on_task(i, s, t0,
    tf)`` observes each scheduled task (memory/traffic accounting, tracing).

    Returns ``finish[M][N]`` task completion times.
    """
    m = len(task_cost)
    finish = np.zeros((m, num_stages))
    for i in range(m):
        for s in range(num_stages):
            ready = release if (i == 0 and s == 0) else 0.0
            if s:
                ready = max(ready, finish[i][s - 1] + comm[i])
            if i:
                ready = max(ready, finish[i - 1][s])
            t0 = max(ready, float(stage_free[s]))
            extra = extra_of(s, t0) if extra_of is not None else 0.0
            d = float(task_cost[i]) + extra
            if stage_scale is not None:
                d *= float(stage_scale[s])
            tf = t0 + d
            finish[i][s] = tf
            stage_free[s] = tf
            if on_task is not None:
                on_task(i, s, t0, tf)
    return finish


# ------------------------------------------------------------------ engine

def _kv_capacity(cfg: ModelConfig, hw: cm.HardwareProfile, num_stages: int,
                 tp: int) -> float:
    weights = cfg.param_count() * 2 / (num_stages * tp)
    return max(hw.hbm_cap - weights, 0.0)


def simulate(sc: SimConfig) -> SimResult:
    cfg, hw = sc.model, sc.hw
    n = sc.num_stages
    tp = max(hw.num_dies // n, 1)
    sm = cm.StageModel.build(cfg, n, tp)
    cap = _kv_capacity(cfg, hw, n, tp) * tp  # stage = tp dies ganged
    if cap <= 0:
        return SimResult(False, detail="weights exceed HBM")

    if sc.scheduler == "gpipe":
        return _sim_gpipe(sc, sm, cap)
    return _sim_chunked(sc, sm, cap)


def _sim_gpipe(sc: SimConfig, sm: cm.StageModel, cap: float) -> SimResult:
    cfg, hw, n = sc.model, sc.hw, sc.num_stages
    s_len, b = sc.seq_len, sc.batch
    # one task per (request, stage): full-sequence compute
    dur = cm.chunk_compute_time(sm, s_len, 0, hw)
    comm = cm.boundary_comm_time(cfg, s_len, hw)
    kv = cm.kv_chunk_bytes(sm, s_len)          # stage KV of one request
    act = s_len * cfg.d_model * 2 * 2          # transient activations

    stage_free = np.zeros(n)
    finish = np.zeros((b, n))
    mem = _MemTrack(n)
    for r in range(b):
        for s in range(n):
            ready = finish[r][s - 1] + comm if s else (finish[r - 1][s] if r else 0.0)
            if s and r:
                ready = max(ready, finish[r - 1][s])
            t0 = max(ready, stage_free[s])
            finish[r][s] = t0 + dur
            stage_free[s] = finish[r][s]
            mem.alloc(s, t0, kv + act)
            mem.free(s, finish[r][s], act)     # activations are transient
    for r in range(b):
        for s in range(n):
            mem.free(s, finish[r][n - 1], kv)  # retained until request exits
    peaks = mem.peaks()
    mk = float(finish[-1][-1])
    e2e = float(np.mean(finish[:, -1]))
    feasible = bool(peaks.max() <= cap)
    return SimResult(feasible, mk, e2e, b / mk, float(peaks.max()), cap,
                     chunks=[s_len],
                     detail="" if feasible else
                     f"OOM: peak {peaks.max()/1e9:.1f} GB > cap {cap/1e9:.1f} GB")


def _sim_chunked(sc: SimConfig, sm: cm.StageModel, cap: float) -> SimResult:
    cfg, hw, n = sc.model, sc.hw, sc.num_stages
    m, b, s_len = sc.num_chunks, sc.batch, sc.seq_len
    is_mocap = sc.scheduler == "mocap"
    use_mbkr = is_mocap and sc.mbkr and not cfg.attn_free
    plan = mbkr.plan(m, n, mbkr=use_mbkr)
    p2 = plan.p2 if use_mbkr else m

    # ---- chunk partition
    if is_mocap and sc.partition == "lbcp":
        pp = lbcp.plan_partition(cfg, s_len, m, n, hw, tp=sm.tp,
                                 mbkr=use_mbkr, compress=sc.compress,
                                 sa_iters=sc.sa_iters, batch_cap=b)
        chunks = pp.chunks
    else:
        chunks = lbcp.uniform_partition(s_len, m)
    # ---- per-chunk costs (shared vectors; p2 == m when MBKR is off)
    dur, comm, kvb, spill_t, fetch_t = cm.chunk_cost_arrays(
        sm, chunks, hw, mbkr_plan=plan if use_mbkr else None,
        compress=sc.compress)

    mem = _MemTrack(n)
    link_bytes = 0.0
    pair = [mbkr.pair_of(s, n) for s in range(n)]
    finish = np.zeros((b, m, n))

    if sc.execution == "lockstep":
        # tick-synchronous: tick t runs (r, i) on stage s where
        # t = r*m + i + s; tick duration = max active task cost (+ transfer).
        n_ticks = b * m + n - 1
        serve = np.zeros(m)
        if p2 < m:
            for i in range(m):
                pp = (i + m - n // 2) % m  # pair's phase at my phase i
                serve[i] = 0.5 * (spill_t[pp] + fetch_t[pp])
        task_cost = dur + fetch_t + spill_t + serve
        now = 0.0
        for t in range(n_ticks):
            lo = max(0, t - (b * m - 1))
            hi = min(n - 1, t)
            phases = (t - np.arange(lo, hi + 1)) % m
            tick = float((task_cost[phases]).max() + comm[phases].max())
            t_end = now + tick
            for s in range(lo, hi + 1):
                gi = t - s
                r, i = gi // m, gi % m
                finish[r][i][s] = t_end
                if i >= p2:
                    link_bytes += kvb[i] * sc.compress
                if i > p2:
                    link_bytes += kvb[p2:i].sum() * sc.compress
                if i < p2:
                    mem.alloc(s, t_end, kvb[i])
                else:
                    mem.alloc(pair[s], t_end, kvb[i] * sc.compress)
                if i == m - 1:
                    mem.free(s, t_end, kvb[:p2].sum())
                    if p2 < m:
                        mem.free(pair[s], t_end, kvb[p2:].sum() * sc.compress)
            now = t_end
    else:
        stage_free = np.zeros(n)
        serve_due = [[] for _ in range(n)]  # (time, extra busy) on creditor
        task_cost = dur + fetch_t + spill_t
        acct = {"link": 0.0}

        def extra_of(s: int, t0: float) -> float:
            # creditor serve obligations accrued before this task
            extra = 0.0
            due = serve_due[s]
            while due and due[0][0] <= t0:
                extra += due.pop(0)[1]
            return extra

        def on_task(i: int, s: int, t0: float, tf: float) -> None:
            # memory: local store below p2, else spill to pair
            # (creditor memory is RESERVED at spill initiation)
            if i < p2:
                mem.alloc(s, tf, kvb[i])
            else:
                mem.alloc(pair[s], tf, kvb[i] * sc.compress)
                acct["link"] += kvb[i] * sc.compress
                insort(serve_due[pair[s]], (tf, spill_t[i] * 0.5))
            if fetch_t[i] > 0:
                acct["link"] += kvb[p2:i].sum() * sc.compress
                insort(serve_due[pair[s]], (t0, fetch_t[i] * 0.5))

        for r in range(b):
            finish[r] = schedule_request(task_cost, comm, n, stage_free,
                                         extra_of=extra_of, on_task=on_task)
            # request r's stage-KV frees once its LAST chunk clears stage s
            for s in range(n):
                t_done = finish[r][m - 1][s]
                mem.free(s, t_done, kvb[:p2].sum())
                if p2 < m:
                    mem.free(pair[s], t_done, kvb[p2:].sum() * sc.compress)
        link_bytes = acct["link"]

    peaks = mem.peaks()
    mk = float(finish[-1][-1][-1])
    e2e = float(np.mean(finish[:, m - 1, n - 1]))
    feasible = bool(peaks.max() <= cap)
    busy = np.zeros(n)
    for s in range(n):
        busy[s] = dur.sum() * b / mk
    return SimResult(feasible, mk, e2e, b / mk, float(peaks.max()), cap,
                     stage_busy=busy, link_bytes=link_bytes, chunks=list(chunks),
                     detail="" if feasible else
                     f"OOM: peak {peaks.max()/1e9:.1f} GB > cap {cap/1e9:.1f} GB")


# -------------------------------------------------------------- max seq len

def max_seq_len(sc: SimConfig, *, lo: int = 4096, hi: int = 16 << 20,
                quantum: int = 4096) -> int:
    """Largest feasible sequence length (bisection over the simulator)."""

    def ok(s_len: int) -> bool:
        if s_len < sc.num_chunks:
            return True
        return simulate(replace(sc, seq_len=s_len)).feasible

    if not ok(lo):
        return 0
    while ok(hi):
        hi *= 2
        if hi > (1 << 31):
            return hi
    while hi - lo > quantum:
        mid = (lo + hi) // 2 // quantum * quantum
        if mid <= lo:
            break
        if ok(mid):
            lo = mid
        else:
            hi = mid
    return lo
