"""Paged, quantized, tiered KV-cache store (DESIGN.md §6).

MOCAP orchestrates KV *slots* (``core.mbkr``) and *leases*
(``sched.kvlease``); this package owns the KV *bytes* behind both:

- ``pages``  — fixed-size KV pages per stage with a device-resident page
               table; MBKR slot tables index pages instead of whole-chunk
               arrays, so creditor/debtor reallocation is page-handle
               movement.
- ``quant``  — the page codec: int8 (per-kv-head scale) and fp8-emulated
               encode on write, dequant-on-read fused into the attention
               backends (``RunConfig.kv_dtype``).
- ``tiers``  — hot (stage-local) / warm (MBKR pair-hosted) / cold (host
               offload) placement with analytic prefetch scheduled off the
               LBCP chunk plan.
- ``prefix`` — cross-request prefix reuse: a refcounted radix index keyed
               by chained chunk-content hash with copy-on-write on
               divergence, so an admitted request leases only its novel
               suffix (DESIGN.md §11).
"""
from repro.kvstore.pages import (PageGeometry, PagedPool, alloc_pool,
                                 build_slot_pages, gather_chunk, page_geometry,
                                 pool_bytes, scatter_chunk, verify_page_plan)
from repro.kvstore.prefix import (DeviceSeedCache, PrefixLease,
                                  PrefixPageCache, chunk_hashes,
                                  verify_prefix_index)
from repro.kvstore.quant import (KVCodec, decode, encode, get_codec,
                                 kv_compress_factor, list_codecs)
from repro.kvstore.tiers import (HostOffloadStager, PrefetchOp, TierPlan,
                                 TierSpec, max_seq_len_for_budget, plan_tiers)
