"""Hot / warm / cold KV page tiers with analytic prefetch.

MOCAP's pool-scan re-reads every resident prefix chunk at every tick, so a
page can only leave stage-local HBM if the byte stream that brings it back
fits under the tick it is due in (capacity-tier prefetching, cf. the
Packing-Prefetch Scheduler line of work in PAPERS.md). Three tiers:

- HOT   stage-local HBM pages (own slots below the MBKR spill threshold);
- WARM  MBKR pair-hosted pages (chunks >= p2 — the slot plan already moves
        these off-stage; they are re-read over the D2D fabric by
        fetch/qship, so they never count against the local budget);
- COLD  host-offloaded pages staged back by ``jax.device_put``. Placement
        is chosen so every cold page's H2D stream lands BEFORE the
        pool-scan tick that reads it, using the LBCP chunk plan's per-tick
        compute times as the overlap window.

``plan_tiers`` is analytic (same fidelity as ``core.costmodel``): it
classifies pages, emits the prefetch schedule, and reports feasibility.
``HostOffloadStager`` does the real ``device_put`` staging at wave
granularity for the serving path (``serve --kv-offload``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kvstore import quant as Q
from repro.kvstore.pages import PageGeometry

HOT, WARM, COLD = 0, 1, 2
TIER_NAMES = ("hot", "warm", "cold")


@dataclass(frozen=True)
class TierSpec:
    """Per-stage byte budgets. ``cold_bw`` is the host<->device staging
    bandwidth (bytes/s); 0 disables the cold tier."""
    hot_bytes: float
    warm_bytes: float = math.inf   # pair-side hosting is the pair's problem
    cold_bw: float = 0.0


@dataclass(frozen=True)
class PrefetchOp:
    """One chunk's cold pages must be on-device before ``due_tick``'s pool
    scan; the H2D stream is issued under the previous tick's compute."""
    chunk: int
    pages: Tuple[int, ...]
    due_tick: int
    issue_tick: int
    nbytes: float


@dataclass
class TierPlan:
    tier_of: np.ndarray            # [num_pages] int8 (HOT/WARM/COLD)
    prefetch: List[PrefetchOp]
    feasible: bool                 # every prefetch fits its overlap window
    hot_bytes: float
    warm_bytes: float
    cold_bytes: float
    worst_tick_bw: float           # peak H2D demand (bytes/s) over ticks

    def summary(self) -> Dict[str, float]:
        counts = {TIER_NAMES[t]: int((self.tier_of == t).sum())
                  for t in (HOT, WARM, COLD)}
        return {"pages": counts, "hot_bytes": self.hot_bytes,
                "warm_bytes": self.warm_bytes, "cold_bytes": self.cold_bytes,
                "prefetch_ops": len(self.prefetch),
                "worst_tick_bw": self.worst_tick_bw,
                "feasible": self.feasible}


def chunk_page_bytes(geom: PageGeometry, codec: Q.KVCodec, lps: int, b: int,
                     kvh: int, hd: int) -> float:
    """Stored bytes of ONE chunk's pages (k + v + scales)."""
    payload = 2.0 * lps * b * geom.chunk_len * kvh * hd * codec.bytes_per_el
    scales = 2.0 * geom.pages_per_chunk * codec.scale_bytes_per_page(
        lps, b, kvh)
    return payload + scales


def plan_tiers(geom: PageGeometry, codec: Q.KVCodec, slot_pages: np.ndarray,
               own_slot: np.ndarray, p2: int, num_chunks: int,
               spec: TierSpec, *, lps: int, b: int, kvh: int, hd: int,
               tick_s: Optional[Sequence[float]] = None,
               host_slots: Optional[Sequence[int]] = None) -> TierPlan:
    """Place every page of one stage's pool into a tier.

    ``own_slot``/``p2`` come from the MBKR plan: chunks < p2 are stage-local
    candidates (HOT, overflowing to COLD), chunks >= p2 are pair-hosted
    (WARM — symmetrically, THIS stage's host slots, passed as
    ``host_slots``, hold the pair's spill and are marked WARM locally).
    ``tick_s`` is the per-phase compute time vector (LBCP ``ChunkPlan.dur``);
    uniform 1s ticks when absent — feasibility then means "fits at 1
    chunk-compute-second of overlap per tick".

    Cold candidates are chosen LAST-written-first: chunk j's pages are
    re-read on ticks j+1..M-1, so the latest chunks cost the fewest
    re-streams and have the shortest residency.
    """
    m = num_chunks
    ticks = np.asarray(tick_s if tick_s is not None else np.ones(m), float)
    cb = chunk_page_bytes(geom, codec, lps, b, kvh, hd)
    tier_of = np.full(geom.num_pages, HOT, np.int8)

    # chunks >= p2 are hosted at the pair under ITS page table; my own host
    # slots hold the pair's spill — the local face of the WARM tier
    warm_bytes = max(m - p2, 0) * cb
    if host_slots is not None:
        for s in np.unique(np.asarray(host_slots, np.int64)):
            tier_of[slot_pages[int(s)]] = WARM
    # scratch pages are write-garbage targets; they never hold live bytes
    own_chunks = list(range(min(p2, m)))
    hot_used = 0.0
    cold_chunks: List[int] = []
    for j in own_chunks:                       # earliest = most re-read = hot
        if hot_used + cb <= spec.hot_bytes or spec.cold_bw <= 0:
            hot_used += cb
        else:
            cold_chunks.append(j)
    # keep the overflow choice "latest first": re-assign so the LAST chunks
    # go cold regardless of which iteration overflowed. Slots that ALSO do
    # host duty at other phases (the coloring shares the pool) must stay
    # on-device — their pages carry the pair's spill mid-cycle.
    host_set = (set(int(s) for s in np.asarray(host_slots).ravel())
                if host_slots is not None else set())
    eligible = [j for j in own_chunks if int(own_slot[j]) not in host_set]
    n_cold = min(len(cold_chunks), len(eligible))
    cold_chunks = eligible[len(eligible) - n_cold:] if n_cold else []
    for j in cold_chunks:
        s = int(own_slot[j])
        tier_of[slot_pages[s]] = COLD

    # prefetch schedule: chunk j's cold pages are due at every tick t > j,
    # streamed under tick t-1's compute (issue_tick) — so the bandwidth
    # check divides tick t's demand by the ISSUE window ticks[t-1]
    prefetch: List[PrefetchOp] = []
    demand = np.zeros(m)
    for t in range(1, m):
        for j in cold_chunks:
            if j < t:
                s = int(own_slot[j])
                prefetch.append(PrefetchOp(
                    chunk=j, pages=tuple(int(x) for x in slot_pages[s]),
                    due_tick=t, issue_tick=t - 1, nbytes=cb))
                demand[t] += cb
    window = np.concatenate([[np.inf], ticks[:-1]]) if m else ticks
    bw_need = demand / np.maximum(window, 1e-12)
    worst = float(bw_need.max()) if m else 0.0
    feasible = (not cold_chunks) or (spec.cold_bw > 0
                                     and worst <= spec.cold_bw * (1 + 1e-9))
    return TierPlan(tier_of, prefetch, feasible,
                    hot_bytes=hot_used, warm_bytes=warm_bytes,
                    cold_bytes=len(cold_chunks) * cb, worst_tick_bw=worst)


def max_seq_len_for_budget(budget_bytes: float, *, kv_token_bytes: float,
                           num_chunks: int, num_stages: int,
                           codec: Q.KVCodec, model_dtype: str = "bfloat16",
                           page_tokens: int = 0, head_dim: int = 0,
                           mbkr: bool = True) -> int:
    """Max feasible sequence length whose per-stage paged pool fits
    ``budget_bytes``. ``kv_token_bytes`` is one stage's KV bytes per token
    in the MODEL dtype (``cm.kv_chunk_bytes(sm, 1)``); the codec's
    compression factor (incl. scale overhead) rescales it. MBKR shrinks the
    pool from M chunk-slots to ``plan(M, N).num_slots`` — the two levers
    (slot orchestration x byte compression) multiply."""
    from repro.core import mbkr as mb
    m = num_chunks
    slots = mb.plan(m, num_stages, mbkr=mbkr).num_slots if mbkr else m
    factor = Q.kv_compress_factor(codec, model_dtype=model_dtype,
                                  page_tokens=page_tokens, head_dim=head_dim)
    per_chunk_token = kv_token_bytes * factor
    if per_chunk_token <= 0:
        return 0
    chunk_tokens = int(budget_bytes // (slots * per_chunk_token))
    if page_tokens > 1:
        chunk_tokens -= chunk_tokens % page_tokens
    return chunk_tokens * m


# ------------------------------------------------------------- cold staging

class HostOffloadStager:
    """Real cold-tier staging: page slices move host<->device with
    ``jax.device_put``. Wave-granular (between jit'd pipeline calls) — the
    in-pipeline per-tick stream is the analytic plan above; this object is
    what the serving path uses to park drained pools off-device."""

    def __init__(self):
        import jax
        self._jax = jax
        cpus = jax.devices("cpu")
        self._cpu = cpus[0] if cpus else None
        self._store: Dict[Tuple[str, int], object] = {}

    def offload(self, name: str, pages_array, page_ids: Sequence[int]):
        """Copy the given pages to host memory and zero them on device.
        Returns the device array with the offloaded pages cleared."""
        import jax.numpy as jnp
        ids = np.asarray(page_ids, np.int32)
        host = self._jax.device_put(pages_array[ids], self._cpu)
        self._store[(name, 0)] = (ids, self._jax.block_until_ready(host))
        return pages_array.at[ids].set(jnp.zeros_like(pages_array[ids]))

    def restore(self, name: str, pages_array):
        """Stage the offloaded pages back into the device array."""
        ids, host = self._store.pop((name, 0))
        back = self._jax.device_put(host, self._jax.devices()[0])
        return pages_array.at[ids].set(back)

    def host_bytes(self) -> float:
        return float(sum(np.asarray(h).nbytes
                         for _, h in self._store.values()))
