"""Quantized KV page codec: int8 and fp8 encode-on-write, dequant-on-read.

One codec per ``RunConfig.kv_dtype``:

- ``auto`` / a float dtype name — passthrough: pages store the model dtype,
  no scales (bit-identical to the pre-kvstore pool).
- ``int8``  — symmetric per-(layer, batch, kv-head) scale: amax over the
  token and head-dim axes, payload = round(kv / scale) clipped to ±127.
- ``fp8``   — fp8-e4m3 *emulated* encode: the same per-head scale maps amax
  to the e4m3 dynamic range, the payload is cast through
  ``jnp.float8_e4m3fn`` (ml_dtypes does the rounding off-TPU; on TPU the
  cast is native). One byte per element like int8, ~4x the relative error
  resolution near amax, no clipping cliff for outliers below amax.

Scales always travel WITH the payload (spill/fetch wires ship both), so a
quantized pool also halves MBKR reallocation traffic. Decode is a multiply:
``payload.astype(f32) * scale`` — cheap enough to fuse into the attention
backends (the Pallas kernel dequantizes in its epilogue; the jnp reference
dequantizes just before the block update).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

FP8_MAX = 448.0          # float8_e4m3fn finite max
INT8_MAX = 127.0


@dataclass(frozen=True)
class KVCodec:
    """How KV pages are stored. ``quantized`` implies a per-head fp32 scale
    array rides along with each page."""
    name: str
    storage_dtype: str      # payload dtype in the pool / on the wire
    bytes_per_el: float     # payload bytes per element
    quantized: bool

    def scale_bytes_per_page(self, lps: int, b: int, kvh: int) -> float:
        """fp32 scale entries per page (k + v handled per-tensor by caller)."""
        return 4.0 * lps * b * kvh if self.quantized else 0.0


_FLOAT_BYTES = {"float32": 4.0, "bfloat16": 2.0, "float16": 2.0}


def list_codecs() -> Tuple[str, ...]:
    return ("auto", "bfloat16", "float32", "int8", "fp8")


def get_codec(name: str, model_dtype: str = "bfloat16") -> KVCodec:
    """Resolve a ``kv_dtype`` knob value against the model dtype."""
    if name in ("auto", "", None):
        name = model_dtype
    if name in _FLOAT_BYTES:
        return KVCodec(name, name, _FLOAT_BYTES[name], quantized=False)
    if name == "int8":
        return KVCodec("int8", "int8", 1.0, quantized=True)
    if name == "fp8":
        return KVCodec("fp8", "float8_e4m3fn", 1.0, quantized=True)
    raise ValueError(f"unknown kv_dtype {name!r}; choose from {list_codecs()}")


def _amax_scale(kv: jax.Array, target: float) -> jax.Array:
    """Per-(.., kv-head) scale: amax over the token (-3) and head-dim (-1)
    axes of a [..., T, K, D] tensor, floored to avoid div-by-zero."""
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=(-3, -1),
                   keepdims=True)
    return jnp.maximum(amax, 1e-6) / target


def encode(codec: KVCodec, kv: jax.Array, pages: int = 1
           ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """kv [..., T, K, D] -> (payload in storage dtype, per-PAGE scales
    [pages, ..., 1, K, 1] fp32 or None).

    The token axis is split into ``pages`` blocks and each page gets its own
    per-kv-head scale (block-wise quantization: a page-local amax is tighter
    than a whole-chunk amax, which is what keeps the deep-pipeline p99 error
    inside the int8-spill tolerance)."""
    if not codec.quantized:
        return kv, None
    *lead, t, k, d = kv.shape
    paged = kv.reshape(*lead, pages, t // pages, k, d)
    paged = jnp.moveaxis(paged, -4, 0)          # [pages, ..., pt, K, D]
    if codec.name == "int8":
        scale = _amax_scale(paged, INT8_MAX)
        q = jnp.clip(jnp.round(paged.astype(jnp.float32) / scale),
                     -INT8_MAX, INT8_MAX).astype(jnp.int8)
    else:  # fp8: scale amax into the e4m3 range, the cast does the rounding
        scale = _amax_scale(paged, FP8_MAX)
        q = (paged.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    q = jnp.moveaxis(q, 0, -4).reshape(kv.shape)
    return q, scale


def expand_page_scale(scale: jax.Array, page_tokens: int) -> jax.Array:
    """[pages, ..., 1, K, 1] per-page scales -> [..., T, K, 1] per-token
    (T = pages * page_tokens), for decode / the kernel's dequant epilogue."""
    pages = scale.shape[0]
    s = jnp.moveaxis(scale, 0, -4)              # [..., pages, 1, K, 1]
    tgt = s.shape[:-4] + (pages, page_tokens) + s.shape[-2:]
    s = jnp.broadcast_to(s, tgt)
    return s.reshape(s.shape[:-4] + (pages * page_tokens,) + s.shape[-2:])


def decode(payload: jax.Array, scale: Optional[jax.Array],
           out_dtype=None) -> jax.Array:
    """Inverse of ``encode``; works for every codec (scale None = identity)."""
    if scale is None:
        return payload if out_dtype is None else payload.astype(out_dtype)
    out = payload.astype(jnp.float32) * scale
    return out if out_dtype is None else out.astype(out_dtype)


def kv_compress_factor(codec: KVCodec, *, model_dtype: str = "bfloat16",
                       page_tokens: int = 0, head_dim: int = 0) -> float:
    """Stored-bytes ratio vs the model-dtype pool (lease accounting uses
    this to count quantized bytes). Includes the per-head scale overhead
    when the page/head geometry is known: one fp32 per (page, head) against
    ``page_tokens * head_dim`` payload elements."""
    base = _FLOAT_BYTES.get(model_dtype, 2.0)
    f = codec.bytes_per_el / base
    if codec.quantized and page_tokens and head_dim:
        f += 4.0 / (page_tokens * head_dim * base)
    return f
