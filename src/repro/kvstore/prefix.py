"""Cross-request prefix KV reuse: a radix index over paged KV (DESIGN.md §11).

Production prefill traffic is dominated by shared system prompts and few-shot
prefixes.  The page table (``kvstore.pages``) already decouples MBKR slots
from physical storage, so sharing is an indexing + accounting layer:

- ``chunk_hashes``    — CHAINED content hashes per chunk: ``h[i]`` commits to
  every token of chunks ``0..i``, so a flat dict keyed by ``h[i]`` IS a radix
  trie — equal keys mean equal full prefixes, and the first miss walking the
  chain is the divergence point.
- ``PrefixPageCache`` — the index: one node per cached chunk holding its
  physical page handles and a refcount of live leases.  A request whose
  prefix is resident ACQUIRES the hit nodes (refcount++) and allocates fresh
  pages only for its novel suffix — copy-on-write at chunk granularity: a
  diverging request never writes a shared page, it gets new handles from the
  free list.  Refcount-0 nodes stay cached (that IS the cache) and are
  evicted leaf-first in LRU order under capacity pressure; a node with live
  readers or resident children is never evicted, and pages return to the
  free list only at eviction — never while refcount > 0.
- ``verify_prefix_index`` — the ``pages.verify_page_plan`` discipline
  extended to the shared store: node pages and the free list partition the
  allocated handle space, refcounts equal live-lease membership, and
  resident bytes equal the analytic node-count model.
- ``DeviceSeedCache`` — host-side per-request pool snapshots for the device
  path: ``prefill_pipeline(..., return_kv=True)`` yields the final paged
  pool; a later request with a matching prefix seeds its pool from the
  snapshot while ``prefix_chunks=k`` redirects its first ``k`` chunk writes
  to the scratch slot, so the cached pages stay authoritative.

Handles here are CACHE-LOCAL accounting handles (the scheduler's view of the
shared store), allocated from a free list disjoint from the device scratch
slot by construction — the device pool keeps its own table.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["chunk_hashes", "PrefixLease", "PrefixPageCache",
           "verify_prefix_index", "DeviceSeedCache"]


def chunk_hashes(tokens: Sequence[int], chunks) -> Tuple[int, ...]:
    """Chained per-chunk content hashes over a token stream.

    ``chunks`` is either a per-chunk length sequence (an LBCP split) or a
    single uniform chunk length.  Only chunks FULLY covered by the token
    stream are hashed — a partial trailing chunk can never be shared.
    ``h[i]`` commits to all tokens of chunks ``0..i`` (chained), so two
    requests agree on ``h[i]`` iff their first ``i+1`` chunks are identical
    under the same split.
    """
    toks = np.asarray(tokens).ravel()
    if np.ndim(chunks) == 0:
        cl = int(chunks)
        if cl <= 0:
            return ()
        lens = [cl] * (len(toks) // cl)
    else:
        lens = [int(c) for c in chunks]
    out: List[int] = []
    prev = b""
    start = 0
    for c in lens:
        if c <= 0 or start + c > len(toks):
            break
        h = hashlib.blake2b(digest_size=8)
        h.update(prev)
        h.update(np.ascontiguousarray(toks[start:start + c],
                                      dtype=np.int64).tobytes())
        prev = h.digest()
        out.append(int.from_bytes(prev, "big"))
        start += c
    return tuple(out)


@dataclass
class PrefixLease:
    """One request's hold on the index: the node chain it references (hit
    prefix + the novel suffix it inserted) and the pages it WROTE — shared
    pages are read-only to the holder (copy-on-write)."""
    rid: int
    chain: Tuple[int, ...]          # node keys, root-first
    hit_chunks: int                 # leading chunks served from the index
    hit_pages: int
    new_pages: Tuple[int, ...]      # pages this request allocated (wrote)
    released: bool = False


@dataclass
class _Node:
    key: int
    parent: Optional[int]
    depth: int                      # chunks from the root, 1-based
    pages: Tuple[int, ...]
    refs: int = 0                   # live leases referencing this node
    children: int = 0               # resident child nodes
    last_use: int = 0


class PrefixPageCache:
    """Refcounted radix page index keyed by chained chunk-content hash.

    ``pages_per_chunk`` and ``page_bytes`` fix the accounting geometry (one
    node = one chunk = ``ppc`` pages of ``page_bytes`` each).
    ``capacity_pages`` bounds residency: when allocation would exceed it and
    no refcount-0 leaf can be evicted, the novel tail of the request is
    simply not indexed (its lease charges full price regardless, so the
    budget math never depends on insertion succeeding).
    """

    def __init__(self, pages_per_chunk: int, page_bytes: float,
                 capacity_pages: Optional[int] = None):
        self.pages_per_chunk = int(pages_per_chunk)
        self.page_bytes = float(page_bytes)
        self.capacity_pages = capacity_pages
        self._nodes: Dict[int, _Node] = {}
        self._free: List[int] = []
        self._next_page = 0
        self._clock = 0
        self._live: Dict[int, PrefixLease] = {}
        self.requests = 0
        self.hits = 0
        self.misses = 0
        self.hit_chunks_total = 0
        self.hit_pages_total = 0
        self.saved_bytes = 0.0
        self.evictions = 0

    # ------------------------------------------------------------- queries

    def match(self, hashes: Sequence[int]) -> int:
        """Longest resident prefix, in chunks.  Pure — no refcount effects."""
        k = 0
        for h in hashes:
            if h not in self._nodes:
                break
            k += 1
        return k

    def hit_pages(self, hashes: Sequence[int]) -> int:
        return self.match(hashes) * self.pages_per_chunk

    def resident_pages(self) -> int:
        return len(self._nodes) * self.pages_per_chunk

    def resident_bytes(self) -> float:
        return self.resident_pages() * self.page_bytes

    def live_shared_bytes(self) -> float:
        """Refcount-weighted bytes the index serves to live leases: what the
        lease manager would have charged WITHOUT sharing, minus what it does
        charge, summed over holders of shared nodes."""
        return sum(l.hit_pages for l in self._live.values()) * self.page_bytes

    def stats(self) -> dict:
        n = max(self.requests, 1)
        return {"prefix_requests": self.requests, "prefix_hits": self.hits,
                "prefix_misses": self.misses,
                "prefix_hit_rate": self.hits / n,
                "prefix_hit_chunks": self.hit_chunks_total,
                "prefix_hit_pages": self.hit_pages_total,
                "prefix_saved_bytes": self.saved_bytes,
                "prefix_resident_bytes": self.resident_bytes(),
                "prefix_evictions": self.evictions}

    # ------------------------------------------------------------ lifecycle

    def acquire(self, rid: int, hashes: Sequence[int]) -> PrefixLease:
        """Reference the resident prefix (refcount++) and index the novel
        suffix under freshly allocated pages (copy-on-write: shared pages
        are never handed to a writer)."""
        self._clock += 1
        self.requests += 1
        hashes = tuple(hashes)
        hit = self.match(hashes)
        chain: List[int] = []
        new_pages: List[int] = []
        parent = None
        for i, h in enumerate(hashes):
            if i < hit:
                node = self._nodes[h]
                node.refs += 1
                node.last_use = self._clock
                chain.append(h)
            else:
                pages = self._alloc_chunk()
                if pages is None:
                    break               # capacity: stop indexing the tail
                node = _Node(key=h, parent=parent, depth=i + 1,
                             pages=pages, refs=1, last_use=self._clock)
                self._nodes[h] = node
                if parent is not None:
                    self._nodes[parent].children += 1
                chain.append(h)
                new_pages.extend(pages)
            parent = h
        hp = hit * self.pages_per_chunk
        if hit > 0:
            self.hits += 1
        else:
            self.misses += 1
        self.hit_chunks_total += hit
        self.hit_pages_total += hp
        self.saved_bytes += hp * self.page_bytes
        lease = PrefixLease(rid=rid, chain=tuple(chain), hit_chunks=hit,
                            hit_pages=hp, new_pages=tuple(new_pages))
        self._live[id(lease)] = lease
        return lease

    def release(self, lease: PrefixLease) -> None:
        """Drop the lease's references.  Nodes stay resident at refcount 0
        (cached for future hits) until evicted under pressure."""
        if lease.released:
            return
        lease.released = True
        self._live.pop(id(lease), None)
        for h in lease.chain:
            node = self._nodes.get(h)
            if node is not None and node.refs > 0:
                node.refs -= 1

    # ------------------------------------------------------------ internals

    def _alloc_chunk(self) -> Optional[Tuple[int, ...]]:
        ppc = self.pages_per_chunk
        if self.capacity_pages is not None:
            while (self.resident_pages() + ppc > self.capacity_pages
                   and self._evict_one()):
                pass
            if self.resident_pages() + ppc > self.capacity_pages:
                return None
        out = []
        for _ in range(ppc):
            if self._free:
                out.append(self._free.pop())
            else:
                out.append(self._next_page)
                self._next_page += 1
        return tuple(out)

    def _evict_one(self) -> bool:
        """Evict the LRU refcount-0 LEAF (no resident children): its pages
        go back on the free list.  Never touches a node with live readers."""
        victim = None
        for node in self._nodes.values():
            if node.refs == 0 and node.children == 0:
                if victim is None or node.last_use < victim.last_use:
                    victim = node
        if victim is None:
            return False
        del self._nodes[victim.key]
        if victim.parent is not None and victim.parent in self._nodes:
            self._nodes[victim.parent].children -= 1
        self._free.extend(victim.pages)
        self.evictions += 1
        return True


def verify_prefix_index(cache: PrefixPageCache) -> None:
    """``pages.verify_page_plan`` extended to the shared store.  Raises on:
    node pages + free list not partitioning the allocated handle space
    (double-grant / leak), refcounts diverging from live-lease membership,
    a live lease's WRITTEN pages overlapping another live lease's, stale
    child counts, or resident bytes off the node-count model."""
    owned: List[int] = []
    for node in cache._nodes.values():
        assert len(node.pages) == cache.pages_per_chunk, node
        assert node.refs >= 0 and node.children >= 0, node
        owned.extend(node.pages)
    all_handles = owned + list(cache._free)
    assert len(set(all_handles)) == len(all_handles), "page handle collision"
    assert len(all_handles) == cache._next_page, \
        (len(all_handles), cache._next_page)
    # refcounts == live-lease membership, per node
    refs: Dict[int, int] = {}
    writers: Dict[int, int] = {}
    for lease in cache._live.values():
        for h in lease.chain:
            refs[h] = refs.get(h, 0) + 1
        for p in lease.new_pages:
            assert p not in writers, \
                f"page {p} written by rids {writers[p]} and {lease.rid}"
            writers[p] = lease.rid
    for key, node in cache._nodes.items():
        assert node.refs == refs.get(key, 0), (key, node.refs, refs.get(key))
    # child counts match the resident parent->child edges
    kids: Dict[int, int] = {}
    for node in cache._nodes.values():
        if node.parent is not None and node.parent in cache._nodes:
            kids[node.parent] = kids.get(node.parent, 0) + 1
    for key, node in cache._nodes.items():
        assert node.children == kids.get(key, 0), (key, node.children)
    # analytic residency model
    model = len(cache._nodes) * cache.pages_per_chunk * cache.page_bytes
    assert abs(cache.resident_bytes() - model) <= 1e-9 * max(model, 1.0)


class DeviceSeedCache:
    """Host-side pool snapshots for the DEVICE prefix path (JaxExecutor).

    One entry per request: the request's batch element of the final paged
    pool (``return_kv=True``), stage-stacked, keyed by its full hash chain.
    ``lookup(chain, k)`` returns any snapshot agreeing on the first ``k``
    chunks — pages past ``k`` are garbage to the new request, which is safe
    because its own writes for phases ``>= prefix_chunks`` overwrite them
    in lockstep.  Bounded LRU: snapshots are whole-pool sized.
    """

    def __init__(self, max_entries: int = 8):
        self.max_entries = int(max_entries)
        self._snaps: "OrderedDict[Tuple[int, ...], dict]" = OrderedDict()
        self._by_prefix: Dict[Tuple[int, ...], Tuple[int, ...]] = {}

    def put(self, chain: Sequence[int], element: dict) -> None:
        key = tuple(chain)
        if not key:
            return
        self._snaps[key] = element
        self._snaps.move_to_end(key)
        while len(self._snaps) > self.max_entries:
            self._snaps.popitem(last=False)
        self._reindex()

    def match(self, chain: Sequence[int]) -> int:
        """Longest seedable prefix of ``chain``, in chunks."""
        chain = tuple(chain)
        k = 0
        while k < len(chain) and chain[:k + 1] in self._by_prefix:
            k += 1
        return k

    def lookup(self, chain: Sequence[int], k: int) -> Optional[dict]:
        key = self._by_prefix.get(tuple(chain)[:k])
        if key is None:
            return None
        self._snaps.move_to_end(key)
        return self._snaps[key]

    def _reindex(self) -> None:
        self._by_prefix = {}
        for key in self._snaps:
            for j in range(1, len(key) + 1):
                self._by_prefix.setdefault(key[:j], key)
