"""Page-table KV store: the byte layer under the MBKR slot plan.

The pre-kvstore pool stored whole-chunk KV arrays indexed directly by slot
id. Here the unit of storage is a fixed-size PAGE of ``page_tokens`` tokens;
a chunk occupies ``pages_per_chunk`` pages, and a device-resident page table
(``slot_pages [slots+1, ppc]``, a static numpy array that lowers to an HLO
constant) maps each MBKR slot to its physical page handles. Slot semantics —
which chunk lives in which slot at which phase — stay entirely in
``core.mbkr``; this module only owns where the bytes of a slot live, so
creditor/debtor reallocation is page-handle movement: the spill wire carries
encoded pages + scales and the creditor scatters them under ITS page table.

Pages are stored encoded (``kvstore.quant``): payload arrays in the codec's
storage dtype plus per-(page, layer, batch, kv-head) fp32 scales when
quantized — block-wise quantization at page granularity, so smaller pages
mean tighter amax windows and lower error.

Layouts (P = total physical pages incl. the scratch slot's):
    k_pages / v_pages   [P, lps, B, page_tokens, kvh, hd]   storage dtype
    k_scale / v_scale   [P, lps, B, 1, kvh, 1]              fp32 (quantized)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvstore import quant as Q


@dataclass(frozen=True)
class PageGeometry:
    """Static page layout of one stage's pool."""
    chunk_len: int
    page_tokens: int
    pages_per_chunk: int
    num_slots: int            # excl. scratch
    num_pages: int            # (num_slots + 1) * pages_per_chunk

    @property
    def scratch_slot(self) -> int:
        return self.num_slots


def page_geometry(chunk_len: int, num_slots: int,
                  kv_page_tokens: int = 0) -> PageGeometry:
    """``kv_page_tokens`` 0 (or >= chunk) means one page per chunk; otherwise
    it is rounded down to the largest divisor of ``chunk_len`` so chunks stay
    page-aligned (uniform chunks; LBCP buckets pad to the bucket)."""
    pt = kv_page_tokens if 0 < kv_page_tokens < chunk_len else chunk_len
    while chunk_len % pt:
        pt -= 1
    ppc = chunk_len // pt
    return PageGeometry(chunk_len, pt, ppc, num_slots,
                        (num_slots + 1) * ppc)


def build_slot_pages(geom: PageGeometry) -> np.ndarray:
    """slot -> physical page handles, [slots+1, ppc] int32.

    Pages of one slot are STRIDED across the physical array (handle =
    j * (slots+1) + slot) rather than contiguous, so nothing downstream can
    silently rely on slot-major contiguity — every read/write goes through
    the table, which is what makes reallocation pure handle movement."""
    s1 = geom.num_slots + 1
    tbl = np.empty((s1, geom.pages_per_chunk), np.int32)
    for s in range(s1):
        for j in range(geom.pages_per_chunk):
            tbl[s, j] = j * s1 + s
    return tbl


def handle_rows(slot_pages, slots=None):
    """Export the page-handle rows the PAGED pool kernel consumes as its
    scalar-prefetch argument (``kernels.ops.pool_attention_paged``): the
    [S, ppc] rows of the visited slots — all non-scratch slots, or the
    ``slots`` subset (creditor scan). Static numpy in, static numpy out (the
    handles lower to an HLO constant and land in SMEM before the grid
    runs); traced tables pass through as jnp."""
    if isinstance(slot_pages, np.ndarray):
        rows = (slot_pages[:-1] if slots is None
                else slot_pages[np.asarray(slots, np.int32)])
        return rows.astype(np.int32)
    tbl = jnp.asarray(slot_pages, jnp.int32)
    return tbl[:-1] if slots is None else tbl[jnp.asarray(slots)]


def verify_page_plan(slot_pages: np.ndarray, geom: PageGeometry) -> None:
    """Page handles must be a bijection onto [0, num_pages): distinct slots
    own disjoint page sets, so slot-level collision-freedom (``mbkr.
    verify_plan``) implies page-level collision-freedom. Raises on violation."""
    flat = slot_pages.ravel()
    assert flat.size == geom.num_pages, (flat.size, geom.num_pages)
    assert flat.min() >= 0 and flat.max() < geom.num_pages
    assert np.unique(flat).size == flat.size, "page handle collision"


# --------------------------------------------------------------------- pool

@dataclass
class PagedPool:
    """Device-resident paged KV pool (a jax pytree; scales None when the
    codec is passthrough)."""
    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array] = None
    v_scale: Optional[jax.Array] = None


def _pool_flatten(p: PagedPool):
    return (p.k, p.v, p.k_scale, p.v_scale), None


def _pool_unflatten(_, children):
    return PagedPool(*children)


jax.tree_util.register_pytree_node(PagedPool, _pool_flatten, _pool_unflatten)


def alloc_pool(geom: PageGeometry, codec: Q.KVCodec, lps: int, b: int,
               kvh: int, hd: int) -> PagedPool:
    shape = (geom.num_pages, lps, b, geom.page_tokens, kvh, hd)
    dt = jnp.dtype(codec.storage_dtype)
    k = jnp.zeros(shape, dt)
    v = jnp.zeros(shape, dt)
    if not codec.quantized:
        return PagedPool(k, v)
    sshape = (geom.num_pages, lps, b, 1, kvh, 1)
    one = jnp.ones(sshape, jnp.float32)  # benign scale for never-written pages
    return PagedPool(k, v, one, one)


def pool_bytes(geom: PageGeometry, codec: Q.KVCodec, lps: int, b: int,
               kvh: int, hd: int) -> float:
    """Total device bytes of one stage's paged pool (k + v + scales)."""
    payload = 2.0 * geom.num_pages * lps * b * geom.page_tokens * kvh * hd \
        * codec.bytes_per_el
    scales = 2.0 * geom.num_pages * codec.scale_bytes_per_page(lps, b, kvh)
    return payload + scales


# ----------------------------------------------------------- write (scatter)

def _paginate(x: jax.Array, ppc: int) -> jax.Array:
    """[lps, B, C, kvh, hd] -> [ppc, lps, B, page_tokens, kvh, hd]."""
    lps, b, c, kvh, hd = x.shape
    x = x.reshape(lps, b, ppc, c // ppc, kvh, hd)
    return x.transpose(2, 0, 1, 3, 4, 5)


def scatter_chunk(pool: PagedPool, pages: jax.Array, k: jax.Array,
                  v: jax.Array, codec: Q.KVCodec) -> PagedPool:
    """Encode one chunk's fresh KV ([lps, B, C, kvh, hd]) block-wise (one
    scale per page) and scatter its pages to the handles ``pages`` [ppc]
    (traced)."""
    ppc = pages.shape[0]
    kq, ks = Q.encode(codec, k, pages=ppc)
    vq, vs = Q.encode(codec, v, pages=ppc)
    return scatter_chunk_raw(pool, pages, kq, vq, ks, vs)


def scatter_chunk_raw(pool: PagedPool, pages: jax.Array, kq: jax.Array,
                      vq: jax.Array, ks: Optional[jax.Array],
                      vs: Optional[jax.Array]) -> PagedPool:
    """Scatter already-encoded chunk KV (the creditor side of a spill: the
    wire delivered payload + per-page scales [ppc, lps, B, 1, kvh, 1]; only
    handles move locally). One batched scatter per tensor — page handles of
    one slot are disjoint by the table bijection (``verify_page_plan``)."""
    ppc = pages.shape[0]
    kp = _paginate(kq, ppc).astype(pool.k.dtype)
    vp = _paginate(vq, ppc).astype(pool.v.dtype)
    k_pool = pool.k.at[pages].set(kp)
    v_pool = pool.v.at[pages].set(vp)
    k_sc, v_sc = pool.k_scale, pool.v_scale
    if k_sc is not None:
        k_sc = k_sc.at[pages].set(ks)
        v_sc = v_sc.at[pages].set(vs)
    return PagedPool(k_pool, v_pool, k_sc, v_sc)


# ------------------------------------------------------------ read (gather)

def gather_chunk(k_l: jax.Array, v_l: jax.Array,
                 ks_l: Optional[jax.Array], vs_l: Optional[jax.Array],
                 pages: jax.Array
                 ) -> Tuple[jax.Array, jax.Array,
                            Optional[jax.Array], Optional[jax.Array]]:
    """Gather one slot's chunk from LAYER-SLICED pool arrays — the
    jnp-REFERENCE feed (per-slot scan order and the streamed fetch wire),
    not a perf path: the paged kernel (``ops.pool_attention_paged``) reads
    pages in place and never materializes this copy.

    k_l/v_l [P, B, page_tokens, kvh, hd]; ks_l/vs_l [P, B, 1, kvh, 1];
    pages [ppc] (traced). Returns the ENCODED chunk ([B, C, kvh, hd] payload
    + per-PAGE scales [ppc, B, 1, kvh, 1]) — decode is the reader's
    business (the jnp backend multiplies out; the Pallas kernel dequantizes
    in its epilogue)."""
    kq = jnp.take(k_l, pages, axis=0)          # [ppc, B, pt, kvh, hd]
    vq = jnp.take(v_l, pages, axis=0)
    ppc, b, pt, kvh, hd = kq.shape
    kq = kq.transpose(1, 0, 2, 3, 4).reshape(b, ppc * pt, kvh, hd)
    vq = vq.transpose(1, 0, 2, 3, 4).reshape(b, ppc * pt, kvh, hd)
    ks = vs = None
    if ks_l is not None:
        ks = jnp.take(ks_l, pages, axis=0)     # [ppc, B, 1, kvh, 1]
        vs = jnp.take(vs_l, pages, axis=0)
    return kq, vq, ks, vs


def gather_chunks(k_l: jax.Array, v_l: jax.Array,
                  ks_l: Optional[jax.Array], vs_l: Optional[jax.Array],
                  page_rows: jax.Array
                  ) -> Tuple[jax.Array, jax.Array,
                             Optional[jax.Array], Optional[jax.Array]]:
    """``gather_chunk`` over a STACK of slots in one shot: ``page_rows``
    [S, ppc] (traced) -> payloads [S, B, C, kvh, hd] + per-page scales
    [S, ppc, B, 1, kvh, 1]. One batched take per tensor.

    ORACLE FEED ONLY: this materializes the dense slot stack in HBM — the
    input of the gathered slot-grid kernel (``kernels.ops.pool_attention``),
    kept as the reference the paged path is reconciled against. The perf
    path (``pool_backend="paged"``) skips it entirely: the paged kernel
    takes ``handle_rows`` and reads pages in place."""
    s, ppc = page_rows.shape
    flat = page_rows.reshape(-1)
    kq = jnp.take(k_l, flat, axis=0)           # [S*ppc, B, pt, kvh, hd]
    vq = jnp.take(v_l, flat, axis=0)
    _, b, pt, kvh, hd = kq.shape
    kq = kq.reshape(s, ppc, b, pt, kvh, hd).transpose(0, 2, 1, 3, 4, 5) \
           .reshape(s, b, ppc * pt, kvh, hd)
    vq = vq.reshape(s, ppc, b, pt, kvh, hd).transpose(0, 2, 1, 3, 4, 5) \
           .reshape(s, b, ppc * pt, kvh, hd)
    ks = vs = None
    if ks_l is not None:
        ks = jnp.take(ks_l, flat, axis=0).reshape(s, ppc, *ks_l.shape[1:])
        vs = jnp.take(vs_l, flat, axis=0).reshape(s, ppc, *vs_l.shape[1:])
    return kq, vq, ks, vs
