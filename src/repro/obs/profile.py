"""Measured span attribution: wall-clock per-(stage, tick) spans for the
pipeline scan, aligned index-for-index with the device ``TelemetryProfile``
(DESIGN.md §9).

The pipeline's tick body accepts a ``tick_hook`` (``core.pipeline``): a
ZERO-ARG host callback fired via ``jax.debug.callback(hook)`` at the end of
every tick. It carries no operands because this jaxlib's SPMD partitioner
rejects operand-carrying callbacks inside the manual shard_map region — so
tick identity is recovered host-side from ARRIVAL ORDER (``jax.lax.scan``
runs ticks strictly in order, and debug-callback delivery preserves program
order per dispatch). ``TickSpanCollector`` timestamps the firings;
``finalize`` turns the stream into a ``MeasuredProfile`` whose ``tick_s``
``[N, T]`` array uses the SAME stage-major / ``T = M + N - 1`` layout and
``0 <= phase < M`` validity convention as the telemetry profiles — so a
measured span, its analytic twin, and the device counters all index the
same way, and the calibration design matrix (``obs.calibrate``) is a zip.

Measurement semantics, stated honestly:

- Ticks are SPMD-lockstep, so the measurable quantity is the per-tick
  wall-clock span, SHARED by every stage active that tick. ``finalize``
  broadcasts each tick's span into the valid (stage, tick) cells; it does
  NOT partition a tick's time between its stages (that attribution lives in
  the per-kernel-tag stream below and the analytic split of the fit).
- A tick span is the delta between consecutive tick arrivals; tick 0
  additionally carries dispatch overhead from the collector's epoch (reset
  right before launch). Callers warm up first so compile time is out.
- Debug callbacks flush asynchronously under real (TPU) dispatch —
  ``jax.effects_barrier()`` orders them before ``finalize`` reads.
- A tick may fire the beacon more than once (one per participating
  dispatch); ``finalize`` order-groups the sorted timestamps into
  ``num_ticks`` groups and keeps each group's LAST arrival — the straggler
  defines the span, as it defines the pipeline's critical path.

Per-kernel-tag attribution rides the existing ``ops.count_launches`` frame
stack: ``count_launches(timed=True)`` records the ordered
``(tag, perf_counter)`` event stream, and ``kernel_tag_times`` charges each
inter-event delta to the tag of the LATER event — the kernel whose
completion the callback marks.

Import-light: stdlib + numpy at import; jax only inside ``measure_prefill``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class MeasuredProfile:
    """Wall-clock twin of ``TelemetryProfile``: ``tick_s [N, T]`` seconds
    per (stage, tick), plus optional per-kernel-tag totals."""
    tick_s: np.ndarray
    kernel_s: Dict[str, float] = field(default_factory=dict)

    @property
    def stages(self) -> int:
        return self.tick_s.shape[0]

    @property
    def ticks(self) -> int:
        return self.tick_s.shape[1]

    def valid(self, num_chunks: int) -> np.ndarray:
        """Boolean [N, T]: True where ``0 <= tick - stage < M`` — the spans
        that carry a real chunk (the telemetry validity convention); the
        rest is fill/drain bubble."""
        n, t_all = self.tick_s.shape
        ph = np.arange(t_all)[None, :] - np.arange(n)[:, None]
        return (ph >= 0) & (ph < num_chunks)

    def total(self) -> float:
        """End-to-end measured scan seconds (ticks are lockstep: the
        per-tick maximum over stages, summed)."""
        return float(self.tick_s.max(axis=0).sum())

    def to_dict(self) -> Dict:
        return {"tick_s": [[float(v) for v in row] for row in self.tick_s],
                "kernel_s": {k: float(v) for k, v in self.kernel_s.items()}}


class TickSpanCollector:
    """Host-side sink for the pipeline's ``tick_hook``. Pass ``col.note``
    as the hook; ``reset`` right before the timed dispatch; ``finalize``
    after ``jax.effects_barrier()``."""

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self.epoch = time.perf_counter()
        self.events: List[float] = []

    def note(self) -> None:
        self.events.append(time.perf_counter())

    def finalize(self, num_stages: int, num_ticks: int, *,
                 kernel_s: Optional[Dict[str, float]] = None
                 ) -> MeasuredProfile:
        """Collapse the timestamp stream into ``tick_s [N, T]``: order-group
        the sorted arrivals into ``num_ticks`` groups (a tick may beacon
        once per participating dispatch), keep each group's LAST arrival,
        difference consecutive group arrivals (tick 0 against the epoch),
        and broadcast each tick's span into its VALID (stage, tick) cells
        (``0 <= tick - stage < M``). A tick that never fired gets a zero
        span; bubble cells stay zero."""
        ts = sorted(self.events)
        arrive = np.full(num_ticks, np.nan)
        if ts:
            k = max(1, int(round(len(ts) / num_ticks)))
            for t in range(num_ticks):
                lo = t * k
                if lo >= len(ts):
                    break
                hi = len(ts) if t == num_ticks - 1 else min((t + 1) * k,
                                                            len(ts))
                arrive[t] = ts[hi - 1]
        m = num_ticks - num_stages + 1  # num_chunks under T = M + N - 1
        tick_s = np.zeros((num_stages, num_ticks))
        prev = self.epoch
        for t in range(num_ticks):
            cur = arrive[t]
            if np.isnan(cur):
                cur = prev
            span = max(cur - prev, 0.0)
            prev = cur
            s_lo = max(0, t - m + 1)
            s_hi = min(num_stages - 1, t)
            tick_s[s_lo:s_hi + 1, t] = span
        return MeasuredProfile(tick_s=tick_s, kernel_s=dict(kernel_s or {}))


def kernel_tag_times(frame: Dict) -> Dict[str, float]:
    """Per-kernel-tag wall-clock totals from a ``count_launches(timed=True)``
    frame: each inter-event delta is charged to the tag of the LATER event
    (the kernel whose completion the callback marks); the first event's
    delta runs from ``frame["t0"]``."""
    events = frame.get("events") or []
    out: Dict[str, float] = {}
    prev = float(frame.get("t0", events[0][1] if events else 0.0))
    for tag, ts in events:
        out[tag] = out.get(tag, 0.0) + max(ts - prev, 0.0)
        prev = ts
    return out


def measure_prefill(cfg, staged, tokens, plan, topo, *, embeds=None,
                    warmup: int = 1, timed_kernels: bool = False):
    """Timed replay of the tick loop: run ``prefill_pipeline`` with a
    ``tick_hook`` and return ``(logits, MeasuredProfile)``.

    ``warmup`` un-timed runs absorb compile; ``timed_kernels=True`` nests
    the run in ``ops.count_launches(timed=True)`` (tests-only cost: the
    kernel wrappers retrace) and attaches per-tag totals.
    """
    import jax

    from repro.core import pipeline as pl

    col = TickSpanCollector()

    def run():
        return pl.prefill_pipeline(cfg, staged, tokens, plan, topo,
                                   embeds=embeds, tick_hook=col.note)

    fn = jax.jit(run)
    for _ in range(max(int(warmup), 0)):
        jax.block_until_ready(fn())
        jax.effects_barrier()

    kernel_s: Dict[str, float] = {}
    if timed_kernels:
        from repro.kernels import ops
        with ops.count_launches(timed=True) as frame:
            # a FRESH function object: jit caches by identity, so reusing
            # ``run`` would replay the warmup trace and skip the (cleared)
            # kernel wrappers' launch-note retrace inside the frame
            compiled = jax.jit(lambda: run()).lower().compile()
            col.reset()
            frame["t0"] = time.perf_counter()
            logits = jax.block_until_ready(compiled())
            jax.effects_barrier()
        kernel_s = kernel_tag_times(frame)
    else:
        col.reset()
        logits = jax.block_until_ready(fn())
        jax.effects_barrier()
    return logits, col.finalize(plan.num_stages, plan.num_ticks,
                                kernel_s=kernel_s)
