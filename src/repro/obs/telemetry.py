"""StageTelemetry: per-(stage, tick) device counters for the pipeline scan.

Generalizes the ``CollectiveLedger`` pattern (``core.transport``): a
carry-threaded pytree of fp32 counters that the scan bodies in
``core/{pipeline,stagestep,remote,gpipe}.py`` charge per tick, snapshotted
every tick through the scan's ``ys`` — so ``prefill_pipeline(...,
return_telemetry=True)`` returns one ``[N, T]`` profile per key (stage-major,
``T = M + N - 1`` ticks):

- ``own_chunks`` / ``hosted_chunks``  LIVE chunk-slot occupancy of the
  stage's KV pool: +1 when a chunk is written locally (phase < p2) or lands
  from the MBKR pair (pair phase in [p2, M)), freed in bulk the tick after
  the owning request's last chunk clears (phase == M) — exactly the
  lifecycle ``sched.kvlease`` accounts host-side. The tick x stage total
  renders the paper's Fig-1 imbalance: Terapipe ramps every stage to M;
  MBKR's peak is the slot-plan's ``num_slots`` < M.
- ``kv_bytes``        the same profile priced in STORED bytes via the
  kvstore codec (quantized payload + per-page fp32 scales).
- ``spill_events`` / ``fetch_events`` / ``qship_events``  useful wire
  transfers, gated by the SAME consumption predicates the CollectiveLedger
  charges — so ``events x per_event_wire_bytes`` reproduces the ledger's
  per-category byte totals.
- ``attn_work``       attention FLOPs actually performed, per the LBCP cost
  model (``costmodel.attn_flops`` with the traced phase prefix) — the
  predicted-vs-actual chunk-cost comparison is a subtraction.
- ``launches``        attention-backend block invocations per chip (==
  Pallas kernel launches under the pallas backend; cross-checked against
  ``kernels.ops.count_launches``).

Disabled (``telem=None``) every charge is a no-op and the scan emits no
``ys`` — the pipeline is bit-identical with zero extra collectives (the
only telemetry collective at all is the manual-TP psum in
``telemetry_collect``; at tp=1 / GSPMD-auto there is none).

Charging semantics under the MANUAL TP lowering: logical per-stage COUNTS
(chunks, events, work, launches) are charged divided by ``rep`` (= tp) so
the end-of-scan psum over the tp axes restores them; BYTE amounts are
charged from the local shard geometry so the same psum sums shards back to
the stage's logical bytes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

# "prefix_hit" counts chunk writes served from the prefix cache (one event
# per (stage, hit phase) — the scratch-redirected stores whose KV the radix
# index already held; closed form in ``prefix_saved_model``). The key exists
# unconditionally so armed and disabled runs carry the same pytree.
TELEM_KEYS = ("own_chunks", "hosted_chunks", "kv_bytes", "spill_events",
              "fetch_events", "qship_events", "attn_work", "launches",
              "prefix_hit")

StageTelemetry = Optional[Dict[str, jax.Array]]


def telemetry_init() -> Dict[str, jax.Array]:
    """Fresh per-chip telemetry: one fp32 counter per key."""
    return {k: jnp.zeros((), jnp.float32) for k in TELEM_KEYS}


def charge(tel: StageTelemetry, key: str, amount, active=None,
           rep: int = 1) -> StageTelemetry:
    """Add ``amount / rep`` to ``tel[key]``, gated by the traced ``active``
    predicate (None = unconditional). ``amount`` may be a Python number or a
    traced array (attention work depends on the traced phase). No-op on a
    None telemetry — the disabled path stays free."""
    if tel is None:
        return tel
    if isinstance(amount, (int, float)):
        if amount == 0.0:
            return tel
        amount = jnp.float32(amount / rep)
    else:
        amount = amount.astype(jnp.float32) / rep
    add = amount if active is None else jnp.where(active, amount,
                                                  jnp.float32(0.0))
    out = dict(tel)
    out[key] = tel[key] + add
    return out


def telemetry_collect(tel_ys, tp_axes) -> Dict[str, jax.Array]:
    """Sum the per-tick snapshots over the manual TP axes (None = the stage
    already holds logical values: GSPMD-auto TP or tp=1 — no collective)."""
    if tp_axes is None or not tp_axes:
        return tel_ys
    return {k: jax.lax.psum(v, tp_axes) for k, v in tel_ys.items()}


def chunk_stored_bytes(plan, lps: int, b: int, c: int, kvh: int,
                       hd: int) -> float:
    """STORED bytes of one chunk in the stage's paged pool (k + v payload at
    the codec's storage width + the per-page fp32 scale rows when
    quantized) — equals ``nbytes(encode(k)) + nbytes(encode(v))`` for the
    given (possibly TP-local) geometry."""
    codec = plan.codec
    payload = 2.0 * lps * b * c * kvh * hd * codec.bytes_per_el
    scales = 2.0 * plan.pages_per_chunk * codec.scale_bytes_per_page(
        lps, b, kvh)
    return payload + scales


def charge_tick_residency(tel: StageTelemetry, ctx,
                          chunk_bytes: float, rep: int = 1) -> StageTelemetry:
    """Charge this tick's pool-occupancy deltas (called once per tick from
    the pipeline body). Lifecycle mirrors the slot plan / lease manager:

    - own chunk lands while ``phase < p2`` (spilled chunks live at the pair);
      ALL own chunks free the tick my last chunk clears (``phase == M``).
    - hosted chunk lands while the pair's phase is in ``[p2, M)``; all
      hosted chunks free the tick the PAIR's last chunk clears.

    Frees beyond the scan horizon simply never fire (the run is over); the
    analytic twin ``analytic_occupancy`` applies the identical clipping.
    """
    if tel is None:
        return tel
    plan = ctx.plan
    m, p2 = plan.num_chunks, min(plan.p2, plan.num_chunks)
    phase = ctx.phase
    own_add = (phase >= 0) & (phase < p2)
    tel = charge(tel, "own_chunks", 1.0, own_add, rep)
    tel = charge(tel, "kv_bytes", chunk_bytes, own_add)
    tel = charge(tel, "own_chunks", -float(p2), phase == m, rep)
    tel = charge(tel, "kv_bytes", -float(p2) * chunk_bytes, phase == m)
    if p2 < m and plan.mode == "mocap":
        n2 = plan.pair_shift
        pp = jnp.where(ctx.first_half, phase - n2, phase + n2)
        host_add = (pp >= p2) & (pp < m)
        tel = charge(tel, "hosted_chunks", 1.0, host_add, rep)
        tel = charge(tel, "kv_bytes", chunk_bytes, host_add)
        tel = charge(tel, "hosted_chunks", -float(m - p2), pp == m, rep)
        tel = charge(tel, "kv_bytes", -float(m - p2) * chunk_bytes, pp == m)
    return tel


# ===================================================== host-side analytics

def safe_ratio(num: float, den: float) -> float:
    """``num / den`` with an all-empty guard: 0.0 when the denominator is 0
    (an empty profile is perfectly balanced / has zero drift, not NaN).
    Shared by ``TelemetryProfile.skew`` and the health drift sentinels."""
    return 0.0 if den == 0.0 else float(num) / float(den)

def analytic_occupancy(m: int, n: int, p2: int, *, mode: str = "mocap",
                       ticks: Optional[int] = None):
    """Closed-form LIVE occupancy twin of the device telemetry: ``(own,
    hosted)`` chunk counts, each ``[N, T]`` (stage-major, like the returned
    profiles). Terapipe (``p2 >= m`` or non-mocap) hosts nothing and every
    stage ramps to M — the Fig-1 imbalance the MBKR profile flattens."""
    t_all = ticks if ticks is not None else m + n - 1
    p2 = min(p2, m)
    n2 = n // 2
    own = np.zeros((n, t_all))
    hosted = np.zeros((n, t_all))
    for s in range(n):
        for t in range(t_all):
            ph = t - s
            if ph < m:
                own[s, t] = np.clip(ph + 1, 0, p2)
            if p2 < m and mode == "mocap":
                pp = ph - n2 if s < n2 else ph + n2
                if pp < m:
                    hosted[s, t] = np.clip(pp + 1 - p2, 0, m - p2)
    return own, hosted


def occupancy_model(plan) -> Dict[str, object]:
    """Tick x stage occupancy table for a ``PipelinePlan`` (dryrun records
    this next to ``wire_model``): per-(stage, tick) live slot counts plus
    the peak — the slot-plan guarantee ``peak <= num_slots``."""
    own, hosted = analytic_occupancy(plan.num_chunks, plan.num_stages,
                                     plan.p2, mode=plan.mode)
    total = own + hosted
    return {
        "ticks": int(total.shape[1]),
        "stages": int(total.shape[0]),
        "p2": int(min(plan.p2, plan.num_chunks)),
        "peak_slots": int(total.max()),
        "num_slots": int(plan.num_slots),
        "per_stage_peak": [int(v) for v in total.max(axis=1)],
        "table": [[int(v) for v in row] for row in total],
    }


def per_event_wire_bytes(plan, cfg, b: int) -> Dict[str, float]:
    """Wire bytes of ONE telemetry event per category, derived from the
    §3.4 analytic totals divided by the event counts the telemetry charges
    — so ``sum(events) x per_event == CollectiveLedger category`` holds by
    construction (asserted in tests/test_obs.py)."""
    from repro.core import transport as tx
    w = tx.analytic_wire_bytes(plan, cfg, b)
    n, m, p2 = plan.num_stages, plan.num_chunks, min(plan.p2, plan.num_chunks)
    lps = plan.layers_per_stage
    out = {"spill": 0.0, "fetch": 0.0, "qship": 0.0}
    n_spill = n * (m - p2)
    if n_spill:
        out["spill"] = w["spill"] / n_spill
    consumed = sum(max(0, min(p, m) - p2) for p in range(m))
    if plan.remote_attn == "fetch":
        n_fetch = n * lps * consumed
        if n_fetch:
            out["fetch"] = w["fetch"] / n_fetch
    else:
        n_q = n * lps * max(0, m - 1 - p2)
        if n_q:
            out["qship"] = (w["qship_q"] + w["qship_state"]) / n_q
    return out


def prefix_saved_model(plan, lps: int, b: int, c: int, kvh: int, hd: int,
                       prefix_chunks: int) -> Dict[str, float]:
    """Closed-form twin of the ``prefix_hit`` ledger/telemetry category for
    one armed ``prefill_pipeline(..., prefix_chunks=k)`` call: every stage
    redirects exactly its ``k`` hit-phase chunk stores to scratch, so

        ledger_bytes = N_stages x k x chunk_stored_bytes   (saved KV stores)
        events       = N_stages x k                        (telemetry count)

    with the SAME clamp the device applies (``k <= min(p2, M-1)``). The
    runtime counters are pinned against this in tests/test_prefix.py."""
    k = min(max(int(prefix_chunks), 0),
            min(plan.p2, plan.num_chunks - 1))
    cb = chunk_stored_bytes(plan, lps, b, c, kvh, hd)
    return {"ledger_bytes": plan.num_stages * k * cb,
            "events": float(plan.num_stages * k)}


@dataclass
class TelemetryProfile:
    """Host-side view over the ``[N, T]`` profiles ``prefill_pipeline``
    returns; all arrays stage-major."""
    data: Dict[str, np.ndarray]

    @classmethod
    def from_run(cls, tel) -> "TelemetryProfile":
        return cls({k: np.asarray(v) for k, v in tel.items()})

    @property
    def stages(self) -> int:
        return self.data["own_chunks"].shape[0]

    @property
    def ticks(self) -> int:
        return self.data["own_chunks"].shape[1]

    def occupancy(self) -> np.ndarray:
        """Live slot occupancy [N, T] = own + hosted chunks."""
        return self.data["own_chunks"] + self.data["hosted_chunks"]

    def per_stage_peak(self, key: Optional[str] = None) -> np.ndarray:
        arr = self.occupancy() if key is None else self.data[key]
        return arr.max(axis=1)

    def peak(self, key: Optional[str] = None) -> float:
        return float(self.per_stage_peak(key).max())

    def skew(self, key: str = "kv_bytes") -> float:
        """Normalized cross-stage peak imbalance ``(max - min) / max`` — the
        spread MBKR narrows (0 = perfectly balanced peaks). An ALL-EMPTY key
        (every per-stage peak 0, e.g. kv_bytes on an attention-free run)
        returns 0.0 instead of dividing by zero: no residency means no
        imbalance."""
        pk = self.per_stage_peak(key)
        return safe_ratio(float(pk.max() - pk.min()), float(pk.max()))

    def totals(self) -> Dict[str, float]:
        """Final cumulative value per key, summed over stages (counters like
        events/work/launches; occupancy keys report their peak instead)."""
        out: Dict[str, float] = {}
        for k, v in self.data.items():
            if k in ("own_chunks", "hosted_chunks", "kv_bytes"):
                out[k] = float(v.max(axis=1).sum())
            else:
                out[k] = float(v[:, -1].sum())
        return out
