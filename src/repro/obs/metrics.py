"""Serving metrics: counters/gauges/histograms + JSON-lines / Prometheus
textfile export (stdlib-only, atomic writes).

The registry is deliberately small — a name->metric dict with the three
Prometheus primitive kinds — because the serving loop is single-process and
single-threaded: no locks, no label cardinality explosions, just the values
an SLO dashboard needs. Two wire formats from one registry:

- ``export_jsonl``  one JSON object per line (``{"name", "kind", "value" |
  "buckets"/"sum"/"count", "help"}``) — trivially greppable/jq-able.
- ``export_prom``   the Prometheus textfile-collector format (``# HELP`` /
  ``# TYPE`` + samples, ``_bucket{le=...}``/``_sum``/``_count`` for
  histograms) — drop the file in a node-exporter textfile directory.

``export_engine_metrics`` maps a ``ContinuousEngine``/``PrefillEngine``
summary (``sched.metrics.SchedMetrics.summary``) onto the registry and picks
the format from the extension (``.prom`` -> Prometheus, else JSON-lines).
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

from repro.obs._io import atomic_write_text

_PREFIX = "repro_"

# Default TTFT-style latency buckets (seconds), roughly log-spaced.
DEFAULT_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
                   120.0)


class Counter:
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def sample(self) -> Dict[str, Any]:
        return {"name": self.name, "kind": self.kind, "value": self.value,
                "help": self.help}


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def sample(self) -> Dict[str, Any]:
        return {"name": self.name, "kind": self.kind, "value": self.value,
                "help": self.help}


class Histogram:
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)   # +Inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        for i, b in enumerate(self.buckets):
            if value <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> List[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def sample(self) -> Dict[str, Any]:
        return {"name": self.name, "kind": self.kind,
                "buckets": {("+Inf" if math.isinf(b) else repr(b)): c
                            for b, c in zip(self.buckets + (math.inf,),
                                            self.cumulative())},
                "sum": self.sum, "count": self.count, "help": self.help}


class MetricsRegistry:
    """Name-keyed metric store with idempotent getters and two exporters."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def metrics(self) -> Iterable[Any]:
        return self._metrics.values()

    # ------------------------------------------------------------- export
    def to_jsonl(self) -> str:
        return "".join(json.dumps(m.sample()) + "\n" for m in self.metrics())

    def to_prom(self) -> str:
        lines: List[str] = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                for b, c in zip(m.buckets + (math.inf,), m.cumulative()):
                    le = "+Inf" if math.isinf(b) else repr(b)
                    lines.append(f'{m.name}_bucket{{le="{le}"}} {c}')
                lines.append(f"{m.name}_sum {m.sum}")
                lines.append(f"{m.name}_count {m.count}")
            else:
                lines.append(f"{m.name} {m.value}")
        return "\n".join(lines) + "\n"

    def export_jsonl(self, path: str) -> str:
        return atomic_write_text(path, self.to_jsonl())

    def export_prom(self, path: str) -> str:
        return atomic_write_text(path, self.to_prom())

    def export(self, path: str) -> str:
        """Format by extension: ``.prom`` -> textfile, else JSON-lines."""
        if path.endswith(".prom"):
            return self.export_prom(path)
        return self.export_jsonl(path)


# Engine-summary key -> (metric kind, help). Counters are monotone totals;
# everything else from the summary is a point-in-time gauge.
_SUMMARY_COUNTERS = {
    "completed": "requests completed",
    "rejected": "requests rejected at admission",
    "slo_total": "requests carrying an SLO",
    "slo_met": "requests that met their SLO",
    "lease_refusals": "distinct requests refused by the KV lease manager",
}


def export_engine_metrics(path: str, summary: Mapping[str, Any],
                          records: Optional[Sequence[Any]] = None,
                          extra: Optional[Mapping[str, float]] = None,
                          health=None) -> str:
    """Export an engine metrics summary (``engine.metrics()``) to ``path``.

    ``records`` (``sched.metrics.RequestRecord``) feed the TTFT/queue-wait
    histograms; ``extra`` adds ad-hoc gauges (e.g. wall-clock, wave count);
    ``health`` (an ``obs.health.HealthMonitor``) adds per-kind alert
    counters + the SLO burn-rate gauge.
    Format picked from the extension (``.prom`` vs JSON-lines).
    """
    reg = MetricsRegistry()
    for key, value in summary.items():
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            continue
        if key in _SUMMARY_COUNTERS:
            reg.counter(_PREFIX + key, _SUMMARY_COUNTERS[key]).inc(value)
        else:
            reg.gauge(_PREFIX + key, f"engine summary {key}").set(value)
    if records:
        ttft = reg.histogram(_PREFIX + "ttft_seconds",
                             "time to first token (finish - arrival)")
        qwait = reg.histogram(_PREFIX + "queue_wait_seconds",
                              "admission queue wait (admit - arrival)")
        for r in records:
            # rejected requests carry finish/admit = inf — not a latency
            if math.isfinite(r.finish):
                ttft.observe(r.finish - r.arrival)
            if math.isfinite(r.admit):
                qwait.observe(r.admit - r.arrival)
    if extra:
        for key, value in extra.items():
            reg.gauge(_PREFIX + key, f"run stat {key}").set(float(value))
    if health is not None:
        health.to_metrics(reg)
    return reg.export(path)
