"""Atomic artifact writes shared by the trace/metrics exporters.

A crashed or interrupted run must never leave a truncated/corrupt JSON (or
Prometheus textfile) artifact behind: write to a temp file in the SAME
directory (so the rename never crosses a filesystem) and ``os.replace`` it
into place — readers see either the old complete file or the new one.
"""
from __future__ import annotations

import os
import tempfile


def atomic_write_text(path: str, text: str) -> str:
    """Atomically write ``text`` to ``path`` (parent dirs created)."""
    path = os.path.abspath(path)
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path
