"""Merged Chrome/Perfetto trace recorder (stdlib-only).

One recorder, one file, three event families (ISSUE 6 tentpole #2):

- scheduler task intervals (``task``/``mark``, the original
  ``sched/trace.py`` surface — pid = stage, tid = request),
- engine wave / per-tick stage spans (``span`` — arbitrary pid/tid),
- counter tracks (``counter`` — ``"ph": "C"`` events Perfetto renders as
  stacked area charts: KV occupancy and wire bytes per stage).

Timestamps are SECONDS on whatever clock the caller uses (the scheduler's
virtual clock or ``time.perf_counter`` deltas); export converts to the
trace-event microsecond unit. ``export`` writes atomically
(``_io.atomic_write_text``) so an interrupted run never leaves a truncated
JSON artifact. ``sched.trace`` re-exports this module's names, so existing
imports keep working.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Mapping, Optional

from repro.obs._io import atomic_write_text


@dataclass(frozen=True)
class TaskEvent:
    rid: int
    chunk: int
    stage: int
    start: float          # seconds (scheduler clock)
    finish: float


@dataclass(frozen=True)
class MarkEvent:
    rid: int
    kind: str             # arrival | admit | finish | reject
    time: float


@dataclass(frozen=True)
class SpanEvent:
    name: str
    pid: Any              # process row (stage index or a string label)
    tid: Any              # thread row within the process
    start: float
    finish: float
    cat: str = "span"
    args: Optional[Dict[str, Any]] = None


@dataclass(frozen=True)
class CounterEvent:
    name: str             # counter track name (one track per (pid, name))
    pid: Any
    time: float
    values: Dict[str, float] = field(default_factory=dict)


class TraceRecorder:
    """Accumulates scheduler/engine/telemetry events; no-op when disabled."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.tasks: List[TaskEvent] = []
        self.marks: List[MarkEvent] = []
        self.spans: List[SpanEvent] = []
        self.counters: List[CounterEvent] = []
        self._pid_names: Dict[Any, str] = {}

    def task(self, rid: int, chunk: int, stage: int,
             start: float, finish: float) -> None:
        if self.enabled:
            self.tasks.append(TaskEvent(rid, chunk, stage, start, finish))

    def mark(self, rid: int, kind: str, time: float) -> None:
        if self.enabled:
            self.marks.append(MarkEvent(rid, kind, time))

    def span(self, name: str, *, pid: Any, tid: Any, start: float,
             finish: float, cat: str = "span",
             args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete-duration ("ph": "X") interval."""
        if self.enabled:
            self.spans.append(SpanEvent(name, pid, tid, start, finish,
                                        cat, args))

    def counter(self, name: str, *, pid: Any, time: float,
                values: Mapping[str, float]) -> None:
        """Record one sample on a counter track ("ph": "C")."""
        if self.enabled:
            self.counters.append(CounterEvent(name, pid, time,
                                              dict(values)))

    def process_name(self, pid: Any, name: str) -> None:
        """Label a process row (overrides the default ``stage {pid}``)."""
        if self.enabled:
            self._pid_names[pid] = name

    # -------------------------------------------------------------- merging
    def absorb(self, other: "TraceRecorder", *, pid_prefix: str = "") -> None:
        """Fold another recorder's events into this one under a per-source
        process namespace — the multi-cell fleet timeline (``repro.fleet``):
        ONE file where every cell keeps its own process rows
        (``cell0/stage 3``, ``cell1/engine``, ...). All of ``other``'s pids
        (task stages, span/counter pids, registered process names) are
        re-keyed to ``f"{pid_prefix}{pid}"``; task intervals become chunk
        spans (tid = request) and lifecycle marks become zero-duration
        request instants, so absorbed cells never collide with this
        recorder's own integer stage pids. With an empty prefix events copy
        through verbatim."""
        if not self.enabled:
            return

        def _pid(p: Any) -> Any:
            if not pid_prefix:
                return p
            base = f"stage {p}" if isinstance(p, int) else str(p)
            return f"{pid_prefix}{base}"

        if not pid_prefix:
            self.tasks.extend(other.tasks)
            self.marks.extend(other.marks)
        else:
            for t in other.tasks:
                self.span(f"r{t.rid}/c{t.chunk}", pid=_pid(t.stage),
                          tid=t.rid, start=t.start, finish=t.finish,
                          cat="chunk", args={"rid": t.rid, "chunk": t.chunk,
                                             "stage": t.stage})
            for m in other.marks:
                self.span(f"{m.kind} r{m.rid}", pid=f"{pid_prefix}requests",
                          tid=m.rid, start=m.time, finish=m.time,
                          cat="request")
        for s in other.spans:
            self.spans.append(SpanEvent(s.name, _pid(s.pid), s.tid, s.start,
                                        s.finish, s.cat, s.args))
        for c in other.counters:
            self.counters.append(CounterEvent(c.name, _pid(c.pid), c.time,
                                              dict(c.values)))
        for p, name in other._pid_names.items():
            self._pid_names[_pid(p)] = (f"{pid_prefix}{name}" if pid_prefix
                                        else name)

    # ------------------------------------------------------------- export
    def events(self) -> Dict[str, List[Dict[str, Any]]]:
        """Raw event dicts for offline analysis."""
        return {"tasks": [asdict(t) for t in self.tasks],
                "marks": [asdict(m) for m in self.marks],
                "spans": [asdict(s) for s in self.spans],
                "counters": [asdict(c) for c in self.counters]}

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: pid = stage, tid = request, ts in us."""
        ev: List[Dict[str, Any]] = []
        for t in self.tasks:
            ev.append({
                "name": f"r{t.rid}/c{t.chunk}",
                "cat": "chunk",
                "ph": "X",
                "ts": t.start * 1e6,
                "dur": (t.finish - t.start) * 1e6,
                "pid": t.stage,
                "tid": t.rid,
                "args": {"rid": t.rid, "chunk": t.chunk, "stage": t.stage},
            })
        for m in self.marks:
            ev.append({
                "name": m.kind,
                "cat": "request",
                "ph": "i",
                "s": "g",
                "ts": m.time * 1e6,
                "pid": 0,
                "tid": m.rid,
            })
        for s in self.spans:
            rec = {
                "name": s.name,
                "cat": s.cat,
                "ph": "X",
                "ts": s.start * 1e6,
                "dur": (s.finish - s.start) * 1e6,
                "pid": s.pid,
                "tid": s.tid,
            }
            if s.args:
                rec["args"] = s.args
            ev.append(rec)
        for c in self.counters:
            ev.append({
                "name": c.name,
                "cat": "counter",
                "ph": "C",
                "ts": c.time * 1e6,
                "pid": c.pid,
                "tid": 0,
                "args": c.values,
            })
        pids = ({t.stage for t in self.tasks} | {s.pid for s in self.spans}
                | {c.pid for c in self.counters} | set(self._pid_names))
        for p in sorted(pids, key=str):
            name = self._pid_names.get(
                p, f"stage {p}" if isinstance(p, int) else str(p))
            ev.append({"name": "process_name", "ph": "M", "pid": p,
                       "args": {"name": name}})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Atomically write the Chrome trace JSON to ``path``."""
        return atomic_write_text(path, json.dumps(self.chrome_trace()))
