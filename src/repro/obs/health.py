"""Runtime health sentinels: a structured alert stream over the obs surfaces
(DESIGN.md §9 alert taxonomy).

Three sentinel families feed one ``HealthMonitor``:

- ``nonfinite``   NaN/inf activations per (stage, tick), reported by the
  pipeline via a ``jax.debug.callback`` that fires ONLY when the monitor is
  attached (``health=None`` traces nothing — the compiled program is
  bit-identical with zero extra collectives, proven the same way as the
  telemetry-off path in tests/test_calibration.py).
- ``occupancy_drift`` / ``ledger_drift``   the device telemetry / collective
  ledger measured against their analytic twins
  (``telemetry.analytic_occupancy``, ``transport.analytic_wire_bytes``)
  beyond a relative threshold — the invariants the tests assert once,
  watched continuously in serving.
- ``slo_burn``   SLO burn-rate from the TTFT histogram: the fraction of the
  error budget (``1 - target``) being consumed. Burn-rate 1.0 = exactly on
  budget; an alert fires above ``burn_threshold``.

Alerts land in BOTH export surfaces: ``to_metrics`` adds per-kind counters
(+ the burn-rate gauge) to a ``MetricsRegistry``; ``to_trace`` adds a
``health`` process row of instant spans to the merged Perfetto trace.

Import-light: stdlib + numpy at import; ``repro.obs.telemetry`` (which pulls
jax) only inside ``check_occupancy``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional

import numpy as np

ALERT_KINDS = ("nonfinite", "occupancy_drift", "ledger_drift", "slo_burn")


@dataclass(frozen=True)
class Alert:
    kind: str                      # one of ALERT_KINDS
    severity: str                  # "warn" | "crit"
    message: str
    value: float                   # the measurement that tripped
    threshold: float
    stage: Optional[int] = None
    tick: Optional[int] = None
    time: float = 0.0              # perf_counter at detection

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "severity": self.severity,
                "message": self.message, "value": self.value,
                "threshold": self.threshold, "stage": self.stage,
                "tick": self.tick, "time": self.time}


def slo_burn_rate(hist, slo_s: float, target: float = 0.99) -> float:
    """Burn-rate from a ``metrics.Histogram``: ``(1 - frac_within_slo) /
    (1 - target)`` using the largest bucket boundary <= ``slo_s`` (a
    conservative read of the quantized histogram). 0.0 on no observations.
    """
    if hist.count == 0:
        return 0.0
    within = 0
    for b, c in zip(hist.buckets, hist.cumulative()):
        if b <= slo_s:
            within = c
        else:
            break
    frac_violating = 1.0 - within / hist.count
    budget = max(1.0 - target, 1e-12)
    return frac_violating / budget


class HealthMonitor:
    """Accumulates alerts; attach to the executor (``note_nonfinite`` is the
    device callback target) and run the ``check_*`` sentinels host-side."""

    def __init__(self, *, occupancy_threshold: float = 0.01,
                 ledger_threshold: float = 0.01,
                 burn_threshold: float = 1.0):
        self.occupancy_threshold = occupancy_threshold
        self.ledger_threshold = ledger_threshold
        self.burn_threshold = burn_threshold
        self.alerts: List[Alert] = []
        self.burn_rate: Optional[float] = None

    # ------------------------------------------------------ device callback
    def note_nonfinite_profile(self, counts,
                               where: str = "activations") -> None:
        """Host callback target for the pipeline's ``[N, T]`` per-(stage,
        tick) non-finite count profile (delivered in ONE callback after the
        manual shard_map region — operand callbacks are illegal inside it).
        Emits one alert per offending cell; an all-zero profile (the
        healthy case) emits nothing."""
        arr = np.asarray(counts)
        for s, t in zip(*np.nonzero(arr)):
            self.note_nonfinite(arr[s, t], t, s, where=where)

    def note_nonfinite(self, count, tick, stage, where: str = "activations"
                       ) -> None:
        """Per-cell alert emitter (see ``note_nonfinite_profile``); zero
        count = healthy cell = no alert."""
        n = int(count)
        if n > 0:
            self.alerts.append(Alert(
                kind="nonfinite", severity="crit",
                message=f"{n} non-finite {where} at stage "
                        f"{int(stage)} tick {int(tick)}",
                value=float(n), threshold=0.0, stage=int(stage),
                tick=int(tick), time=time.perf_counter()))

    # ----------------------------------------------------- drift sentinels
    def check_occupancy(self, telem, plan) -> float:
        """Device occupancy vs the closed-form twin: relative drift
        ``max|measured - analytic| / analytic_peak``. ``telem`` is a
        ``TelemetryProfile`` or the raw dict a wave carries."""
        from repro.obs import telemetry as obs_t
        prof = telem if hasattr(telem, "occupancy") \
            else obs_t.TelemetryProfile.from_run(telem)
        own, hosted = obs_t.analytic_occupancy(
            plan.num_chunks, plan.num_stages, plan.p2, mode=plan.mode,
            ticks=prof.ticks)
        model = own + hosted
        drift = obs_t.safe_ratio(
            float(np.abs(prof.occupancy() - model).max()),
            float(model.max()))
        if drift > self.occupancy_threshold:
            self.alerts.append(Alert(
                kind="occupancy_drift", severity="warn",
                message=f"telemetry occupancy drifts {drift:.3f} from the "
                        "analytic slot model",
                value=drift, threshold=self.occupancy_threshold,
                time=time.perf_counter()))
        return drift

    def check_ledger(self, ledger: Mapping[str, float],
                     model: Mapping[str, float]) -> float:
        """Measured collective-ledger bytes vs the §3.4 analytic wire model:
        worst per-category relative drift over the shared categories."""
        from repro.obs.telemetry import safe_ratio
        worst = 0.0
        for k in set(ledger) & set(model):
            d = safe_ratio(abs(float(ledger[k]) - float(model[k])),
                           abs(float(model[k])))
            if d > worst:
                worst = d
            if d > self.ledger_threshold:
                self.alerts.append(Alert(
                    kind="ledger_drift", severity="warn",
                    message=f"ledger category {k!r} drifts {d:.3f} from the "
                            "analytic wire model",
                    value=d, threshold=self.ledger_threshold,
                    time=time.perf_counter()))
        return worst

    def check_slo(self, ttft_hist, slo_s: float,
                  target: float = 0.99) -> float:
        """SLO burn-rate sentinel over the TTFT histogram."""
        burn = slo_burn_rate(ttft_hist, slo_s, target)
        self.burn_rate = burn
        if burn > self.burn_threshold:
            self.alerts.append(Alert(
                kind="slo_burn", severity="crit",
                message=f"TTFT SLO burn-rate {burn:.2f}x the error budget "
                        f"(slo={slo_s}s, target={target})",
                value=burn, threshold=self.burn_threshold,
                time=time.perf_counter()))
        return burn

    # ------------------------------------------------------------- exports
    def counts(self) -> Dict[str, int]:
        out = {k: 0 for k in ALERT_KINDS}
        for a in self.alerts:
            out[a.kind] = out.get(a.kind, 0) + 1
        return out

    def summary(self) -> Dict[str, Any]:
        return {"alerts_total": len(self.alerts), "by_kind": self.counts(),
                "burn_rate": self.burn_rate}

    def to_metrics(self, reg) -> None:
        """Per-kind alert counters + the burn-rate gauge on a
        ``MetricsRegistry`` (same ``repro_`` prefix as the engine export)."""
        total = reg.counter("repro_health_alerts_total",
                            "health sentinel alerts fired")
        total.inc(len(self.alerts))
        for kind, n in self.counts().items():
            reg.counter(f"repro_health_{kind}_total",
                        f"{kind} sentinel alerts").inc(n)
        if self.burn_rate is not None:
            reg.gauge("repro_health_slo_burn_rate",
                      "TTFT SLO burn-rate (1.0 = on budget)"
                      ).set(self.burn_rate)

    def to_trace(self, rec, *, pid: str = "health",
                 width_s: float = 1e-4) -> None:
        """Instant spans on a dedicated ``health`` process row of the merged
        Perfetto trace (one thread row per alert kind)."""
        if not self.alerts:
            return
        rec.process_name(pid, "health sentinels")
        t0 = min(a.time for a in self.alerts)
        for a in self.alerts:
            rec.span(a.message, pid=pid, tid=a.kind, start=a.time - t0,
                     finish=a.time - t0 + width_s, cat="alert",
                     args=a.to_dict())
