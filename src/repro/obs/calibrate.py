"""LBCP cost-model calibration: fit effective HardwareProfile rates to
measured per-(stage, tick) spans (DESIGN.md §9).

The analytic chunk cost is LINEAR in four effective inverse rates
(``costmodel.FEATURE_TERMS``): within the attention regime the nominal
profile picks, ``X @ theta == dur + comm + spill_t + fetch_t`` holds exactly
for the work-quantity matrix ``X = chunk_cost_features(...)`` and
``theta = profile_theta(hw, tp)``. Calibration inverts that identity:

    theta* = argmin_theta || X @ theta - measured ||_2

over one design row per VALID (stage, tick) of a ``MeasuredProfile`` (the
positions where ``0 <= phase < M`` — the same index alignment as the device
``TelemetryProfile``). Columns with no signal in the run (e.g. no
bandwidth-bound chunk) and non-positive fitted rates (unidentifiable under
noise) keep their NOMINAL rate — the fit only moves terms the data pins
down. ``profile_from_theta`` folds theta* back into a ``HardwareProfile``
whose effective fields absorb the fit, so ``lbcp.plan_partition``,
``chunk_cost_arrays`` and the scheduler admission costs consume it with no
call-site changes.

Persistence: ``save_profile`` writes ``{"profile": ..., "fit": ...}`` JSON
via the atomic writer. json floats round-trip bit-identically (repr =
shortest round-trip), so a loaded profile reproduces the exact
``dp_partition`` output of the in-memory one (asserted in
tests/test_calibration.py). The ``fit`` block carries the per-(chunk, stage)
residuals dryrun records next to ``wire_model`` / ``occupancy_model``.

Import-light: numpy + costmodel only — no jax (usable from the sim-backed
calibration benchmark and the scheduler path).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel as cm
from repro.obs._io import atomic_write_text


def mape(pred, true) -> float:
    """Mean absolute percentage error over the entries with nonzero truth
    (zero-truth rows carry no scale information); 0.0 on an empty mask."""
    pred = np.asarray(pred, float).ravel()
    true = np.asarray(true, float).ravel()
    mask = true > 0
    if not mask.any():
        return 0.0
    return float(np.mean(np.abs(pred[mask] - true[mask]) / true[mask]))


@dataclass
class FitResult:
    """One calibration fit: the profile + everything needed to audit it."""
    profile: cm.HardwareProfile      # calibrated (effective rates absorbed)
    nominal: cm.HardwareProfile
    theta: np.ndarray                # fitted inverse rates [4], FEATURE_TERMS
    theta_nominal: np.ndarray
    rows: List[Tuple[int, int]]      # (chunk, stage) of each design row
    residual_s: np.ndarray           # measured - calibrated prediction, [rows]
    mape_nominal: float              # nominal prediction vs measured
    mape_calibrated: float           # calibrated prediction vs measured

    def residual_records(self) -> List[Dict]:
        """Per-(chunk, stage) residual rows for the dryrun record."""
        return [{"chunk": int(c), "stage": int(s), "residual_s": float(r)}
                for (c, s), r in zip(self.rows, self.residual_s)]


def design_matrix(sm: cm.StageModel, chunks: Sequence[int],
                  hw: cm.ProfileSpec, tick_s: np.ndarray, *,
                  mbkr_plan=None, compress: float = 1.0
                  ) -> Tuple[np.ndarray, np.ndarray, List[Tuple[int, int]]]:
    """``(X, y, rows)``: one row per valid (stage, tick) of the ``[N, T]``
    span array — phase ``t - s`` in ``[0, M)`` maps that span to chunk
    ``phase``'s feature row. Fill/drain ticks (garbage compute) are NOT
    design rows."""
    feats = cm.chunk_cost_features(sm, chunks, hw, mbkr_plan=mbkr_plan,
                                   compress=compress)
    tick_s = np.asarray(tick_s, float)
    n, t_all = tick_s.shape
    m = len(chunks)
    xs, ys, rows = [], [], []
    for s in range(n):
        for t in range(t_all):
            ph = t - s
            if 0 <= ph < m:
                xs.append(feats[ph])
                ys.append(tick_s[s, t])
                rows.append((ph, s))
    return np.asarray(xs), np.asarray(ys), rows


def fit_profile(sm: cm.StageModel, chunks: Sequence[int], measured,
                hw: cm.ProfileSpec, *, mbkr_plan=None, compress: float = 1.0,
                name: Optional[str] = None) -> FitResult:
    """Least-squares fit of the effective rates against measured spans.

    ``measured``: an ``obs.profile.MeasuredProfile`` or a raw ``[N, T]``
    seconds array aligned like the telemetry profiles (stage-major,
    ``T = M + N - 1``).
    """
    hw = cm.resolve_profile(hw)
    tick_s = getattr(measured, "tick_s", measured)
    x, y, rows = design_matrix(sm, chunks, hw, tick_s,
                               mbkr_plan=mbkr_plan, compress=compress)
    theta0 = cm.profile_theta(hw, sm.tp)
    theta = theta0.copy()
    active = np.abs(x).sum(axis=0) > 0 if len(y) else np.zeros(4, bool)
    if active.any():
        sol, *_ = np.linalg.lstsq(x[:, active], y, rcond=None)
        for j, v in zip(np.flatnonzero(active), sol):
            if v > 0:           # a non-positive rate is unidentifiable noise
                theta[j] = float(v)
    prof = cm.profile_from_theta(hw, theta, sm.tp, name=name)
    pred_cal, pred_nom = x @ theta, x @ theta0
    return FitResult(profile=prof, nominal=hw, theta=theta,
                     theta_nominal=theta0, rows=rows,
                     residual_s=y - pred_cal,
                     mape_nominal=mape(pred_nom, y),
                     mape_calibrated=mape(pred_cal, y))


# ---------------------------------------------------------------- persistence

def save_profile(path: str, profile: cm.HardwareProfile, *,
                 fit: Optional[FitResult] = None,
                 meta: Optional[Dict] = None) -> str:
    """Atomically write a calibrated-profile JSON: ``{"profile": {...}}``
    plus, when a fit is given, the full audit block (nominal profile, theta
    pair, MAPEs, per-(chunk, stage) residuals)."""
    blob: Dict = {"profile": cm.profile_to_dict(profile)}
    if fit is not None:
        blob["fit"] = {
            "feature_terms": list(cm.FEATURE_TERMS),
            "nominal": cm.profile_to_dict(fit.nominal),
            "theta": [float(v) for v in fit.theta],
            "theta_nominal": [float(v) for v in fit.theta_nominal],
            "mape_nominal": fit.mape_nominal,
            "mape_calibrated": fit.mape_calibrated,
            "residuals": fit.residual_records(),
        }
    if meta:
        blob["meta"] = dict(meta)
    return atomic_write_text(path, json.dumps(blob, indent=1))


def load_profile(path: str) -> Tuple[cm.HardwareProfile, Dict]:
    """``(profile, blob)`` — the profile plus the raw JSON (fit metadata)."""
    with open(path) as f:
        blob = json.load(f)
    return cm.profile_from_dict(blob.get("profile", blob)), blob


# ------------------------------------------------------------ dryrun record

def calibration_record(sm: cm.StageModel, chunks: Sequence[int],
                       hw_nominal: cm.ProfileSpec, calibrated_path: str, *,
                       mbkr_plan=None, compress: float = 1.0) -> Dict:
    """Dryrun's ``calibration`` block (recorded next to ``wire_model`` /
    ``occupancy_model``): per-chunk predicted costs under the nominal and
    calibrated profiles for THIS cell's plan, plus the persisted fit
    residuals — so a cell artifact says how far the measured hardware moved
    the partitioning inputs."""
    hw_nominal = cm.resolve_profile(hw_nominal)
    cal, blob = load_profile(calibrated_path)

    def total(hw):
        dur, comm, _, spill_t, fetch_t = cm.chunk_cost_arrays(
            sm, chunks, hw, mbkr_plan=mbkr_plan, compress=compress)
        return dur + comm + spill_t + fetch_t

    t_nom, t_cal = total(hw_nominal), total(cal)
    fit = blob.get("fit", {})
    return {
        "profile": cal.name,
        "nominal_profile": hw_nominal.name,
        "chunk_cost_nominal_s": [float(v) for v in t_nom],
        "chunk_cost_calibrated_s": [float(v) for v in t_cal],
        "shift_frac": mape(t_nom, t_cal),
        "mape_nominal": fit.get("mape_nominal"),
        "mape_calibrated": fit.get("mape_calibrated"),
        "residuals": fit.get("residuals", []),
    }
