"""Unified observability: device telemetry, merged traces, metrics export,
and the closed profiling loop (measure -> calibrate -> detect drift).

Coordinated surfaces (DESIGN.md §8-§9):

- ``obs.telemetry``   the carry-threaded ``StageTelemetry`` pytree charged
                      per (stage, tick) inside the jitted pipeline scan —
                      pool occupancy, resident KV bytes, spill/fetch/qship
                      event counts, attention work units, launch counts.
                      Returned by ``prefill_pipeline(...,
                      return_telemetry=True)`` as ``[N, T]`` profiles.
- ``obs.trace``       the Chrome/Perfetto trace recorder: scheduler task
                      spans + engine wave/tick spans + per-stage counter
                      tracks, one merged file (atomic export).
- ``obs.metrics``     counters/gauges/histograms with JSON-lines and
                      Prometheus-textfile export for serving runs.
- ``obs.profile``     MEASURED wall-clock spans: per-(stage, tick)
                      ``MeasuredProfile`` aligned with the telemetry
                      profiles, plus per-kernel-tag attribution riding
                      ``kernels.ops.count_launches(timed=True)``.
- ``obs.calibrate``   least-squares fit of the ``HardwareProfile`` effective
                      rates against measured spans; calibrated-profile JSON
                      accepted by ``lbcp.plan_partition`` /
                      ``chunk_cost_arrays`` / scheduler admission.
- ``obs.health``      runtime sentinels: non-finite activations, telemetry
                      vs analytic drift, SLO burn-rate — one structured
                      alert stream into metrics + trace.

``obs.trace`` / ``obs.metrics`` / ``obs.health`` / ``obs.calibrate`` /
``obs.profile`` are import-light (stdlib/numpy) so scheduler and benchmark
code can depend on them; ``obs.telemetry`` pulls in jax and is imported only
by ``repro.core`` and engine code (``health``/``profile`` reach jax lazily,
inside methods).
"""
from repro.obs.health import Alert, HealthMonitor, slo_burn_rate
from repro.obs.metrics import MetricsRegistry, export_engine_metrics
from repro.obs.profile import MeasuredProfile, TickSpanCollector
from repro.obs.trace import TraceRecorder

__all__ = ["Alert", "HealthMonitor", "MeasuredProfile", "MetricsRegistry",
           "TickSpanCollector", "TraceRecorder", "export_engine_metrics",
           "slo_burn_rate"]
