"""Unified observability: device telemetry, merged traces, metrics export.

Three coordinated surfaces (DESIGN.md §Observability):

- ``obs.telemetry``   the carry-threaded ``StageTelemetry`` pytree charged
                      per (stage, tick) inside the jitted pipeline scan —
                      pool occupancy, resident KV bytes, spill/fetch/qship
                      event counts, attention work units, launch counts.
                      Returned by ``prefill_pipeline(...,
                      return_telemetry=True)`` as ``[N, T]`` profiles.
- ``obs.trace``       the Chrome/Perfetto trace recorder: scheduler task
                      spans + engine wave/tick spans + per-stage counter
                      tracks, one merged file (atomic export).
- ``obs.metrics``     counters/gauges/histograms with JSON-lines and
                      Prometheus-textfile export for serving runs.

``obs.trace`` / ``obs.metrics`` are import-light (stdlib only) so the
scheduler package can depend on them; ``obs.telemetry`` pulls in jax and is
imported only by ``repro.core`` and engine code.
"""
from repro.obs.metrics import MetricsRegistry, export_engine_metrics
from repro.obs.trace import TraceRecorder

__all__ = ["MetricsRegistry", "TraceRecorder", "export_engine_metrics"]
