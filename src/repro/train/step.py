"""Distributed train step: CE loss, grad accumulation, AdamW, FSDP + TP.

Sharding: parameters and optimizer moments follow ``model.param_specs``
(FSDP over "data", Megatron TP over "model"); the batch shards over
``topo.batch_axes`` (("pod","data") on the multi-pod mesh). Remat is inside
the model's scan-over-layers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import Model
from repro.models.topology import Topology
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

TrainState = Dict[str, Any]  # {"params", "opt", ...}


def train_state_specs(model: Model, topo: Topology, *, fsdp: bool = True):
    pspec = model.param_specs(fsdp=fsdp)
    return {
        "params": pspec,
        "opt": {"m": pspec, "v": pspec, "step": P()},
    }


def init_train_state(model: Model, key: jax.Array) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(
    model: Model,
    topo: Optional[Topology],
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    grad_accum: int = 1,
    remat: bool = True,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    batch: {"tokens": [B, S], "labels": [B, S], ("embeds": [B, F, d])}.
    ``grad_accum`` > 1 scans over microbatches (B must divide).
    """
    cfg = model.cfg

    def loss_fn(params, tokens, labels, embeds=None):
        kw = dict(topo=topo, remat=remat)
        if embeds is not None:
            kw["embeds"] = embeds
        return model.loss(params, tokens, labels, **kw)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        tokens, labels = batch["tokens"], batch["labels"]
        embeds = batch.get("embeds")

        if grad_accum == 1:
            (loss, grads) = jax.value_and_grad(loss_fn)(params, tokens, labels, embeds)
        else:
            b = tokens.shape[0]
            assert b % grad_accum == 0
            mb = b // grad_accum

            def micro(carry, idx):
                acc, loss_acc = carry
                sl = lambda x: jax.lax.dynamic_slice_in_dim(x, idx * mb, mb, 0)
                e = sl(embeds) if embeds is not None else None
                l, g = jax.value_and_grad(loss_fn)(params, sl(tokens), sl(labels), e)
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
                return (acc, loss_acc + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zero, jnp.float32(0)), jnp.arange(grad_accum))
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum

        new_params, new_opt, om = adamw_update(opt_cfg, grads, state["opt"], params)
        if topo is not None:
            pspec = model.param_specs(fsdp=True)
            new_params = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                new_params, pspec, is_leaf=lambda x: hasattr(x, "shape"))
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
