from repro.train.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.step import TrainState, make_train_step, train_state_specs
