"""AdamW + LR schedules, hand-rolled (no optax in this container).

Optimizer state is a plain pytree mirroring the params (fp32 moments), so it
shards with the same PartitionSpecs as the parameters (FSDP-friendly) and
checkpoints through ``runtime.checkpoint`` like any other tree.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Params, state: Dict[str, Any],
                 params: Params) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step with global-norm clipping and decoupled weight decay.
    Weight decay is skipped for 1-D tensors (norms, biases)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, state["step"])
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        if p.ndim > 1:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * update
        return p2.astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
