"""Model facade: family dispatch + dry-run input specs.

``build_model(cfg)`` returns a `Model` whose methods are pure functions over
param pytrees; ``model.input_specs(shape)`` returns ShapeDtypeStruct stand-ins
for every model input of that (arch x shape) cell — weak-type-correct,
shardable, no device allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import hybrid, ssm, transformer, whisper
from repro.models import layers as L


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def _mod(self):
        return {
            "dense": transformer, "moe": transformer, "vlm": transformer,
            "ssm": ssm, "hybrid": hybrid, "encdec": whisper,
        }[self.cfg.family]

    # ------------------------------------------------------------- params
    def init(self, key: jax.Array):
        return self._mod.init(self.cfg, key)

    def abstract_params(self, key: Optional[jax.Array] = None):
        """Shape-only params (dry-run: no allocation)."""
        key = key if key is not None else jax.random.key(0)
        return jax.eval_shape(self._mod.init, self.cfg, key)

    def param_specs(self, *, fsdp: bool = True):
        return self._mod.specs(self.cfg, fsdp=fsdp)

    # -------------------------------------------------------------- apply
    def forward(self, params, tokens, **kw):
        return self._mod.forward(self.cfg, params, tokens, **kw)

    def decode_step(self, params, cache, tokens, **kw):
        if self.cfg.family == "ssm":
            return ssm.decode_step(self.cfg, params, cache, tokens, **kw)
        return self._mod.decode_step(self.cfg, params, cache, tokens, **kw)

    def loss(self, params, tokens, labels, **kw):
        logits = self.forward(params, tokens, **kw)
        # VLM/audio prefixes carry no labels: score only the token positions.
        if logits.shape[1] != labels.shape[1]:
            logits = logits[:, logits.shape[1] - labels.shape[1]:]
        return L.cross_entropy(logits, labels, self.cfg.vocab_size)

    # -------------------------------------------------------------- cache
    def init_cache_shape(self, batch: int, max_len: int):
        if self.cfg.family == "ssm":
            return ssm.init_state_shape(self.cfg, batch)
        return self._mod.init_cache_shape(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int):
        sh = self.init_cache_shape(batch, max_len)
        return {k: jnp.zeros(v.shape, v.dtype) for k, v in sh.items()}

    def cache_specs(self, *, batch_axes: Tuple[str, ...], seq_axes: Tuple[str, ...]):
        if self.cfg.family == "ssm":
            return ssm.state_specs(self.cfg, batch_axes=batch_axes)
        return self._mod.cache_specs(self.cfg, batch_axes=batch_axes, seq_axes=seq_axes)

    # ----------------------------------------------------------- dry-run
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for a (this arch x shape) cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        d = cfg.d_model
        tok = jnp.int32
        out: Dict[str, Any] = {}
        n_front = cfg.frontend.num_embeds
        if shape.kind in ("train", "prefill"):
            s_tok = s - n_front if cfg.frontend.kind == "vision_stub" else s
            out["tokens"] = jax.ShapeDtypeStruct((b, s_tok), tok)
            if cfg.frontend.kind == "vision_stub":
                out["embeds"] = jax.ShapeDtypeStruct((b, n_front, d), jnp.bfloat16)
            elif cfg.frontend.kind == "audio_stub":
                out["embeds"] = jax.ShapeDtypeStruct((b, n_front, d), jnp.bfloat16)
            if shape.kind == "train":
                out["labels"] = jax.ShapeDtypeStruct((b, s_tok), tok)
        else:  # decode
            out["tokens"] = jax.ShapeDtypeStruct((b,), tok)
            out["cache"] = self.init_cache_shape(b, s)
        return out

    def input_sharding_specs(self, shape: ShapeConfig, *,
                             batch_axes: Tuple[str, ...],
                             seq_axes: Tuple[str, ...] = ()) -> Dict[str, Any]:
        """PartitionSpecs matching ``input_specs`` leaves."""
        bt = batch_axes if batch_axes else None
        out: Dict[str, Any] = {}
        if shape.kind in ("train", "prefill"):
            out["tokens"] = P(bt, None)
            if self.cfg.frontend.kind in ("vision_stub", "audio_stub"):
                out["embeds"] = P(bt, None, None)
            if shape.kind == "train":
                out["labels"] = P(bt, None)
        else:
            out["tokens"] = P(bt)
            out["cache"] = self.cache_specs(batch_axes=batch_axes, seq_axes=seq_axes)
        return out


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
