"""Topology: the bridge between model code and mesh axes.

Model code never names mesh axes directly; it asks the Topology. A ``None``
topology means "single device, no collectives" (smoke tests, oracles).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Topology:
    mesh: Mesh
    batch_axes: Tuple[str, ...] = ("data",)  # ("pod","data") on the multi-pod mesh
    # single axis ("model") or a split view (("kv","qg")) for collective-free
    # GQA attention (perf variant; see core.pipeline)
    tp_axis: object = "model"
    stage_axis: str = "data"  # chunked-pipeline stages live on this axis

    @property
    def tp_size(self) -> int:
        if isinstance(self.tp_axis, tuple):
            n = 1
            for a in self.tp_axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[self.tp_axis]

    @property
    def num_stages(self) -> int:
        return self.mesh.shape[self.stage_axis]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def batch_spec(self, *rest) -> P:
        return P(self.batch_axes, *rest)

    def divisible(self, n: int, axis: Optional[str] = None) -> bool:
        return n % self.mesh.shape[axis or self.tp_axis] == 0


def single_device_topology() -> Optional[Topology]:
    """Degenerate 1-device topology (tests)."""
    dev = jax.devices()[0]
    mesh = Mesh([[dev]], ("data", "model"))
    return Topology(mesh=mesh)
