"""Whisper-style encoder-decoder backbone. The audio frontend (mel + conv) is
a STUB per the task spec: inputs are precomputed frame embeddings
[B, num_frames, d_model].

MOCAP adaptation (DESIGN.md §4): encoder attention is bidirectional, so the
chunked pipeline (which requires causal chunk independence) applies to the
DECODER prefill; the encoder runs as a single TP pass (1500 frames).

Deviation from the original: RoPE replaces learned/sinusoidal positions (the
backbone-only config is what matters here; noted in DESIGN.md).
"""
from __future__ import annotations

import math
from dataclasses import replace as dc_replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.topology import Topology

Params = Dict[str, Any]


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    return dc_replace(cfg, num_layers=cfg.encdec.enc_layers, family="dense",
                      tie_embeddings=True)


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv, nl = cfg.num_heads, cfg.num_kv_heads, cfg.num_layers
    vpad = L.pad_vocab(cfg.vocab_size)
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc = T.init(_enc_cfg(cfg), k2)
    keys = iter(jax.random.split(k3, 16))

    def nrm(k, *shape, std=0.02):
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dt)

    dec: Params = {
        "ln1": jnp.ones((nl, d), dt),
        "wq": nrm(next(keys), nl, d, h * hd),
        "wk": nrm(next(keys), nl, d, kv * hd),
        "wv": nrm(next(keys), nl, d, kv * hd),
        "wo": nrm(next(keys), nl, h * hd, d, std=0.02 / math.sqrt(2 * nl)),
        "lnx": jnp.ones((nl, d), dt),
        "xwq": nrm(next(keys), nl, d, h * hd),
        "xwk": nrm(next(keys), nl, d, kv * hd),
        "xwv": nrm(next(keys), nl, d, kv * hd),
        "xwo": nrm(next(keys), nl, h * hd, d, std=0.02 / math.sqrt(2 * nl)),
        "ln2": jnp.ones((nl, d), dt),
        "wg": nrm(next(keys), nl, d, cfg.d_ff),
        "wu": nrm(next(keys), nl, d, cfg.d_ff),
        "wd": nrm(next(keys), nl, cfg.d_ff, d, std=0.02 / math.sqrt(2 * nl)),
    }
    return {
        "embed": (jax.random.normal(k1, (vpad, d), jnp.float32) * 0.02).astype(dt),
        "final_norm": jnp.ones((d,), dt),
        "enc_layers": enc["layers"],
        "enc_norm": jnp.ones((d,), dt),
        "dec_layers": dec,
    }


def specs(cfg: ModelConfig, *, fsdp: bool = True) -> Params:
    FD = "data" if fsdp else None
    MD = "model"
    enc = T.specs(_enc_cfg(cfg), fsdp=fsdp)["layers"]
    dec = {
        "ln1": P(None, None), "lnx": P(None, None), "ln2": P(None, None),
        "wq": P(None, FD, MD), "wk": P(None, FD, MD), "wv": P(None, FD, MD),
        "wo": P(None, MD, FD),
        "xwq": P(None, FD, MD), "xwk": P(None, FD, MD), "xwv": P(None, FD, MD),
        "xwo": P(None, MD, FD),
        "wg": P(None, FD, MD), "wu": P(None, FD, MD), "wd": P(None, MD, FD),
    }
    return {
        "embed": P(MD, None), "final_norm": P(None),
        "enc_layers": enc, "enc_norm": P(None), "dec_layers": dec,
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array, *,
           topo=None, impl="xla_flash", remat=True) -> jax.Array:
    """frames [B,F,d] (stub embeddings) -> encoder output [B,F,d]."""
    ecfg = _enc_cfg(cfg)
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(xc, lp):
        xo, _, _ = T.layer_apply(ecfg, lp, xc, causal_offset=None, impl=impl, topo=topo)
        return xo, None

    f = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    x, _ = jax.lax.scan(f, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attn(cfg, lp, x, enc_out=None, xk=None, xv=None):
    """Cross-attention sub-block. Either enc_out (compute kv) or (xk, xv)."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    hn = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", hn, lp["xwq"]).reshape(b, s, h, hd)
    if xk is None:
        f = enc_out.shape[1]
        xk = jnp.einsum("bfd,dq->bfq", enc_out, lp["xwk"]).reshape(b, f, kv, hd)
        xv = jnp.einsum("bfd,dq->bfq", enc_out, lp["xwv"]).reshape(b, f, kv, hd)
    att = L.attention(q, xk, xv, causal_offset=None, impl="naive" if s == 1 else "xla_flash")
    out = jnp.einsum("bsq,qd->bsd", att.reshape(b, s, h * hd), lp["xwo"])
    return x + out, xk, xv


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            embeds=None, topo=None, impl="xla_flash", remat=True,
            return_cache=False):
    """embeds = stub frame embeddings [B,F,d]; tokens = decoder tokens [B,S]."""
    assert embeds is not None, "whisper requires frame embeddings"
    enc_out = encode(cfg, params, embeds, topo=topo, impl=impl, remat=remat)
    x = L.embed_lookup(params["embed"], tokens, topo=topo)

    def body(xc, lp):
        xc, k, v = T.attn_block(cfg, lp, xc, impl=impl, topo=topo)
        xc, xk, xv = _cross_attn(cfg, lp, xc, enc_out=enc_out)
        xc = T.ffn_block(cfg, lp, xc, topo=topo)
        if topo is not None:
            xc = jax.lax.with_sharding_constraint(
                xc, topo.sharding(topo.batch_axes, None, None))
        return xc, (k, v, xk, xv) if return_cache else None

    f = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    x, kvs = jax.lax.scan(f, x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_logits(x, params["embed"].T, topo=topo)
    if return_cache:
        pos = jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)
        return logits, {"k": kvs[0], "v": kvs[1], "xk": kvs[2], "xv": kvs[3], "pos": pos}
    return logits


def init_cache_shape(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    nl, kv = cfg.num_layers, cfg.num_kv_heads
    f = cfg.encdec.num_frames
    return {
        "k": jax.ShapeDtypeStruct((nl, batch, max_len, kv, hd), dt),
        "v": jax.ShapeDtypeStruct((nl, batch, max_len, kv, hd), dt),
        "xk": jax.ShapeDtypeStruct((nl, batch, f, kv, hd), dt),
        "xv": jax.ShapeDtypeStruct((nl, batch, f, kv, hd), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, *, batch_axes, seq_axes) -> Dict[str, P]:
    bt = batch_axes if batch_axes else None
    sq = seq_axes if seq_axes else None
    return {
        "k": P(None, bt, sq, None, None), "v": P(None, bt, sq, None, None),
        "xk": P(None, bt, None, None, None), "xv": P(None, bt, None, None, None),
        "pos": P(bt),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    sh = init_cache_shape(cfg, batch, max_len)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in sh.items()}


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, *, topo: Optional[Topology] = None,
                seq_axes: Tuple[str, ...] = ()):
    b = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed_lookup(params["embed"], tokens[:, None], topo=topo)
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    def body(xc, inp):
        lp, ck, cv, xk, xv = inp
        hn = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", hn, lp["wq"]).reshape(b, 1, h, hd)
        k = jnp.einsum("bsd,dq->bsq", hn, lp["wk"]).reshape(b, 1, kv, hd)
        v = jnp.einsum("bsd,dq->bsq", hn, lp["wv"]).reshape(b, 1, kv, hd)
        cos, sin = L.rope_angles(pos[:, None], hd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        if topo is not None and seq_axes:
            att, ck, cv = T.decode_attn_update(cfg, q, k, v, ck, cv, pos,
                                               topo=topo, seq_axes=seq_axes)
        else:
            ck = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))(ck, k, pos)
            cv = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))(cv, v, pos)
            pv, l, _ = L.decode_attention_local(q, ck, cv, pos + 1)
            att = (pv / jnp.maximum(l, 1e-30).reshape(b, 1, h, 1)).astype(q.dtype)
        xc = xc + jnp.einsum("bsq,qd->bsd", att.reshape(b, 1, h * hd), lp["wo"])
        xc, _, _ = _cross_attn(cfg, lp, xc, xk=xk, xv=xv)
        xc = T.ffn_block(cfg, lp, xc, topo=topo)
        return xc, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_logits(x, params["embed"].T, topo=topo)
    return logits[:, 0], {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"],
                          "pos": pos + 1}
