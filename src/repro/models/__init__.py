from repro.models.api import build_model, Model
