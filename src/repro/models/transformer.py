"""Unified decoder-only transformer: dense (GQA, qk_norm, granite scalars),
MoE, and VLM (embedding splice). Functional: ``init`` builds a stacked-layer
param pytree, ``specs`` builds a matching PartitionSpec pytree, apply fns are
pure and scan over layers.

KV cache layout: dict(k=[L,B,S,K,Dh], v=[L,B,S,K,Dh], pos=[B]).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.topology import Topology

Params = Dict[str, Any]


@dataclass(frozen=True)
class ManualTPApply:
    """Manual-TP hooks for the block fns (DESIGN.md §3.6): which param
    groups arrive SHARDED (so the matching contraction needs ``reduce`` — a
    psum over the manual TP axes, supplied by the caller as a transport
    closure) and, for manual expert parallelism, the mesh axes to derive the
    local expert range from. ``None`` (the default everywhere) is the plain
    single-device / GSPMD path, bit-identical to before."""
    reduce: Callable[[jax.Array], jax.Array]
    attn: bool = False        # wq/wk/wv/wo head-sharded -> psum after wo
    dense: bool = False       # wg/wu/wd f-sharded -> psum after wd
    moe: bool = False         # expert output partial (f- or expert-sharded)
    shared: bool = False      # shared-experts s_w* f-sharded
    ep_axes: Optional[Tuple[str, ...]] = None  # manual EP: slice my experts


def manual_tp_apply(mtp, reduce: Callable[[jax.Array], jax.Array]
                    ) -> ManualTPApply:
    """The ONE mapping from a ``staging.ManualTP`` plan (duck-typed — this
    layer sits below core) to the block-fn hooks; both drivers (stage
    programs and gpipe) build through here so the flag semantics cannot
    drift between them."""
    return ManualTPApply(
        reduce=reduce, attn=mtp.attn, dense=mtp.ffn,
        moe=(mtp.moe_ffn or mtp.moe_ep), shared=mtp.shared_moe,
        ep_axes=(mtp.axes if mtp.moe_ep else None))


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------- init

def init(cfg: ModelConfig, key: jax.Array) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    nl = cfg.num_layers
    vpad = L.pad_vocab(cfg.vocab_size)
    dt = _dtype(cfg)
    keys = iter(jax.random.split(key, 32))

    def nrm(k, *shape, std=None):
        std = std if std is not None else 0.02
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dt)

    lp: Params = {
        "ln1": jnp.ones((nl, d), dt),
        "ln2": jnp.ones((nl, d), dt),
        "wq": nrm(next(keys), nl, d, h * hd),
        "wk": nrm(next(keys), nl, d, kv * hd),
        "wv": nrm(next(keys), nl, d, kv * hd),
        "wo": nrm(next(keys), nl, h * hd, d, std=0.02 / math.sqrt(2 * nl)),
    }
    if cfg.qk_norm:
        lp["q_norm"] = jnp.ones((nl, hd), dt)
        lp["k_norm"] = jnp.ones((nl, hd), dt)
    if cfg.moe is None:
        lp["wg"] = nrm(next(keys), nl, d, cfg.d_ff)
        lp["wu"] = nrm(next(keys), nl, d, cfg.d_ff)
        lp["wd"] = nrm(next(keys), nl, cfg.d_ff, d, std=0.02 / math.sqrt(2 * nl))
    else:
        m = cfg.moe
        fe = m.d_expert or cfg.d_ff
        lp["router"] = nrm(next(keys), nl, d, m.num_experts)
        lp["e_wg"] = nrm(next(keys), nl, m.num_experts, d, fe)
        lp["e_wu"] = nrm(next(keys), nl, m.num_experts, d, fe)
        lp["e_wd"] = nrm(next(keys), nl, m.num_experts, fe, d, std=0.02 / math.sqrt(2 * nl))
        if m.num_shared_experts:
            fs = fe * m.num_shared_experts
            lp["s_wg"] = nrm(next(keys), nl, d, fs)
            lp["s_wu"] = nrm(next(keys), nl, d, fs)
            lp["s_wd"] = nrm(next(keys), nl, fs, d, std=0.02 / math.sqrt(2 * nl))
    params: Params = {
        "embed": nrm(next(keys), vpad, d),
        "final_norm": jnp.ones((d,), dt),
        "layers": lp,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nrm(next(keys), d, vpad)
    return params


def specs(cfg: ModelConfig, *, fsdp: bool = True) -> Params:
    """PartitionSpec tree matching ``init``. TP axis: "model"; FSDP: "data"."""
    FD = "data" if fsdp else None
    MD = "model"
    lp: Params = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, FD, MD),
        "wk": P(None, FD, MD),
        "wv": P(None, FD, MD),
        "wo": P(None, MD, FD),
    }
    if cfg.qk_norm:
        lp["q_norm"] = P(None, None)
        lp["k_norm"] = P(None, None)
    if cfg.moe is None:
        lp["wg"] = P(None, FD, MD)
        lp["wu"] = P(None, FD, MD)
        lp["wd"] = P(None, MD, FD)
    else:
        lp["router"] = P(None, FD, None)
        lp["e_wg"] = P(None, None, FD, MD)
        lp["e_wu"] = P(None, None, FD, MD)
        lp["e_wd"] = P(None, None, MD, FD)
        if cfg.moe.num_shared_experts:
            lp["s_wg"] = P(None, FD, MD)
            lp["s_wu"] = P(None, FD, MD)
            lp["s_wd"] = P(None, MD, FD)
    out: Params = {
        "embed": P(MD, None),
        "final_norm": P(None),
        "layers": lp,
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = P(None, MD)
    return out


# ------------------------------------------------------------------ blocks

def attn_block(cfg: ModelConfig, lp: Params, x: jax.Array, *,
               k_cache=None, v_cache=None, positions=None,
               causal_offset=0, impl="xla_flash",
               topo: Optional[Topology] = None,
               tp: Optional[ManualTPApply] = None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pre-norm attention block. Returns (residual_out, k, v) where k/v are the
    NEW keys/values of these positions (for caching). ``k_cache``/``v_cache``,
    when given, are prepended (chunked prefill against a prefix). Head counts
    come from the param shapes, so under the manual TP lowering (``tp``)
    this computes the LOCAL heads and psums the o-projection."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", hn, lp["wq"])
    k = jnp.einsum("bsd,dq->bsq", hn, lp["wk"])
    v = jnp.einsum("bsd,dq->bsq", hn, lp["wv"])
    q = q.reshape(b, s, q.shape[-1] // hd, hd)
    k = k.reshape(b, s, k.shape[-1] // hd, hd)
    v = v.reshape(b, s, v.shape[-1] // hd, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(s)[None, :] + (0 if causal_offset is None else causal_offset)
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    if topo is not None and cfg.num_heads % topo.tp_size == 0:
        q = jax.lax.with_sharding_constraint(
            q, topo.sharding(topo.batch_axes, None, topo.tp_axis, None))
    k_all = k if k_cache is None else jnp.concatenate([k_cache, k], axis=1)
    v_all = v if v_cache is None else jnp.concatenate([v_cache, v], axis=1)
    scale = cfg.attention_multiplier or None
    off = None if causal_offset is None else (
        causal_offset if k_cache is None else k_cache.shape[1])
    att = L.attention(q, k_all, v_all, causal_offset=off, scale=scale, impl=impl)
    h_loc = att.shape[2]
    out = jnp.einsum("bsq,qd->bsd", att.reshape(b, s, h_loc * hd), lp["wo"])
    if tp is not None and tp.attn:
        out = tp.reduce(out)
    return x + cfg.residual_multiplier * out, k, v


def ffn_block(cfg: ModelConfig, lp: Params, x: jax.Array, *,
              topo: Optional[Topology] = None, ep_axis=None,
              tp: Optional[ManualTPApply] = None) -> jax.Array:
    """FFN / MoE block. Under manual TP (``tp``) the SHARDED parts (per the
    flags) are summed and reduced with ONE psum; unsharded parts add after
    the reduce so replication is never double-counted."""
    hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    partial, replicated = None, None

    def acc(out, sharded):
        nonlocal partial, replicated
        if tp is not None and sharded:
            partial = out if partial is None else partial + out
        else:
            replicated = out if replicated is None else replicated + out

    if cfg.moe is None:
        acc(L.swiglu({"wg": lp["wg"], "wu": lp["wu"], "wd": lp["wd"]}, hn),
            tp is not None and tp.dense)
    else:
        m = cfg.moe
        acc(L.moe_layer(
            {"router": lp["router"], "wg": lp["e_wg"], "wu": lp["e_wu"],
             "wd": lp["e_wd"]},
            hn, num_experts=m.num_experts, top_k=m.top_k,
            capacity_factor=m.capacity_factor, topo=topo,
            num_real=m.real_experts, ep_axis=ep_axis,
            ep_axes=tp.ep_axes if tp is not None else None),
            tp is not None and tp.moe)
        if m.num_shared_experts:
            acc(L.swiglu({"wg": lp["s_wg"], "wu": lp["s_wu"],
                          "wd": lp["s_wd"]}, hn),
                tp is not None and tp.shared)
    out = replicated
    if partial is not None:
        out = tp.reduce(partial) if out is None else tp.reduce(partial) + out
    assert out is not None, "ffn_block produced no parts"
    return x + cfg.residual_multiplier * out


def layer_apply(cfg: ModelConfig, lp: Params, x: jax.Array, *,
                k_cache=None, v_cache=None, positions=None, causal_offset=0,
                impl="xla_flash", topo=None, tp=None):
    x, k, v = attn_block(cfg, lp, x, k_cache=k_cache, v_cache=v_cache,
                         positions=positions, causal_offset=causal_offset,
                         impl=impl, topo=topo, tp=tp)
    x = ffn_block(cfg, lp, x, topo=topo, tp=tp)
    return x, k, v


# ----------------------------------------------------------------- forward

def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
                 embeds: Optional[jax.Array] = None, topo=None) -> jax.Array:
    """tokens [B,St]; embeds [B,Si,d] (VLM/audio stub) spliced in FRONT."""
    x = L.embed_lookup(params["embed"], tokens, topo=topo)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    if cfg.embedding_multiplier != 1.0:
        x = x * cfg.embedding_multiplier
    return x


def logits_head(cfg: ModelConfig, params: Params, x: jax.Array, *, topo=None):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return L.unembed_logits(x, w, topo=topo, scale=cfg.logits_scaling)


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            embeds=None, topo=None, impl="xla_flash", remat=True,
            return_cache=False):
    """Full-sequence forward (training / baseline prefill).
    Returns logits [B,S,Vpad] (fp32, vocab-sharded); with ``return_cache``
    also returns dict(k=[L,B,S,K,Dh], v=..., pos=[B])."""
    x = embed_tokens(cfg, params, tokens, embeds=embeds, topo=topo)

    def body(xc, lp):
        xo, k, v = layer_apply(cfg, lp, xc, impl=impl, topo=topo)
        if topo is not None:
            xo = jax.lax.with_sharding_constraint(
                xo, topo.sharding(topo.batch_axes, None, None))
        return xo, (k, v) if return_cache else None

    f = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    x, kvs = jax.lax.scan(f, x, params["layers"])
    logits = logits_head(cfg, params, x, topo=topo)
    if return_cache:
        pos = jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)
        return logits, {"k": kvs[0], "v": kvs[1], "pos": pos}
    return logits


# ------------------------------------------------------------------ decode

def init_cache_shape(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStructs for the KV cache (dry-run) + sharding spec builder."""
    hd = cfg.resolved_head_dim
    dt = _dtype(cfg)
    return {
        "k": jax.ShapeDtypeStruct((cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd), dt),
        "v": jax.ShapeDtypeStruct((cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, *, batch_axes, seq_axes) -> Dict[str, P]:
    kvspec = P(None, batch_axes if batch_axes else None, seq_axes if seq_axes else None, None, None)
    return {"k": kvspec, "v": kvspec, "pos": P(batch_axes if batch_axes else None)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    sh = init_cache_shape(cfg, batch, max_len)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in sh.items()}


def decode_attn_update(cfg, q, k_new, v_new, ck, cv, pos, *, topo,
                        seq_axes: Tuple[str, ...]):
    """Write (k_new,v_new) at ``pos`` into seq-sharded cache shards and run
    distributed flash decoding. Runs inside shard_map over ``seq_axes``
    (cache seq dim) with batch dims sharded over topo.batch_axes."""
    def local(q, k_new, v_new, ck, cv, pos):
        s_loc = ck.shape[1]
        idx = jnp.int32(0)
        mul = 1
        for ax in reversed(seq_axes):
            idx = idx + jax.lax.axis_index(ax) * mul
            mul = mul * topo.mesh.shape[ax]
        start = idx * s_loc
        # masked single-position write into my shard
        lpos = jnp.clip(pos - start, 0, s_loc - 1)  # [B]
        mine = (pos >= start) & (pos < start + s_loc)

        def write(c, new):
            b = c.shape[0]
            upd = jnp.where(mine[:, None, None, None], new, jnp.take_along_axis(
                c, lpos[:, None, None, None], axis=1))
            return jax.vmap(lambda cb, ub, pb: jax.lax.dynamic_update_slice(
                cb, ub, (pb, 0, 0)))(c, upd, lpos)

        ck = write(ck, k_new)
        cv = write(cv, v_new)
        out = L.decode_attention_seqsharded(q, ck, cv, pos + 1, axis_name=seq_axes,
                                            scale=cfg.attention_multiplier or None)
        return out, ck, cv

    bt = topo.batch_axes
    qspec = P(bt, None, None, None)
    cspec = P(bt, seq_axes, None, None)
    kvnew = P(bt, None, None, None)
    return compat.shard_map(
        local, mesh=topo.mesh,
        in_specs=(qspec, kvnew, kvnew, cspec, cspec, P(bt)),
        out_specs=(qspec, cspec, cspec),
    )(q, k_new, v_new, ck, cv, pos)


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, *, topo: Optional[Topology] = None,
                seq_axes: Tuple[str, ...] = ()):
    """One-token decode. tokens [B] int32. Returns (logits [B,Vpad], cache)."""
    b = tokens.shape[0]
    pos = cache["pos"]  # [B]
    x = embed_tokens(cfg, params, tokens[:, None], topo=topo)
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    def body(xc, layer_in):
        lp, ck, cv = layer_in
        hn = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", hn, lp["wq"]).reshape(b, 1, h, hd)
        k = jnp.einsum("bsd,dq->bsq", hn, lp["wk"]).reshape(b, 1, kv, hd)
        v = jnp.einsum("bsd,dq->bsq", hn, lp["wv"]).reshape(b, 1, kv, hd)
        if cfg.qk_norm:
            q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
        cos, sin = L.rope_angles(pos[:, None], hd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        if topo is not None and seq_axes:
            att, ck, cv = decode_attn_update(cfg, q, k, v, ck, cv, pos,
                                              topo=topo, seq_axes=seq_axes)
        else:
            ck = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))(
                ck, k, pos)
            cv = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))(
                cv, v, pos)
            pv, l, _ = L.decode_attention_local(q, ck, cv, pos + 1,
                                                scale=cfg.attention_multiplier or None)
            att = (pv / jnp.maximum(l, 1e-30)[:, :, None].reshape(b, 1, h, 1)).astype(q.dtype)
        out = jnp.einsum("bsq,qd->bsd", att.reshape(b, 1, h * hd), lp["wo"])
        xc = xc + cfg.residual_multiplier * out
        xc = ffn_block(cfg, lp, xc, topo=topo)
        return xc, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    logits = logits_head(cfg, params, x, topo=topo)
    new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    return logits[:, 0], new_cache
