"""Mamba2 (SSD — state-space duality) in pure JAX.

The chunked SSD algorithm here is also the oracle for the Pallas kernel in
``repro.kernels.ssd``. State layout per layer:
  dict(conv=[B, K-1, conv_ch], ssd=[B, H, P, N], pos=[B])
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.topology import Topology

Params = Dict[str, Any]


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, nheads, conv_ch


# ----------------------------------------------------------------- SSD core

def segsum(x: jax.Array) -> jax.Array:
    """x [..., T] -> [..., T, T] with out[i,j] = sum_{k=j+1..i} x[k], -inf for j>i."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, d_skip, *, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan (Mamba2 alg. 1 "minimal").

    x [B,T,H,P]; dt [B,T,H] (post-softplus); a_log [H]; b,c [B,T,G,N];
    d_skip [H]. Returns y [B,T,H,P], final_state [B,H,P,N].
    """
    bs, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    a = -jnp.exp(a_log.astype(jnp.float32))  # [H] negative
    da = dt.astype(jnp.float32) * a  # [B,T,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    def rs(z, extra_dims):
        return z.reshape((bs, nc, chunk) + extra_dims)

    xc = rs(xdt, (h, p))
    dac = rs(da, (h,)).transpose(0, 1, 3, 2)  # [B,nc,H,Q]
    bc = rs(b.astype(jnp.float32), (g, n))
    cc = rs(c.astype(jnp.float32), (g, n))
    bh = jnp.repeat(bc, hg, axis=3)  # groups -> heads: [B,nc,Q,H,N]
    ch = jnp.repeat(cc, hg, axis=3)
    # intra-chunk ("diagonal") term
    lmat = jnp.exp(segsum(dac))  # [B,nc,H,Q,Q]
    cb = jnp.einsum("bcqhn,bckhn->bchqk", ch, bh)
    scores = cb * lmat
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)
    # chunk states: decay from position q to the END of the chunk is
    # exp(cumsum(da)[-1] - cumsum(da)[q])  (Mamba2 Alg. 1 `decay_states`)
    dac_cs = jnp.cumsum(dac, axis=-1)  # [B,nc,H,Q]
    decay_out = jnp.exp(dac_cs[..., -1:] - dac_cs)  # [B,nc,H,Q]
    states = jnp.einsum("bchq,bcqhn,bcqhp->bchpn", decay_out, bh, xc)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(dac_cs[..., -1])  # [B,nc,H] total decay
    s0 = jnp.zeros((bs, h, p, n), jnp.float32) if init_state is None else init_state.astype(jnp.float32)

    def scan_body(carry, inp):
        st_prev = carry
        dec, st_c = inp  # dec [B,H], st_c [B,H,P,N]
        st = st_prev * dec[:, :, None, None] + st_c
        return st, st_prev

    dec_t = chunk_decay.transpose(1, 0, 2)  # [nc,B,H]
    st_t = states.transpose(1, 0, 2, 3, 4)  # [nc,B,H,P,N]
    final_state, prev_states = jax.lax.scan(scan_body, s0, (dec_t, st_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]
    # inter-chunk ("off-diagonal") output
    state_decay_in = jnp.exp(dac_cs)  # [B,nc,H,Q]
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", ch, prev_states, state_decay_in)
    y = (y_diag + y_off).reshape(bs, nc * chunk, h, p)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    if pad:
        y = y[:, :t]
    return y.astype(x.dtype), final_state


def _ssd_pallas(x, dt, a_log, b, c, d_skip, *, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Pallas SSD kernel behind the ``ssm_backend`` knob (interpret mode
    off-TPU, Mosaic on TPU); same signature/semantics as ``ssd_chunked``."""
    from repro.kernels import ops
    return ops.ssd(x, dt, a_log, b, c, d_skip, chunk=chunk,
                   init_state=init_state)


# SSD inner-loop registry (the ssm/hybrid analogue of the attention-backend
# registry): selected per-plan via ``RunConfig.ssm_backend``.
SSD_IMPLS = {"jnp": ssd_chunked, "pallas": _ssd_pallas}


def ssd_decode_step(x, dt, a_log, b, c, d_skip, state):
    """Single-token SSD update. x [B,H,P]; dt [B,H]; b,c [B,G,N]; state [B,H,P,N]."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dec = jnp.exp(dt.astype(jnp.float32) * a)  # [B,H]
    g = b.shape[1]
    h = x.shape[1]
    bh = jnp.repeat(b.astype(jnp.float32), h // g, axis=1)  # [B,H,N]
    ch = jnp.repeat(c.astype(jnp.float32), h // g, axis=1)
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]  # [B,H,P]
    new_state = state * dec[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", xdt, bh)
    y = jnp.einsum("bhn,bhpn->bhp", ch, new_state)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), new_state


# ------------------------------------------------------------------- conv1d

def causal_conv(x, w, bias, *, init_state=None):
    """Depthwise causal conv. x [B,T,C]; w [K,C]; returns (y, last K-1 inputs)."""
    k = w.shape[0]
    if init_state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    tail = xp[:, xp.shape[1] - (k - 1):, :]
    return y + bias, tail


def causal_conv_step(x, w, bias, conv_state):
    """x [B,C]; conv_state [B,K-1,C] -> (y [B,C], new_state)."""
    k = w.shape[0]
    full = jnp.concatenate([conv_state.astype(x.dtype), x[:, None, :]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", full, w) + bias
    return y, full[:, 1:]


# ------------------------------------------------------------------- block

def init_block(cfg: ModelConfig, key: jax.Array, nl: int) -> Params:
    d = cfg.d_model
    s = cfg.ssm
    d_in, nheads, conv_ch = dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = iter(jax.random.split(key, 8))

    def nrm(k, *shape, std=0.02):
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dt)

    dt_init = jnp.exp(jax.random.uniform(next(ks), (nl, nheads)) *
                      (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "ln": jnp.ones((nl, d), dt),
        "in_proj": nrm(next(ks), nl, d, 2 * d_in + 2 * s.n_groups * s.d_state + nheads),
        "conv_w": nrm(next(ks), nl, s.conv_kernel, conv_ch, std=0.2),
        "conv_b": jnp.zeros((nl, conv_ch), dt),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, nheads + 1, dtype=jnp.float32), (nl, 1))),
        "dt_bias": dt_bias.astype(jnp.float32),
        "d_skip": jnp.ones((nl, nheads), jnp.float32),
        "gate_norm": jnp.ones((nl, d_in), dt),
        "out_proj": nrm(next(ks), nl, d_in, d, std=0.02 / math.sqrt(2 * nl)),
    }


def block_specs(cfg: ModelConfig, *, fsdp: bool = True) -> Params:
    FD = "data" if fsdp else None
    d_in, nheads, conv_ch = dims(cfg)
    tp_ok = "model" if d_in % 16 == 0 else None  # head-dim TP when divisible
    return {
        "ln": P(None, None),
        "in_proj": P(None, FD, None),
        "conv_w": P(None, None, None),
        "conv_b": P(None, None),
        "a_log": P(None, None),
        "dt_bias": P(None, None),
        "d_skip": P(None, None),
        "gate_norm": P(None, None),
        "out_proj": P(None, tp_ok, FD),
    }


def block_apply(cfg: ModelConfig, lp: Params, x: jax.Array, *,
                state: Optional[Dict[str, jax.Array]] = None,
                topo: Optional[Topology] = None, ssd_impl: str = "jnp"):
    """Mamba2 block over a (chunk of a) sequence. Returns (y, new_state).
    ``ssd_impl`` picks the SSD inner loop from ``SSD_IMPLS``."""
    b, t, d = x.shape
    s = cfg.ssm
    d_in, nheads, conv_ch = dims(cfg)
    hn = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("btd,de->bte", hn, lp["in_proj"])
    z, xbc, dtv = jnp.split(zxbcdt, [d_in, d_in + conv_ch], axis=-1)
    conv_init = None if state is None else state["conv"]
    xbc, conv_tail = causal_conv(xbc, lp["conv_w"], lp["conv_b"], init_state=conv_init)
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    xh = xs.reshape(b, t, nheads, s.head_dim)
    bmat = bmat.reshape(b, t, s.n_groups, s.d_state)
    cmat = cmat.reshape(b, t, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + lp["dt_bias"])  # [B,T,H]
    ssd_init = None if state is None else state["ssd"]
    if ssd_impl not in SSD_IMPLS:
        raise KeyError(f"unknown ssm backend {ssd_impl!r}; "
                       f"registered: {sorted(SSD_IMPLS)}")
    y, new_ssd = SSD_IMPLS[ssd_impl](xh, dtv, lp["a_log"], bmat, cmat,
                                     lp["d_skip"], chunk=s.chunk_size,
                                     init_state=ssd_init)
    y = y.reshape(b, t, d_in)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   lp["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, lp["out_proj"])
    new_state = {"conv": conv_tail.astype(jnp.float32), "ssd": new_ssd}
    return x + out, new_state


def block_decode(cfg: ModelConfig, lp: Params, x: jax.Array, state):
    """x [B,1,d] single-token decode."""
    b = x.shape[0]
    s = cfg.ssm
    d_in, nheads, conv_ch = dims(cfg)
    hn = L.rms_norm(x[:, 0], lp["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bd,de->be", hn, lp["in_proj"])
    z, xbc, dtv = jnp.split(zxbcdt, [d_in, d_in + conv_ch], axis=-1)
    xbc, conv_state = causal_conv_step(xbc, lp["conv_w"], lp["conv_b"], state["conv"])
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], axis=-1)
    xh = xs.reshape(b, nheads, s.head_dim)
    bmat = bmat.reshape(b, s.n_groups, s.d_state)
    cmat = cmat.reshape(b, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + lp["dt_bias"])
    y, new_ssd = ssd_decode_step(xh, dtv, lp["a_log"], bmat, cmat, lp["d_skip"], state["ssd"])
    y = y.reshape(b, d_in)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   lp["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, lp["out_proj"])
    return x + out[:, None], {"conv": conv_state.astype(jnp.float32), "ssd": new_ssd}


# ---------------------------------------------------------------- LM wiring

def init(cfg: ModelConfig, key: jax.Array) -> Params:
    k1, k2 = jax.random.split(key)
    vpad = L.pad_vocab(cfg.vocab_size)
    dt = jnp.dtype(cfg.dtype)
    return {
        "embed": (jax.random.normal(k1, (vpad, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "layers": init_block(cfg, k2, cfg.num_layers),
    }


def specs(cfg: ModelConfig, *, fsdp: bool = True) -> Params:
    return {
        "embed": P("model", None),
        "final_norm": P(None),
        "layers": block_specs(cfg, fsdp=fsdp),
    }


def init_state_shape(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in, nheads, conv_ch = dims(cfg)
    nl = cfg.num_layers
    return {
        "conv": jax.ShapeDtypeStruct((nl, batch, s.conv_kernel - 1, conv_ch), jnp.float32),
        "ssd": jax.ShapeDtypeStruct((nl, batch, nheads, s.head_dim, s.d_state), jnp.float32),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def state_specs(cfg: ModelConfig, *, batch_axes) -> Params:
    bt = batch_axes if batch_axes else None
    return {"conv": P(None, bt, None, None), "ssd": P(None, bt, None, None, None),
            "pos": P(bt)}


def init_state(cfg: ModelConfig, batch: int) -> Params:
    sh = init_state_shape(cfg, batch)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in sh.items()}


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            embeds=None, topo=None, impl="xla_flash", remat=True,
            return_cache=False):
    x = L.embed_lookup(params["embed"], tokens, topo=topo)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)

    def body(xc, lp):
        xo, st = block_apply(cfg, lp, xc, topo=topo)
        if topo is not None:
            xo = jax.lax.with_sharding_constraint(
                xo, topo.sharding(topo.batch_axes, None, None))
        return xo, st if return_cache else None

    f = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    x, sts = jax.lax.scan(f, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_logits(x, params["embed"].T, topo=topo)
    if return_cache:
        pos = jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)
        return logits, {"conv": sts["conv"], "ssd": sts["ssd"], "pos": pos}
    return logits


def decode_step(cfg: ModelConfig, params: Params, state: Params,
                tokens: jax.Array, *, topo=None, seq_axes=()):
    x = L.embed_lookup(params["embed"], tokens[:, None], topo=topo)

    def body(xc, inp):
        lp, st = inp
        xo, st2 = block_decode(cfg, lp, xc, st)
        return xo, st2

    x, new_st = jax.lax.scan(
        body, x, (params["layers"], {"conv": state["conv"], "ssd": state["ssd"]}))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_logits(x, params["embed"].T, topo=topo)
    return logits[:, 0], {"conv": new_st["conv"], "ssd": new_st["ssd"],
                          "pos": state["pos"] + 1}
