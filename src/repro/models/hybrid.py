"""Zamba2-style hybrid: Mamba2 backbone with a SHARED attention block applied
once per group of SSM layers (same weights each application, separate KV).

Layer structure (cfg.hybrid): num_groups x (ssm_per_group Mamba2 + 1 shared
attn+FFN application) + tail_ssm_layers Mamba2.

Cache: dict(k=[G,B,S,K,Dh], v=..., g_conv=[G,pg,B,Kc-1,C], g_ssd=[G,pg,B,H,P,N],
            t_conv=[tail,...], t_ssd=[...], pos=[B]).
Only the attention KV participates in MBKR (the SSM state is O(1)/layer).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.topology import Topology

Params = Dict[str, Any]


def _shared_cfg_layers(cfg: ModelConfig, key) -> Params:
    """Single (non-stacked) attention+FFN block params, via transformer init."""
    p = T.init(T_single_cfg(cfg), key)["layers"]
    return jax.tree.map(lambda a: a[0], p)  # drop layer dim


def T_single_cfg(cfg: ModelConfig) -> ModelConfig:
    from dataclasses import replace
    return replace(cfg, num_layers=1, moe=None, family="dense")


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    h = cfg.hybrid
    k1, k2, k3, k4 = jax.random.split(key, 4)
    vpad = L.pad_vocab(cfg.vocab_size)
    dt = jnp.dtype(cfg.dtype)
    n_grouped = h.num_groups * h.ssm_per_group
    g_params = S.init_block(cfg, k2, n_grouped)
    g_params = jax.tree.map(
        lambda a: a.reshape((h.num_groups, h.ssm_per_group) + a.shape[1:]), g_params)
    return {
        "embed": (jax.random.normal(k1, (vpad, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "mamba_groups": g_params,
        "mamba_tail": S.init_block(cfg, k3, h.tail_ssm_layers),
        "shared": _shared_cfg_layers(cfg, k4),
    }


def specs(cfg: ModelConfig, *, fsdp: bool = True) -> Params:
    bs = S.block_specs(cfg, fsdp=fsdp)
    g_specs = jax.tree.map(lambda p: P(None, *p), bs, is_leaf=lambda x: isinstance(x, P))
    shared = jax.tree.map(lambda p: P(*p[1:]),
                          T.specs(T_single_cfg(cfg), fsdp=fsdp)["layers"],
                          is_leaf=lambda x: isinstance(x, P))
    return {
        "embed": P("model", None),
        "final_norm": P(None),
        "mamba_groups": g_specs,
        "mamba_tail": bs,
        "shared": shared,
    }


def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            embeds=None, topo=None, impl="xla_flash", remat=True,
            return_cache=False):
    scfg = T_single_cfg(cfg)
    x = L.embed_lookup(params["embed"], tokens, topo=topo)
    shared = params["shared"]

    def group_body(xc, g_lp):
        def mamba_body(xm, lp):
            xo, st = S.block_apply(cfg, lp, xm, topo=topo)
            return xo, (st if return_cache else None)
        xc, sts = jax.lax.scan(mamba_body, xc, g_lp)
        xc, k, v = T.attn_block(scfg, shared, xc, impl=impl, topo=topo)
        xc = T.ffn_block(scfg, shared, xc, topo=topo)
        if topo is not None:
            xc = jax.lax.with_sharding_constraint(
                xc, topo.sharding(topo.batch_axes, None, None))
        return xc, (k, v, sts) if return_cache else None

    gb = jax.checkpoint(group_body, policy=jax.checkpoint_policies.nothing_saveable) if remat else group_body
    x, kvs = jax.lax.scan(gb, x, params["mamba_groups"])

    def tail_body(xm, lp):
        xo, st = S.block_apply(cfg, lp, xm, topo=topo)
        return xo, (st if return_cache else None)

    tb = jax.checkpoint(tail_body, policy=jax.checkpoint_policies.nothing_saveable) if remat else tail_body
    x, t_sts = jax.lax.scan(tb, x, params["mamba_tail"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_logits(x, params["embed"].T, topo=topo)
    if return_cache:
        pos = jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)
        return logits, {"k": kvs[0], "v": kvs[1],
                        "g_conv": kvs[2]["conv"], "g_ssd": kvs[2]["ssd"],
                        "t_conv": t_sts["conv"], "t_ssd": t_sts["ssd"],
                        "pos": pos}
    return logits


# ------------------------------------------------------------------ decode

def init_cache_shape(cfg: ModelConfig, batch: int, max_len: int):
    h = cfg.hybrid
    s = cfg.ssm
    d_in, nheads, conv_ch = S.dims(cfg)
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jax.ShapeDtypeStruct((h.num_groups, batch, max_len, cfg.num_kv_heads, hd), dt),
        "v": jax.ShapeDtypeStruct((h.num_groups, batch, max_len, cfg.num_kv_heads, hd), dt),
        "g_conv": jax.ShapeDtypeStruct((h.num_groups, h.ssm_per_group, batch, s.conv_kernel - 1, conv_ch), jnp.float32),
        "g_ssd": jax.ShapeDtypeStruct((h.num_groups, h.ssm_per_group, batch, nheads, s.head_dim, s.d_state), jnp.float32),
        "t_conv": jax.ShapeDtypeStruct((h.tail_ssm_layers, batch, s.conv_kernel - 1, conv_ch), jnp.float32),
        "t_ssd": jax.ShapeDtypeStruct((h.tail_ssm_layers, batch, nheads, s.head_dim, s.d_state), jnp.float32),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, *, batch_axes, seq_axes) -> Dict[str, P]:
    bt = batch_axes if batch_axes else None
    sq = seq_axes if seq_axes else None
    return {
        "k": P(None, bt, sq, None, None),
        "v": P(None, bt, sq, None, None),
        "g_conv": P(None, None, bt, None, None),
        "g_ssd": P(None, None, bt, None, None, None),
        "t_conv": P(None, bt, None, None),
        "t_ssd": P(None, bt, None, None, None),
        "pos": P(bt),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    sh = init_cache_shape(cfg, batch, max_len)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in sh.items()}


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, *, topo: Optional[Topology] = None,
                seq_axes: Tuple[str, ...] = ()):
    scfg = T_single_cfg(cfg)
    b = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed_lookup(params["embed"], tokens[:, None], topo=topo)
    shared = params["shared"]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    def group_body(xc, inp):
        g_lp, ck, cv, conv_st, ssd_st = inp

        def mamba_body(xm, lp_st):
            lp, cst, sst = lp_st
            xo, st2 = S.block_decode(cfg, lp, xm, {"conv": cst, "ssd": sst})
            return xo, (st2["conv"], st2["ssd"])

        xc, (conv2, ssd2) = jax.lax.scan(mamba_body, xc, (g_lp, conv_st, ssd_st))
        # shared attention (one token)
        hn = L.rms_norm(xc, shared["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dq->bsq", hn, shared["wq"]).reshape(b, 1, h, hd)
        k = jnp.einsum("bsd,dq->bsq", hn, shared["wk"]).reshape(b, 1, kv, hd)
        v = jnp.einsum("bsd,dq->bsq", hn, shared["wv"]).reshape(b, 1, kv, hd)
        cos, sin = L.rope_angles(pos[:, None], hd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        if topo is not None and seq_axes:
            att, ck, cv = T.decode_attn_update(scfg, q, k, v, ck, cv, pos,
                                               topo=topo, seq_axes=seq_axes)
        else:
            ck = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))(ck, k, pos)
            cv = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))(cv, v, pos)
            pv, l, _ = L.decode_attention_local(q, ck, cv, pos + 1)
            att = (pv / jnp.maximum(l, 1e-30).reshape(b, 1, h, 1)).astype(q.dtype)
        out = jnp.einsum("bsq,qd->bsd", att.reshape(b, 1, h * hd), shared["wo"])
        xc = xc + out
        xc = T.ffn_block(scfg, shared, xc, topo=topo)
        return xc, (ck, cv, conv2, ssd2)

    x, (ck, cv, g_conv, g_ssd) = jax.lax.scan(
        group_body, x,
        (params["mamba_groups"], cache["k"], cache["v"], cache["g_conv"], cache["g_ssd"]))

    def tail_body(xm, lp_st):
        lp, cst, sst = lp_st
        xo, st2 = S.block_decode(cfg, lp, xm, {"conv": cst, "ssd": sst})
        return xo, (st2["conv"], st2["ssd"])

    x, (t_conv, t_ssd) = jax.lax.scan(
        tail_body, x, (params["mamba_tail"], cache["t_conv"], cache["t_ssd"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_logits(x, params["embed"].T, topo=topo)
    return logits[:, 0], {
        "k": ck, "v": cv, "g_conv": g_conv, "g_ssd": g_ssd,
        "t_conv": t_conv, "t_ssd": t_ssd, "pos": pos + 1,
    }
