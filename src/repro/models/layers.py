"""Shared neural layers: norms, RoPE, blocked (flash-style) attention, SwiGLU,
MoE, and topology-aware embedding / unembedding.

All functions are pure; parameters are plain dict pytrees. Attention has three
implementations selected by ``attn_impl``:

- ``"naive"``     materialized-scores oracle (tiny shapes, tests)
- ``"xla_flash"`` blocked online-softmax via ``lax.scan`` over KV blocks —
                  lowers on every backend with bounded memory; used by the
                  dry-run and by default on CPU
- ``"pallas"``    the TPU Pallas kernel in ``repro.kernels`` (chunked prefix
                  attention), validated in interpret mode on CPU
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.topology import Topology

DEFAULT_BLOCK_K = 1024


# ---------------------------------------------------------------- norms / rope

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...,S] -> cos,sin [...,S, head_dim//2] (float32)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B,S,H,D]; cos/sin [B,S,half] or [S,half]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


# ----------------------------------------------------------------- attention

def _gqa_expand(q: jax.Array, num_kv: int) -> jax.Array:
    """[B,S,H,D] -> [B,S,K,G,D] grouped by kv head."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def naive_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal_offset: Optional[int] = 0, scale: Optional[float] = None,
) -> jax.Array:
    """Oracle. q [B,Sq,H,D], k/v [B,Skv,K,D]. ``causal_offset`` is the absolute
    position of q[0] minus the position of k[0] (prefix length). ``None``
    disables masking (bidirectional encoder)."""
    b, sq, h, d = q.shape
    kheads = k.shape[2]
    scale = scale or (1.0 / math.sqrt(d))
    qg = _gqa_expand(q, kheads)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32) * scale
    if causal_offset is not None:
        qpos = jnp.arange(sq)[:, None] + causal_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        scores = jnp.where(kpos <= qpos, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def flash_attention_xla(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal_offset: Optional[int] = 0, scale: Optional[float] = None,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Blocked online-softmax attention (scan over KV blocks). Memory is
    O(Sq * block_k) instead of O(Sq * Skv)."""
    b, sq, h, d = q.shape
    skv, kheads = k.shape[1], k.shape[2]
    if skv <= block_k:
        return naive_attention(q, k, v, causal_offset=causal_offset, scale=scale)
    scale = scale or (1.0 / math.sqrt(d))
    nblk = -(-skv // block_k)
    pad = nblk * block_k - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block_k, kheads, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block_k, kheads, d).transpose(1, 0, 2, 3, 4)
    qg = _gqa_expand(q, kheads)  # [B,Sq,K,G,D]
    qpos = jnp.arange(sq)[:, None] + (0 if causal_offset is None else causal_offset)

    def body(carry, blk):
        m, l, acc, j = carry
        kj, vj = blk  # [B,blk,K,D]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj, preferred_element_type=jnp.float32) * scale
        kpos = j * block_k + jnp.arange(block_k)[None, :]
        valid = kpos < skv
        if causal_offset is not None:
            valid = jnp.logical_and(valid, kpos <= qpos)
        s = jnp.where(valid[None, None, None, :, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj, preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, j + 1), None

    g = h // kheads
    m0 = jnp.full((b, kheads, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kheads, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kheads, g, sq, d), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def attention(q, k, v, *, causal_offset=0, scale=None, impl="xla_flash", block_k=DEFAULT_BLOCK_K):
    if impl == "naive":
        return naive_attention(q, k, v, causal_offset=causal_offset, scale=scale)
    if impl == "xla_flash":
        return flash_attention_xla(q, k, v, causal_offset=causal_offset, scale=scale, block_k=block_k)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.chunk_attention(q, k, v, causal_offset=causal_offset, scale=scale)
    raise ValueError(f"unknown attention impl {impl!r}")


# --------------------------------------------------- distributed decode attn

def decode_attention_local(q, k, v, kv_len, *, scale=None):
    """One-token decode against a cache. q [B,1,H,D]; k/v [B,Smax,K,D];
    kv_len [B] valid lengths. Returns ([B,1,H,D], lse [B,H], m [B,H])."""
    b, _, h, d = q.shape
    kheads = k.shape[2]
    scale = scale or (1.0 / math.sqrt(d))
    qg = _gqa_expand(q, kheads)[:, 0]  # [B,K,G,D]
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k, preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(k.shape[1])[None, :] < kv_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    l = p.sum(axis=-1)
    pv = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    return pv.reshape(b, 1, h, d), l.reshape(b, h), m_safe.reshape(b, h)


def decode_attention_seqsharded(q, k, v, kv_len, *, axis_name, scale=None):
    """Flash-decoding across chips: the cache's SEQ dim is sharded over
    ``axis_name``; combine partial softmax stats with psums. Must run inside
    shard_map. k/v are the LOCAL seq shards; kv_len is the GLOBAL length."""
    b, _, h, d = q.shape
    s_loc = k.shape[1]
    idx = jax.lax.axis_index(axis_name)
    start = idx * s_loc
    local_len = jnp.clip(kv_len - start, 0, s_loc)
    pv, l, m = decode_attention_local(q, k, v, local_len, scale=scale)
    # combine: global max, rescale
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    # fully-masked local shard -> l == 0, pv == 0; corr finite
    l_glob = jax.lax.psum(l * corr, axis_name)
    pv_glob = jax.lax.psum(pv * corr[:, None, :, None], axis_name)
    return (pv_glob / jnp.maximum(l_glob, 1e-30)[:, None, :, None]).astype(q.dtype)


# ------------------------------------------------------------------- mlp/moe

def swiglu(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["wg"])
    u = jnp.einsum("bsd,df->bsf", x, params["wu"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params["wd"])


def moe_layer(params, x, *, num_experts: int, top_k: int, capacity_factor: float,
              topo: Optional[Topology] = None, num_real: int = 0,
              ep_axis=None, ep_axes=None):
    """Token-choice top-k MoE with per-example capacity-bounded sort dispatch.

    Dispatch is vmapped over the batch dim so token sorts never cross data
    shards. Three layouts:
      - default: expert FFNs TENSOR-parallel over the TP axis;
      - ``ep_axis``: EXPERT-parallel via GSPMD — the dispatched [B,E,cap,*]
        tensors are E-sharded so expert FFNs are chip-local and the only
        collective is the [B,S,d] psum at combine (experts zero-padded to
        the axis size, ``num_real`` masks their router logits — bit-exact);
      - ``ep_axes``: MANUAL expert parallelism (DESIGN.md §3.6) — the
        expert params arrive pre-sliced (``wg.shape[0]`` local experts per
        chip, kv-major over the named manual mesh axes); the full dispatch
        is computed replicated, MY expert rows are sliced out by axis index,
        and the returned [B,S,d] is the PARTIAL combine — the caller psums.
    x: [B,S,d]. params: router [d,E], wg/wu [E,d,f], wd [E,f,d].
    """
    b, s, d = x.shape
    e, k = num_experts, top_k
    n_real = num_real or e
    cap = max(int(math.ceil(s * k / n_real * capacity_factor)), k)
    logits = jnp.einsum("bsd,de->bse", x, params["router"], preferred_element_type=jnp.float32)
    if n_real < e:  # padded experts are never routable
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        logits = jnp.where(iota < n_real, logits, -1e30)
    weights, choices = jax.lax.top_k(logits, k)  # [B,S,k]
    weights = jax.nn.softmax(weights, axis=-1)

    def dispatch_one(xe, choice, w):
        # xe [S,d], choice [S,k], w [S,k]
        flat_e = choice.reshape(-1)  # [S*k]
        flat_tok = jnp.repeat(jnp.arange(s), k)
        flat_w = w.reshape(-1)
        # position of each (token,slot) within its expert, by token order
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        # rank within equal-expert runs
        same = jnp.concatenate([jnp.array([0], sorted_e.dtype), (sorted_e[1:] == sorted_e[:-1]).astype(sorted_e.dtype)])
        seg_start = jnp.where(same == 0, jnp.arange(s * k), 0)
        run_start = jax.lax.associative_scan(jnp.maximum, seg_start)
        rank = jnp.arange(s * k) - run_start
        # scatter token ids into [E, cap]
        keep = rank < cap
        e_idx = jnp.where(keep, sorted_e, e)  # drops -> row e (discarded)
        r_idx = jnp.where(keep, rank, 0)
        slots_tok = jnp.zeros((e + 1, cap), jnp.int32).at[e_idx, r_idx].set(
            flat_tok[order].astype(jnp.int32), mode="drop")
        slots_valid = jnp.zeros((e + 1, cap), jnp.bool_).at[e_idx, r_idx].set(True, mode="drop")
        slots_w = jnp.zeros((e + 1, cap), jnp.float32).at[e_idx, r_idx].set(flat_w[order], mode="drop")
        xd = xe[slots_tok[:e]] * slots_valid[:e, :, None].astype(xe.dtype)  # [E,cap,d]
        return xd, slots_tok[:e], slots_valid[:e], slots_w[:e]

    xd, tok, valid, wgt = jax.vmap(dispatch_one)(x, choices, weights)  # [B,E,cap,...]
    if ep_axes is not None:
        # manual EP: slice MY contiguous expert block (kv-major flat index
        # over the named axes, matching the P(..., axes, ...) layout)
        e_loc = params["wg"].shape[0]
        idx = jnp.int32(0)
        for a in ep_axes:
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        off = idx * e_loc
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, off, e_loc, axis=1)
        xd, tok, valid, wgt = sl(xd), sl(tok), sl(valid), sl(wgt)
    if ep_axis is not None:
        ep = P(None, ep_axis, None, None)
        xd = jax.lax.with_sharding_constraint(xd, ep)
    g = jnp.einsum("becd,edf->becf", xd, params["wg"])
    u = jnp.einsum("becd,edf->becf", xd, params["wu"])
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, params["wd"])
    if ep_axis is not None:
        y = jax.lax.with_sharding_constraint(y, P(None, ep_axis, None, None))
    if topo is not None:
        y = jax.lax.with_sharding_constraint(
            y, topo.sharding(topo.batch_axes, None, None, None))
    y = y * (wgt * valid)[..., None].astype(y.dtype)

    def combine_one(ye, tok_e, valid_e):
        out = jnp.zeros((s, d), ye.dtype)
        return out.at[tok_e.reshape(-1)].add(
            ye.reshape(-1, d) * valid_e.reshape(-1, 1).astype(ye.dtype))

    return jax.vmap(combine_one)(y, tok, valid).astype(x.dtype)


# --------------------------------------------------------- embed / unembed

def pad_vocab(v: int, multiple: int = 128) -> int:
    return -(-v // multiple) * multiple


def embed_lookup(table: jax.Array, tokens: jax.Array, *, topo: Optional[Topology] = None):
    """table [Vpad, d] (vocab-sharded over TP), tokens [B,S] int32."""
    if topo is None or topo.tp_size == 1:
        return jnp.take(table, tokens, axis=0)
    vpad, dm = table.shape
    tp = topo.tp_size

    def local(tab, tok):
        vloc = tab.shape[0]
        off = jax.lax.axis_index(topo.tp_axis) * vloc
        li = tok - off
        ok = (li >= 0) & (li < vloc)
        vec = jnp.take(tab, jnp.clip(li, 0, vloc - 1), axis=0)
        vec = jnp.where(ok[..., None], vec, 0)
        return jax.lax.psum(vec, topo.tp_axis)

    return compat.shard_map(
        local, mesh=topo.mesh,
        in_specs=(P(topo.tp_axis, None), topo.batch_spec(None)),
        out_specs=topo.batch_spec(None, None),
    )(table, tokens)


def unembed_logits(x: jax.Array, w: jax.Array, *, topo: Optional[Topology] = None,
                   scale: float = 1.0):
    """x [B,S,d] @ w [d,Vpad] -> fp32 logits, vocab-sharded over TP."""
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    if scale != 1.0:
        logits = logits / scale
    if topo is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, topo.sharding(topo.batch_axes, None, topo.tp_axis))
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int):
    """Fused CE over (possibly padded + vocab-sharded) logits.
    logits [B,S,Vpad] fp32; labels [B,S]. Pads masked to -inf via iota compare.
    Returns mean loss."""
    vpad = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    logits = jnp.where(iota < vocab_size, logits, -jnp.inf)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    return jnp.mean(lse - true_logit)
