"""Deterministic synthetic LM data pipeline: seeded, shardable, resumable.

Every batch is a pure function of (seed, step, shard) — a restart from a
checkpointed ``DataState`` reproduces the exact stream, and each data-parallel
shard draws only its slice (no host ever materializes the global batch).

The token stream is structured (Zipf unigrams + a Markov backbone + repeated
motifs) so that a model trained on it shows a real, decreasing loss curve —
enough signal for the end-to-end training example without external data.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator

import numpy as np


@dataclass
class DataState:
    seed: int
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d) -> "DataState":
        return DataState(int(d["seed"]), int(d["step"]))


class SyntheticLM:
    """Sharded synthetic next-token-prediction stream.

    Args:
      vocab_size, seq_len: token geometry.
      global_batch: total batch across all shards.
      shard / num_shards: this host's slice of the batch.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, shard: int = 0, num_shards: int = 1,
                 motif_len: int = 16):
        assert global_batch % num_shards == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // num_shards
        self.shard = shard
        self.num_shards = num_shards
        self.motif_len = motif_len
        self.state = DataState(seed)
        # fixed Markov backbone: next ~ (a * cur + b) mod V over a small field,
        # mixed with Zipf noise — cheap, stationary, learnable
        rng = np.random.default_rng(seed)
        self._a = int(rng.integers(2, 64))
        self._b = int(rng.integers(1, vocab_size))
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._zipf = p / p.sum()

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step, self.shard]))

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._batch_rng(self.state.step)
        b, s, v = self.local_batch, self.seq, self.vocab
        noise = rng.choice(v, size=(b, s), p=self._zipf).astype(np.int64)
        toks = np.empty((b, s), np.int64)
        toks[:, 0] = noise[:, 0]
        use_markov = rng.random((b, s)) < 0.7
        for t in range(1, s):
            markov = (self._a * toks[:, t - 1] + self._b) % v
            toks[:, t] = np.where(use_markov[:, t], markov, noise[:, t])
        # splice a repeated motif (teaches copying / induction)
        ml = min(self.motif_len, s // 4)
        if ml > 1:
            starts = rng.integers(0, s // 2 - ml, size=b)
            for i in range(b):
                m0 = starts[i]
                toks[i, m0 + s // 2: m0 + s // 2 + ml] = toks[i, m0: m0 + ml]
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        self.state.step += 1
        return {"tokens": toks.astype(np.int32), "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # ------------------------------------------------------------- resume
    def checkpoint(self) -> Dict[str, int]:
        return self.state.to_dict()

    def restore(self, d) -> None:
        st = DataState.from_dict(d)
        assert st.seed == self.state.seed, "restoring a different stream"
        self.state = st
