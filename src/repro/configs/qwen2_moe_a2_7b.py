"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4, d_expert=1408.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=151936, head_dim=128,
        moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                      d_expert=1408, capacity_factor=1.25),
        rope_theta=1_000_000.0, norm_eps=1e-6,
        source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-moe-a2.7b", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=256, head_dim=16,
        moe=MoEConfig(num_experts=6, top_k=2, num_shared_experts=2,
                      d_expert=96, capacity_factor=1.5),
    )


register("qwen2-moe-a2.7b", full_config, smoke_config)
