"""stablelm-3b [dense] — MHA (kv=32). [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=6912, vocab_size=50304, head_dim=80,
        rope_theta=10000.0, norm_eps=1e-5,
        source="[hf:stabilityai/stablelm-2-1_6b; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="stablelm-3b", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
    )


register("stablelm-3b", full_config, smoke_config)
