"""granite-3-2b [dense] — GQA, granite scalar multipliers.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-3-2b", family="dense",
        num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
        d_ff=8192, vocab_size=49155, head_dim=64,
        tie_embeddings=True,
        embedding_multiplier=12.0, logits_scaling=8.0,
        residual_multiplier=0.22, attention_multiplier=0.015625,
        rope_theta=10000.0, norm_eps=1e-5,
        source="[hf:ibm-granite/granite-3.0-2b-base; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-3-2b", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        tie_embeddings=True,
        embedding_multiplier=12.0, logits_scaling=8.0,
        residual_multiplier=0.22, attention_multiplier=0.25,
    )


register("granite-3-2b", full_config, smoke_config)
