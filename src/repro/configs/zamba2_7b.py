"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; unverified]

81 layers = 13 groups of (5 Mamba2 + 1 application of the SHARED attn+FFN
block) + 3 trailing Mamba2 layers. The attention block's parameters are
shared across all 13 applications (Zamba2's shared-block design).
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000, head_dim=112,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                      n_groups=1, chunk_size=256),
        hybrid=HybridConfig(ssm_per_group=5, num_groups=13, tail_ssm_layers=3),
        rope_theta=10000.0, norm_eps=1e-5,
        source="[arXiv:2411.15242; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-7b", family="hybrid",
        num_layers=7, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                      n_groups=1, chunk_size=32),
        hybrid=HybridConfig(ssm_per_group=2, num_groups=2, tail_ssm_layers=1),
    )


register("zamba2-7b", full_config, smoke_config)
