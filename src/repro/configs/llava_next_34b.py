"""llava-next-34b [vlm] — anyres tiling; vision tower stubbed to patch embeds.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The transformer BACKBONE only: ``input_specs()`` supplies precomputed patch
embeddings (anyres: base 576 patches + 4 tiles x 576 = 2880) which the model
splices in front of the text tokens.
"""
from repro.configs.base import FrontendConfig, ModelConfig, register

NUM_PATCHES = 2880  # anyres: 5 x (336/14)^2


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llava-next-34b", family="vlm",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=20480, vocab_size=64000, head_dim=128,
        frontend=FrontendConfig(kind="vision_stub", num_embeds=NUM_PATCHES),
        rope_theta=5_000_000.0, norm_eps=1e-5,
        source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llava-next-34b", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        frontend=FrontendConfig(kind="vision_stub", num_embeds=8),
    )


register("llava-next-34b", full_config, smoke_config)
