"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=17408, vocab_size=151936, head_dim=128,
        qk_norm=True, rope_theta=1_000_000.0, norm_eps=1e-6,
        source="[hf:Qwen/Qwen3-8B; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-14b", family="dense",
        num_layers=2, d_model=80, num_heads=5, num_kv_heads=1,
        d_ff=160, vocab_size=256, head_dim=16,
        qk_norm=True, rope_theta=1_000_000.0, norm_eps=1e-6,
    )


register("qwen3-14b", full_config, smoke_config)
