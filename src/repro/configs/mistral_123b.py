"""mistral-123b (Mistral-Large-2407) — paper evaluation workload (Fig. 6).
[hf:mistralai/Mistral-Large-Instruct-2407; hf]"""
from repro.configs.base import ModelConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="mistral-123b", family="dense",
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=28672, vocab_size=32768, head_dim=128,
        rope_theta=1_000_000.0, norm_eps=1e-5,
        source="[hf:mistralai/Mistral-Large-Instruct-2407; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="mistral-123b", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
    )


register("mistral-123b", full_config, smoke_config)
