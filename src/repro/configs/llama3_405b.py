"""llama3-405b — paper evaluation workload (Fig. 6). [hf:meta-llama/Llama-3.1-405B; hf]"""
from repro.configs.base import ModelConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llama3-405b", family="dense",
        num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
        d_ff=53248, vocab_size=128256, head_dim=128,
        rope_theta=500000.0, norm_eps=1e-5,
        source="[hf:meta-llama/Llama-3.1-405B; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llama3-405b", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
    )


register("llama3-405b", full_config, smoke_config)
