"""qwen3-235b (Qwen3-235B-A22B, MoE) — paper evaluation workload (Fig. 6).
[hf:Qwen/Qwen3-235B-A22B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-235b", family="moe",
        num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
        d_ff=12288, vocab_size=151936, head_dim=128,
        qk_norm=True,
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536,
                      capacity_factor=1.25),
        rope_theta=1_000_000.0, norm_eps=1e-6,
        source="[hf:Qwen/Qwen3-235B-A22B; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen3-235b", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256, head_dim=16, qk_norm=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=96,
                      capacity_factor=1.5),
    )


register("qwen3-235b", full_config, smoke_config)
