"""granite-moe-3b-a800m [moe] — 40 experts top-8, d_expert=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        tie_embeddings=True,
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512,
                      capacity_factor=1.25),
        rope_theta=10000.0, norm_eps=1e-5,
        source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-moe-3b-a800m", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256, head_dim=16,
        tie_embeddings=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=96,
                      capacity_factor=1.5),
    )


register("granite-moe-3b-a800m", full_config, smoke_config)
