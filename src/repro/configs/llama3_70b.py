"""llama3-70b — paper evaluation workload (Fig. 6). [hf:meta-llama/Meta-Llama-3-70B; hf]"""
from repro.configs.base import ModelConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llama3-70b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256, head_dim=128,
        rope_theta=500000.0, norm_eps=1e-5,
        source="[hf:meta-llama/Meta-Llama-3-70B; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llama3-70b", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
    )


register("llama3-70b", full_config, smoke_config)
