"""Config system: model / shape / mesh / run configs and the arch registry.

Every assigned architecture has one file in this package defining an exact
``ModelConfig`` (`full_config()`) plus a reduced config of the same family
(`smoke_config()`) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # d_ff of each routed expert (may differ from the dense d_ff)
    d_expert: int = 0
    router_jitter: float = 0.0
    # expert-parallel padding: experts [num_real:] are zero-weight and their
    # router logits are masked — bit-exact with the unpadded model (0 = none)
    num_real_experts: int = 0

    @property
    def real_experts(self) -> int:
        return self.num_real_experts or self.num_experts


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: groups of SSM layers with a shared attention block."""
    ssm_per_group: int = 5
    num_groups: int = 13
    tail_ssm_layers: int = 3

    @property
    def total_layers(self) -> int:
        return self.num_groups * (self.ssm_per_group + 1) + self.tail_ssm_layers


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 12
    # stubbed audio frontend: precomputed frame embeddings
    num_frames: int = 1500


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub: precomputed embeddings injected as inputs."""
    kind: str = "none"  # none | audio_stub | vision_stub
    num_embeds: int = 0  # frames or patches provided by input_specs()


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # Granite-style scalars (1.0 = disabled)
    embedding_multiplier: float = 1.0
    logits_scaling: float = 1.0
    residual_multiplier: float = 1.0
    attention_multiplier: float = 0.0  # 0 -> 1/sqrt(head_dim)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    dtype: str = "bfloat16"
    source: str = ""  # provenance tag: [hf:...; tier]

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if the arch can run the 500k-token long-context decode shape."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS and memory checks)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            qknorm = 2 * hd if self.qk_norm else 0
            return q + kv + o + qknorm

        def dense_ffn(ff: int) -> int:
            return 3 * d * ff  # SwiGLU: gate, up, down

        def block_norms() -> int:
            return 2 * d

        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
                + s.conv_kernel * (d_in + 2 * s.n_groups * s.d_state)  # conv
                + nheads * 2  # A_log, dt_bias
                + d_in  # norm gate
                + d_in * d  # out_proj
                + d  # pre-norm
            )
            return emb + self.num_layers * per + d
        if self.family == "hybrid":
            h = self.hybrid
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            ssm_per = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                + s.conv_kernel * (d_in + 2 * s.n_groups * s.d_state)
                + nheads * 2 + d_in + d_in * d + d
            )
            n_ssm = h.num_groups * h.ssm_per_group + h.tail_ssm_layers
            shared = attn_params() + dense_ffn(self.d_ff) + block_norms()
            return emb + n_ssm * ssm_per + shared + d
        if self.family == "moe":
            m = self.moe
            d_e = m.d_expert or self.d_ff
            router = d * m.num_experts
            experts = m.num_experts * 3 * d * d_e
            shared = m.num_shared_experts * 3 * d * d_e
            per = attn_params() + router + experts + shared + block_norms()
            return emb + self.num_layers * per + d
        if self.family == "encdec":
            e = self.encdec
            enc_per = attn_params() + dense_ffn(self.d_ff) + block_norms()
            dec_per = 2 * attn_params() + dense_ffn(self.d_ff) + 3 * d
            return emb + e.enc_layers * enc_per + self.num_layers * dec_per + 2 * d
        # dense / vlm
        per = attn_params() + dense_ffn(self.d_ff) + block_norms()
        return emb + self.num_layers * per + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d_e = m.d_expert or self.d_ff
        inactive = (m.num_experts - m.top_k) * 3 * self.d_model * d_e
        return self.param_count() - self.num_layers * inactive

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes per token across all attention layers."""
        hd = self.resolved_head_dim
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            n_attn = self.hybrid.num_groups  # shared block applied once per group
            return 2 * n_attn * self.num_kv_heads * hd * bytes_per_el
        n_attn = self.num_layers
        return 2 * n_attn * self.num_kv_heads * hd * bytes_per_el


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class RunConfig:
    """Knobs for a concrete lowering/run of one (arch x shape) cell."""
    remat: bool = True
    param_dtype: str = "bfloat16"
    # chunked-pipeline (MOCAP) knobs
    num_chunks: int = 16
    num_stages: int = 16
    mbkr: bool = True
    mbkr_spill_chunks: int = 0  # 0 -> auto (N//4)
    kv_spill_dtype: str = "bfloat16"  # beyond-paper: int8 spill compression
    remote_attn: str = "qship"  # fetch (paper-faithful) | qship (beyond-paper)
    # attention inner-loop implementation (core.attention registry):
    # "jnp" = pure-jnp online-softmax reference; "pallas" = the flash kernel
    # kernels.ops.chunk_attention (interpret mode off-TPU, Mosaic on TPU)
    attn_backend: str = "jnp"
    # backend-per-source mixing: the POOL-sourced partial states (own-pool
    # scan, fetch'd chunks, the creditor-side qship scan) may run a
    # different backend than the causal self block — e.g. pallas self-block
    # + jnp remote partials. "auto" follows attn_backend; under "pallas"
    # the pool scan is ONE batched slot-grid kernel launch (O(1) in pool
    # depth) instead of one chunk_attention launch per occupied slot;
    # "paged" keeps the single launch but reads KV pages IN PLACE from the
    # page store (scalar-prefetched handle rows + double-buffered async
    # copies — no gather_chunks stack in HBM, DESIGN.md §3.7)
    pool_backend: str = "auto"
    # SSD inner loop for the ssm/hybrid stage programs, same knob pattern:
    # "jnp" = models.ssm.ssd_chunked reference; "pallas" = kernels.ops.ssd
    ssm_backend: str = "jnp"
    # KV page store (repro.kvstore): storage dtype of the per-stage paged
    # pool — "auto" (model dtype, bit-identical to the unpaged pool),
    # "int8" / "fp8" (per-kv-head-scale codec; spill/fetch wires carry the
    # compressed payload, leases count quantized bytes)
    kv_dtype: str = "auto"
    # tokens per KV page; 0 = one page per chunk (rounded down to a divisor
    # of the chunk length otherwise)
    kv_page_tokens: int = 0
    # enable the cold tier: host-offload placement + analytic prefetch off
    # the LBCP plan (kvstore.tiers); serving-path staging via device_put
    kv_offload: bool = False
    # "kv_split": reshape the TP axis into ("kv","qg") so GQA attention is
    # collective-free (beyond-paper perf variant; auto-falls-back when head
    # counts don't divide). "auto": plain 16-way model axis.
    attn_sharding: str = "auto"
    # TP lowering strategy (core.transport / DESIGN.md §3.6): "auto" =
    # GSPMD partial-auto shard_map (falls back to "manual" on old jaxlib,
    # which cannot partition it — see compat.resolve_tp_lowering);
    # "manual" = all mesh axes manual, explicit transport psums in the
    # stage programs. Restores TP > 1 on the old-jaxlib CI leg.
    tp_lowering: str = "auto"
    # transport registry entry (core.transport): how cross-stage/cross-rank
    # collectives lower. "jax" = jax.lax collectives; future TPU-native
    # qship DMA / cold-streaming transports register here.
    transport: str = "jax"
    # batched fetch (core.remote): "auto" lands all remote chunk-layers in
    # a staging buffer and runs ONE pool_attention launch when the pool
    # backend advertises batched_pool; "off" forces the paper-faithful
    # one-streamed-combine-per-chunk order; "on" requires a batched backend
    fetch_batch: str = "auto"
    partition: str = "uniform"  # uniform | lbcp
    # Megatron-style TP degree is implied by the mesh "model" axis.
    fsdp: bool = True
    grad_accum: int = 1


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE_REGISTRY[name] = smoke


def get_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch]()


def get_smoke_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE_REGISTRY[arch]()


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    from importlib import import_module

    for mod in (
        "whisper_small", "qwen3_8b", "stablelm_3b", "granite_3_2b", "qwen3_14b",
        "granite_moe_3b_a800m", "qwen2_moe_a2_7b", "llava_next_34b",
        "zamba2_7b", "mamba2_130m",
        # paper-evaluation models (simulator workloads, Fig. 6)
        "llama3_70b", "mistral_123b", "qwen3_235b", "llama3_405b",
    ):
        import_module(f"repro.configs.{mod}")
    _LOADED = True


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
