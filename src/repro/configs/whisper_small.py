"""whisper-small [audio] — enc-dec; conv frontend stubbed to frame embeddings.
[arXiv:2212.04356; unverified]

12 encoder + 12 decoder layers, d_model=768, 12 heads (MHA), d_ff=3072,
vocab=51865. The mel/conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings [B, 1500, d_model].
"""
from repro.configs.base import EncDecConfig, FrontendConfig, ModelConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="whisper-small", family="encdec",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=51865, head_dim=64,
        encdec=EncDecConfig(enc_layers=12, num_frames=1500),
        frontend=FrontendConfig(kind="audio_stub", num_embeds=1500),
        rope_theta=10000.0, norm_eps=1e-5,
        source="[arXiv:2212.04356; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="whisper-small", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        encdec=EncDecConfig(enc_layers=2, num_frames=16),
        frontend=FrontendConfig(kind="audio_stub", num_embeds=16),
    )


register("whisper-small", full_config, smoke_config)
