"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="mamba2-130m", family="ssm",
        num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                      n_groups=1, chunk_size=256),
        tie_embeddings=True, norm_eps=1e-5,
        source="[arXiv:2405.21060; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="mamba2-130m", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=256,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_kernel=4,
                      n_groups=1, chunk_size=32),
        tie_embeddings=True,
    )


register("mamba2-130m", full_config, smoke_config)
