from repro.configs.base import (
    EncDecConfig,
    FrontendConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    get_config,
    get_smoke_config,
    list_archs,
    register,
    replace,
)

ASSIGNED_ARCHS = (
    "whisper-small", "qwen3-8b", "stablelm-3b", "granite-3-2b", "qwen3-14b",
    "granite-moe-3b-a800m", "qwen2-moe-a2.7b", "llava-next-34b",
    "zamba2-7b", "mamba2-130m",
)

PAPER_MODELS = ("llama3-70b", "mistral-123b", "qwen3-235b", "llama3-405b")

__all__ = [
    "ASSIGNED_ARCHS", "PAPER_MODELS", "EncDecConfig", "FrontendConfig",
    "HybridConfig", "ModelConfig", "MoEConfig", "RunConfig", "SHAPES",
    "ShapeConfig", "SSMConfig", "get_config", "get_smoke_config",
    "list_archs", "register", "replace",
]
