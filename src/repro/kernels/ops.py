"""Jit'd public wrappers around the Pallas kernels.

Handles: head-dim padding to the 128-lane width, KV padding to block
multiples, CPU fallback to ``interpret=True`` (the container has no TPU;
kernels are validated in interpret mode and TARGET TPU — see DESIGN.md).
"""
from __future__ import annotations

import contextlib
import math
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import chunk_attn as _ca
from repro.kernels import decode_attn as _da
from repro.kernels import ssd as _ssd

LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# --------------------------------------------------- launch-counting test hook
# Counts RUNTIME kernel invocations (a kernel traced once inside a lax.scan
# body still launches once per iteration — the thing the batched pool kernel
# amortizes), via a debug callback staged next to each pallas_call.
#
# A STACK of counter frames makes the hook nesting-safe (an outer harness
# and an inner assertion both observe their own window) and each frame keys
# launches by kernel tag next to the all-kernel "count", so obs can
# attribute launches to the self-block vs pool vs fetch paths.

_LAUNCH_FRAMES: list = []


def _note_launch(tag: str) -> None:
    if not _LAUNCH_FRAMES:  # read at TRACE time: zero cost when unused
        return

    def _bump():
        now = time.perf_counter()
        for frame in _LAUNCH_FRAMES:
            frame["count"] += 1
            frame[tag] = frame.get(tag, 0) + 1
            ev = frame.get("events")
            if ev is not None:
                ev.append((tag, now))

    jax.debug.callback(_bump)


@contextlib.contextmanager
def count_launches(timed: bool = False):
    """Context manager: count Pallas kernel launches executed inside.

        with ops.count_launches() as launches:
            fn(*args)  # must TRACE inside the context (caches are cleared)
        assert launches["count"] == ...
        assert launches["pool_attention"] == ...   # per-kernel attribution

    The yielded dict holds the all-kernel ``"count"`` plus one key per
    kernel tag (``chunk_attention`` / ``pool_attention`` /
    ``pool_attention_paged`` / ``ssd`` / ``decode_attention``) that
    launched at least once. Contexts nest: every
    active frame counts every launch in its window.

    ``timed=True`` additionally records ``frame["events"]`` — the ordered
    ``(tag, perf_counter)`` stream — and ``frame["t0"]`` at entry, the raw
    material for per-kernel-tag span attribution
    (``obs.profile.kernel_tag_times``). Callers may rebase ``frame["t0"]``
    right before dispatch to exclude compile time from the first span.

    The stack is read at trace time, so the wrappers' jit caches are
    cleared on entry/exit — callers pay a retrace, tests only."""
    jitted = (chunk_attention, pool_attention, pool_attention_paged, ssd,
              decode_attention)
    frame = {"count": 0}
    if timed:
        frame["events"] = []
        frame["t0"] = time.perf_counter()
    for f in jitted:
        f.clear_cache()
    _LAUNCH_FRAMES.append(frame)
    try:
        yield frame
    finally:
        # debug callbacks flush asynchronously under real (TPU) dispatch —
        # block_until_ready() alone does not order them before the caller's
        # read of launches["count"]
        jax.effects_barrier()
        _LAUNCH_FRAMES.remove(frame)
        for f in jitted:
            f.clear_cache()


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal_offset", "scale", "block_q",
                                   "block_k", "return_state"))
def chunk_attention(q, k, v, *, causal_offset: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = _ca.DEFAULT_BLOCK_Q,
                    block_k: int = _ca.DEFAULT_BLOCK_K,
                    return_state: bool = False,
                    k_scale=None, v_scale=None):
    """Chunked-prefill flash attention (MOCAP hot spot). See chunk_attn.py.

    ``return_state=True`` also returns the fp32 online-softmax residuals
    ``(m, l) [B, H, C]`` and the unnormalized fp32 accumulator
    ``acc [B, C, H, D]`` so partial results combine across KV sources at
    full precision — used by the pipeline's "pallas" attention backend
    (core.attention).

    ``k_scale``/``v_scale`` [B, T, KVH]: k/v are quantized KV-page payloads
    (``repro.kvstore``, one scale row per kv token) and the kernel
    dequantizes in its epilogue.
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    t, c = k.shape[1], q.shape[1]
    bq = min(block_q, c)
    while c % bq:
        bq //= 2
    bk = min(block_k, t)
    qp = _pad_to(q, 3, LANE)
    kp = _pad_to(_pad_to(k, 3, LANE), 1, bk)
    vp = _pad_to(_pad_to(v, 3, LANE), 1, bk)
    if k_scale is not None:
        k_scale = _pad_to(k_scale, 1, bk)  # pad rows are masked via kv_len
        v_scale = _pad_to(v_scale, 1, bk)
    _note_launch("chunk_attention")
    res = _ca.chunk_attention_pallas(
        qp, kp, vp, causal_offset=causal_offset, scale=scale, kv_len=t,
        block_q=bq, block_k=bk, interpret=not _on_tpu(),
        return_state=return_state, k_scale=k_scale, v_scale=v_scale)
    if return_state:
        out, m, l, acc = res
        return out[..., :d], m, l, acc[..., :d]
    return res[..., :d]


@partial(jax.jit, static_argnames=("scale", "block_q", "block_k"))
def pool_attention(q, k, v, valid, *, scale: Optional[float] = None,
                   block_q: int = _ca.DEFAULT_BLOCK_Q,
                   block_k: int = _ca.DEFAULT_BLOCK_K,
                   k_scale=None, v_scale=None):
    """Batched pool attention (MOCAP pool scan, single launch). See
    ``chunk_attn.pool_attention_pallas``.

    q [B, C, H, D]; k, v [S, B, T, KVH, D] — a stack of S stored chunks,
    each fully visible; ``valid`` [S] bool/int gates slots (False slot ==
    identity-state contribution, exactly). ``k_scale``/``v_scale``
    [S, B, T, KVH]: quantized page payloads, dequantized in the kernel
    epilogue. Returns the fp32 online-softmax state ``(m, l) [B, H, C]`` +
    unnormalized ``acc [B, C, H, D]`` for the caller's combine chain —
    the launch count is O(1) in pool depth instead of O(slots)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    t, c = k.shape[2], q.shape[1]
    bq = min(block_q, c)
    while c % bq:
        bq //= 2
    bk = min(block_k, t)
    qp = _pad_to(q, 3, LANE)
    kp = _pad_to(_pad_to(k, 4, LANE), 2, bk)
    vp = _pad_to(_pad_to(v, 4, LANE), 2, bk)
    if k_scale is not None:
        k_scale = _pad_to(k_scale, 2, bk)  # pad rows are masked via kv_len
        v_scale = _pad_to(v_scale, 2, bk)
    _note_launch("pool_attention")
    m, l, acc = _ca.pool_attention_pallas(
        qp, kp, vp, valid.astype(jnp.int32).reshape(-1, 1),
        scale=scale, kv_len=t, block_q=bq, block_k=bk,
        interpret=not _on_tpu(), k_scale=k_scale, v_scale=v_scale)
    return m, l, acc[..., :d]


def _paged_use_dma() -> bool:
    """The paged kernel's buffering scheme: manual double-buffered
    ``make_async_copy`` by default (the TPU-native path, also exercised in
    interpret mode so both CI legs validate it); ``REPRO_PAGED_DMA=0`` falls
    back to automatically pipelined handle-indexed BlockSpecs — same
    zero-gather property, for environments whose interpret mode lacks DMA
    support."""
    import os
    return os.environ.get("REPRO_PAGED_DMA", "1") != "0"


@partial(jax.jit, static_argnames=("ppc", "scale", "kv_len", "block_q",
                                   "use_dma"))
def pool_attention_paged(q, k_pages, v_pages, handles, valid, *, ppc: int,
                         scale: Optional[float] = None,
                         kv_len: Optional[int] = None,
                         block_q: int = _ca.DEFAULT_BLOCK_Q,
                         k_scale=None, v_scale=None,
                         use_dma: Optional[bool] = None):
    """Ragged paged pool attention (MOCAP pool scan, single launch, ZERO
    gather). See ``chunk_attn.pool_attention_paged_pallas``.

    q [B, C, H, D]; ``k_pages``/``v_pages`` [P, B, pt, KVH, D] — the page
    store's layer slice in STORAGE dtype, read in place (``pltpu.ANY``);
    ``handles`` [S*ppc] int32 flattened page-handle rows; ``valid`` [S]
    bool/int per-slot occupancy (both scalar-prefetched into SMEM).
    ``k_scale``/``v_scale`` [P, B, 1, KVH, 1] fp32: the pool's per-page
    scales, dequantized on the VMEM landing buffer. ``kv_len`` < ppc*pt
    handles a partial last page. Returns the fp32 online-softmax state like
    ``pool_attention`` — one launch per (layer, tick), O(1) in pool depth,
    and HBM traffic O(resident pages), not O(padded pool)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    c = q.shape[1]
    bq = min(_ca.DEFAULT_BLOCK_Q if block_q is None else block_q, c)
    while c % bq:
        bq //= 2
    qp = _pad_to(q, 3, LANE)
    # lane-pad the PAGE STORE only when head_dim is off-lane (a one-off
    # [P, ...] copy — real configs keep hd a multiple of 128 and pass
    # through untouched; there is never an [S, B, C, KVH, D] gather)
    kp = _pad_to(k_pages, 4, LANE)
    vp = _pad_to(v_pages, 4, LANE)
    kvh = k_pages.shape[3]
    if k_scale is not None:
        k_scale = k_scale.reshape(k_scale.shape[0], -1)  # [P, B*KVH]
        v_scale = v_scale.reshape(v_scale.shape[0], -1)
        assert k_scale.shape[1] == q.shape[0] * kvh, k_scale.shape
    use_dma = _paged_use_dma() if use_dma is None else use_dma
    _note_launch("pool_attention_paged")
    m, l, acc = _ca.pool_attention_paged_pallas(
        qp, kp, vp, handles, valid, ppc=ppc, scale=scale, kv_len=kv_len,
        block_q=bq, interpret=not _on_tpu(), k_scale=k_scale,
        v_scale=v_scale, use_dma=use_dma)
    return m, l, acc[..., :d]


def full_attention(q, k, v, *, scale: Optional[float] = None,
                   block_q: int = _ca.DEFAULT_BLOCK_Q,
                   block_k: int = _ca.DEFAULT_BLOCK_K):
    """Non-causal (full-visibility) wrapper around ``chunk_attention``:
    every query attends over every key — the encdec CROSS-attention shape
    (decoder chunk vs the whole encoder output) and bidirectional encoders.
    Implemented as a causal offset past the last key, so padded kv rows are
    still masked by ``kv_len`` inside the kernel."""
    return chunk_attention(q, k, v, causal_offset=int(k.shape[1]),
                           scale=scale, block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, d_skip, *, chunk: int = 128, init_state=None,
        interpret: Optional[bool] = None):
    """Mamba2 chunked SSD scan. See ssd.py."""
    t = x.shape[1]
    ck = min(chunk, t)
    while t % ck:
        ck //= 2
    interpret = (not _on_tpu()) if interpret is None else interpret
    _note_launch("ssd")
    return _ssd.ssd_pallas(x, dt, a_log, b, c, d_skip, chunk=ck,
                           init_state=init_state, interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "block_s"))
def decode_attention(q, k, v, kv_len, *, scale: Optional[float] = None,
                     block_s: int = _da.DEFAULT_BLOCK_S):
    """Flash-decode (one token vs KV cache). See decode_attn.py."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qp = _pad_to(q, 2, LANE)
    kp = _pad_to(k, 3, LANE)
    vp = _pad_to(v, 3, LANE)
    s_len = kp.shape[1]
    bs = min(block_s, s_len)
    while s_len % bs:
        bs //= 2
    _note_launch("decode_attention")
    out = _da.decode_attention_pallas(qp, kp, vp, kv_len, scale=scale,
                                      block_s=bs, interpret=not _on_tpu())
    return out[..., :d]
