"""Jit'd public wrappers around the Pallas kernels.

Handles: head-dim padding to the 128-lane width, KV padding to block
multiples, CPU fallback to ``interpret=True`` (the container has no TPU;
kernels are validated in interpret mode and TARGET TPU — see DESIGN.md).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import chunk_attn as _ca
from repro.kernels import decode_attn as _da
from repro.kernels import ssd as _ssd

LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal_offset", "scale", "block_q",
                                   "block_k", "return_state"))
def chunk_attention(q, k, v, *, causal_offset: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = _ca.DEFAULT_BLOCK_Q,
                    block_k: int = _ca.DEFAULT_BLOCK_K,
                    return_state: bool = False,
                    k_scale=None, v_scale=None):
    """Chunked-prefill flash attention (MOCAP hot spot). See chunk_attn.py.

    ``return_state=True`` also returns the fp32 online-softmax residuals
    ``(m, l) [B, H, C]`` and the unnormalized fp32 accumulator
    ``acc [B, C, H, D]`` so partial results combine across KV sources at
    full precision — used by the pipeline's "pallas" attention backend
    (core.attention).

    ``k_scale``/``v_scale`` [B, T, KVH]: k/v are quantized KV-page payloads
    (``repro.kvstore``, one scale row per kv token) and the kernel
    dequantizes in its epilogue.
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    t, c = k.shape[1], q.shape[1]
    bq = min(block_q, c)
    while c % bq:
        bq //= 2
    bk = min(block_k, t)
    qp = _pad_to(q, 3, LANE)
    kp = _pad_to(_pad_to(k, 3, LANE), 1, bk)
    vp = _pad_to(_pad_to(v, 3, LANE), 1, bk)
    if k_scale is not None:
        k_scale = _pad_to(k_scale, 1, bk)  # pad rows are masked via kv_len
        v_scale = _pad_to(v_scale, 1, bk)
    res = _ca.chunk_attention_pallas(
        qp, kp, vp, causal_offset=causal_offset, scale=scale, kv_len=t,
        block_q=bq, block_k=bk, interpret=not _on_tpu(),
        return_state=return_state, k_scale=k_scale, v_scale=v_scale)
    if return_state:
        out, m, l, acc = res
        return out[..., :d], m, l, acc[..., :d]
    return res[..., :d]


def full_attention(q, k, v, *, scale: Optional[float] = None,
                   block_q: int = _ca.DEFAULT_BLOCK_Q,
                   block_k: int = _ca.DEFAULT_BLOCK_K):
    """Non-causal (full-visibility) wrapper around ``chunk_attention``:
    every query attends over every key — the encdec CROSS-attention shape
    (decoder chunk vs the whole encoder output) and bidirectional encoders.
    Implemented as a causal offset past the last key, so padded kv rows are
    still masked by ``kv_len`` inside the kernel."""
    return chunk_attention(q, k, v, causal_offset=int(k.shape[1]),
                           scale=scale, block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, d_skip, *, chunk: int = 128, init_state=None,
        interpret: Optional[bool] = None):
    """Mamba2 chunked SSD scan. See ssd.py."""
    t = x.shape[1]
    ck = min(chunk, t)
    while t % ck:
        ck //= 2
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _ssd.ssd_pallas(x, dt, a_log, b, c, d_skip, chunk=ck,
                           init_state=init_state, interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "block_s"))
def decode_attention(q, k, v, kv_len, *, scale: Optional[float] = None,
                     block_s: int = _da.DEFAULT_BLOCK_S):
    """Flash-decode (one token vs KV cache). See decode_attn.py."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qp = _pad_to(q, 2, LANE)
    kp = _pad_to(k, 3, LANE)
    vp = _pad_to(v, 3, LANE)
    s_len = kp.shape[1]
    bs = min(block_s, s_len)
    while s_len % bs:
        bs //= 2
    out = _da.decode_attention_pallas(qp, kp, vp, kv_len, scale=scale,
                                      block_s=bs, interpret=not _on_tpu())
    return out[..., :d]
