"""Pallas TPU kernel: Mamba2 chunked SSD (state-space duality) scan.

One grid step computes one (batch, head, chunk) cell: the intra-chunk
"diagonal" attention-like term, the chunk's contribution to the running SSM
state, and the inter-chunk "off-diagonal" term read from the state carried in
fp32 VMEM scratch. The chunk axis is the innermost grid dimension, which TPU
executes SEQUENTIALLY — the scratch state [P, N] persists across chunk steps
and is reset at chunk 0 (this is how the recurrence crosses chunk boundaries
without leaving VMEM).

Layout notes: P (head channel) and N (state) are the two minor dims; Q (chunk
length) is a multiple of 8 sublanes, P/N multiples of 128 lanes preferred.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref,
                init_ref, y_ref, st_out_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _reset():
        state_ref[...] = init_ref[0, 0, :, :].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    a = a_ref[0]                                     # scalar A_log for this head
    bm = b_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]
    cm = c_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]
    d_skip = dskip_ref[0]

    da = dt * (-jnp.exp(a))                          # [Q]
    da_cs = jnp.cumsum(da)                           # [Q]
    xdt = x * dt[:, None]                            # [Q, P]

    # intra-chunk: L[i,j] = exp(da_cs[i] - da_cs[j]) for j <= i
    seg = da_cs[:, None] - da_cs[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(iota_j <= iota_i, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    y_diag = jax.lax.dot_general(cb * lmat, xdt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q, P]

    # off-diagonal: read the carried state
    state = state_ref[...]                           # [P, N]
    decay_in = jnp.exp(da_cs)                        # [Q]
    y_off = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [Q, P]
    y_off = y_off * decay_in[:, None]

    y = y_diag + y_off + x * d_skip
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: state' = state * exp(sum da) + sum_q decay_out[q] B[q] (x dt)[q]
    decay_out = jnp.exp(da_cs[-1] - da_cs)           # [Q]
    upd = jax.lax.dot_general((xdt * decay_out[:, None]), bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    state_ref[...] = state * jnp.exp(da_cs[-1]) + upd

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_out_ref[0, 0, :, :] = state_ref[...]


def ssd_pallas(x: jax.Array, dt: jax.Array, a_log: jax.Array,
               b: jax.Array, c: jax.Array, d_skip: jax.Array, *,
               chunk: int = 128, init_state: Optional[jax.Array] = None,
               n_groups: int = 1, interpret: bool = False):
    """Chunked SSD. x [B,T,H,P]; dt [B,T,H] (post-softplus); a_log [H];
    b, c [B,T,G,N]; d_skip [H]. T must be a multiple of ``chunk``.
    Returns (y [B,T,H,P] fp32-accurate in x.dtype, final_state [B,H,P,N] fp32).

    Groups (G < H) are mapped per-head in the B/C BlockSpec index maps.
    """
    bs, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    if init_state is None:
        init_state = jnp.zeros((bs, h, p, n), jnp.float32)

    grid = (bs, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hg, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hg, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, t, h, p), x.dtype),
            jax.ShapeDtypeStruct((bs, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log.astype(jnp.float32), b, c, d_skip.astype(jnp.float32),
      init_state)
    return y, st
