"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function is the mathematically transparent (materialize-everything)
version of its kernel; kernels are asserted allclose against these across
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def chunk_attention_ref(q, k, v, *, causal_offset: int = 0,
                        scale: Optional[float] = None,
                        kv_len: Optional[int] = None):
    """q [B,C,H,D]; k/v [B,T,KVH,D]. Materialized-scores GQA attention with
    prefix-causal masking (query i sees keys j <= causal_offset + i)."""
    b, c, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = kv_len if kv_len is not None else t
    qg = q.reshape(b, c, kvh, g, d).astype(jnp.float32)
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(c)[:, None] + causal_offset
    kpos = jnp.arange(t)[None, :]
    mask = (kpos <= qpos) & (kpos < kv_len)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgcs,bskd->bckgd", p, v.astype(jnp.float32))
    return out.reshape(b, c, h, d).astype(q.dtype)


def ssd_ref(x, dt, a_log, b, c, d_skip, *, init_state=None):
    """Sequential (token-by-token) SSD recurrence — the slowest, most
    obviously-correct form. x [B,T,H,P]; dt [B,T,H]; b/c [B,T,G,N]."""
    bs, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bh = jnp.repeat(b.astype(jnp.float32), hg, axis=2)   # [B,T,H,N]
    ch = jnp.repeat(c.astype(jnp.float32), hg, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    st = jnp.zeros((bs, h, p, n), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(st, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,H,N], [B,H,N]
        dec = jnp.exp(dtt * a)  # [B,H]
        st = st * dec[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        yt = jnp.einsum("bhn,bhpn->bhp", ct, st)
        return st, yt

    st, ys = jax.lax.scan(
        step, st,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         bh.transpose(1, 0, 2, 3), ch.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3) + xf * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), st


def decode_attention_ref(q, k, v, kv_len, *, scale: Optional[float] = None):
    """q [B,H,D]; k/v [B,S,KVH,D]; kv_len [B]. Materialized decode attention."""
    b, h, d = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(s_len)[None, :] < kv_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
