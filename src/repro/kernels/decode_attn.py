"""Pallas TPU kernel: flash-decode attention (one query token vs a KV cache).

Grid = (B, KVH, ns): the sequence-block loop is innermost (sequential); the
online-softmax state for ALL G group-queries of this kv head lives in fp32
VMEM scratch. Valid lengths are per-batch (``kv_len``), masked inside the
kernel, so one compiled kernel serves ragged batches.

Decode is memory-bound: the kernel's job is to stream K/V blocks through VMEM
exactly once with no materialized [S] score row in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = float(-1e30)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_s: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    first_s = si * block_s

    @pl.when(first_s < kv_len)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)        # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # [Bs, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = first_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(m_new < NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        corr = jnp.exp(m_prev - m_safe)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p, v_ref[0, :, 0, :].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, kv_len: jax.Array, *,
    scale: Optional[float] = None, block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    """q [B, H, D] (single decode token); k, v [B, S, KVH, D];
    kv_len [B] int32 valid lengths. Returns [B, H, D]."""
    import math
    b, h, d = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_s = min(block_s, s_len)
    assert s_len % block_s == 0, (s_len, block_s)
    ns = s_len // block_s

    qg = q.reshape(b, kvh, g, d)
    grid = (b, kvh, ns)
    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, si: (bi,)),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k, v)
    return out.reshape(b, h, d)
