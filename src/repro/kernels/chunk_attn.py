"""Pallas TPU kernel: chunked-prefill flash attention with a prefix offset.

This is MOCAP's compute hot spot: one chunk of C query tokens attends over
(prefix + chunk) KV — the prefix rows are fully visible, the final C rows are
causal with offset ``prefix_len``. GQA is handled by mapping query head h to
kv head h // group in the K/V BlockSpec index maps (no KV replication in VMEM).

Tiling: grid = (B, H, nq, nk) with the KV block loop innermost (sequential on
TPU); online-softmax accumulators live in fp32 VMEM scratch. Block shapes are
(block_q, head_dim) / (block_k, head_dim) with head_dim padded to the 128-lane
width by the wrapper (`ops.chunk_attention`). Blocks strictly above the causal
diagonal are skipped via ``pl.when`` (no MXU work issued).

``pool_attention_pallas`` is the batched sibling for MOCAP's POOL scan: the
same online softmax with a slot axis in the grid — (B, H, nq, slots, nk) —
so one launch covers every stored chunk a consumer attends over, instead of
one launch (and one traced-level combine round-trip) per occupied slot.

``pool_attention_paged_pallas`` is the ragged-paged successor (DESIGN.md
§3.7): page-handle rows + per-slot occupancy arrive as SCALAR-PREFETCH
arguments (``pltpu.PrefetchScalarGridSpec``) and the kernel reads KV pages
straight from the page store ``[P, B, pt, KVH, hd]`` — no ``gather_chunks``
copy, no dense slot stack in HBM — double-buffering each page HBM→VMEM with
``pltpu.make_async_copy`` while the MXU runs the previous page, and
dequantizing int8/fp8 payloads on the landing buffer. Invalid slots issue
zero copies and zero MXU work.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = float(-1e30)


def _block_update(q, k, v, mask, scale, m_ref, l_ref, acc_ref):
    """One online-softmax block update against the VMEM scratch state —
    shared by the per-chunk and the batched pool kernels (q/k/v already
    fp32 and dequantized; only the mask differs between callers)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    m_safe = jnp.where(m_new < NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    corr = jnp.exp(m_prev - m_safe)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new


def _attn_kernel(q_ref, k_ref, v_ref, *refs,
                 scale: float, causal_offset: int, kv_len: int,
                 block_q: int, block_k: int, return_state: bool = False,
                 quantized: bool = False):
    if quantized:  # extra inputs: per-(batch, kv-head) fp32 dequant scales
        ksc_ref, vsc_ref, *refs = refs
    else:
        ksc_ref = vsc_ref = None
    o_ref, *refs = refs
    if return_state:  # extra outputs: max / denom / fp32 accumulator
        mo_ref, lo_ref, ao_ref, m_ref, l_ref, acc_ref = refs
    else:
        mo_ref = lo_ref = ao_ref = None
        m_ref, l_ref, acc_ref = refs
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this block's queries / keys
    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # skip blocks entirely above the causal diagonal
    last_q = qb * block_q + causal_offset + block_q - 1  # last query's abs pos
    first_k = kb * block_k

    @pl.when(first_k <= last_q)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        # dequant-on-read: quantized pages store (payload, per-page per-head
        # scales expanded to a per-token row by the caller); the multiply
        # rides the fp32 upcast the MXU path does anyway
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if ksc_ref is not None:
            k = k * ksc_ref[0, :, 0][:, None]
            v = v * vsc_ref[0, :, 0][:, None]
        mask = (k_pos <= q_pos + causal_offset) & (k_pos < kv_len)
        _block_update(q, k, v, mask, scale, m_ref, l_ref, acc_ref)

    @pl.when(kb == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        if mo_ref is not None:
            mo_ref[0, 0, :] = m_ref[...]
            lo_ref[0, 0, :] = l_ref[...]
            ao_ref[0, :, 0, :] = acc_ref[...]


def _pool_kernel(q_ref, k_ref, v_ref, valid_ref, *refs,
                 scale: float, kv_len: int, block_q: int, block_k: int,
                 quantized: bool = False):
    """Slot-grid pool attention: ONE launch over a stack of stored chunks.

    Grid = (B, H, nq, S, nk) with (slot, kv-block) innermost and sequential,
    so the online-softmax scratch accumulates across every slot's KV blocks
    — the fused form of the per-slot ``chunk_attention`` + combine chain.
    Every stored chunk is fully visible (no causal diagonal); a slot whose
    ``valid`` flag is 0 issues no MXU work and contributes the identity
    state, exactly like the gated per-slot path."""
    if quantized:  # extra inputs: per-(slot, token, kv-head) dequant scales
        ksc_ref, vsc_ref, *refs = refs
    else:
        ksc_ref = vsc_ref = None
    mo_ref, lo_ref, ao_ref, m_ref, l_ref, acc_ref = refs
    si = pl.program_id(3)
    kb = pl.program_id(4)
    ns = pl.num_programs(3)
    nk = pl.num_programs(4)

    @pl.when((si == 0) & (kb == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    @pl.when(valid_ref[0, 0] != 0)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, 0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, 0, :, 0, :].astype(jnp.float32)
        if ksc_ref is not None:
            k = k * ksc_ref[0, 0, :, 0][:, None]
            v = v * vsc_ref[0, 0, :, 0][:, None]
        # stored chunks are fully visible: only page padding masks
        _block_update(q, k, v, k_pos < kv_len, scale, m_ref, l_ref, acc_ref)

    @pl.when((si == ns - 1) & (kb == nk - 1))
    def _finish():
        mo_ref[0, 0, :] = m_ref[...]
        lo_ref[0, 0, :] = l_ref[...]
        ao_ref[0, :, 0, :] = acc_ref[...]


def pool_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, valid: jax.Array, *,
    scale: Optional[float] = None, kv_len: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
):
    """Batched pool attention: q [B, C, H, D] vs a STACK of stored chunks
    k, v [S, B, T, KVH, D] (T padded to a multiple of block_k), in one
    kernel launch. ``valid`` [S, 1] int32 gates each slot (0 = identity
    contribution). Returns ONLY the online-softmax state — ``(m, l)
    [B, H, C]`` fp32 and the unnormalized accumulator ``acc [B, C, H, D]``
    fp32 — because the caller always combines the pool state with the self
    block / remote partials before normalizing.

    ``kv_len``: VALID tokens per chunk (uniform chunks; pad rows masked).
    ``k_scale``/``v_scale`` [S, T, ...]-shaped ``[S, B, T, KVH]`` fp32: when
    given, k/v are quantized page payloads and the per-slot scale rows (the
    page store's per-page scales expanded per token, slot axis leading) are
    multiplied out in the kernel epilogue after the block load."""
    b, c, h, d = q.shape
    ns, t, kvh = k.shape[0], k.shape[2], k.shape[3]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = kv_len if kv_len is not None else t
    block_q = min(block_q, c)
    block_k = min(block_k, t)
    assert c % block_q == 0 and t % block_k == 0, (c, t, block_q, block_k)
    nq, nk = c // block_q, t // block_k
    quantized = k_scale is not None
    assert quantized == (v_scale is not None)

    grid = (b, h, nq, ns, nk)
    kernel = functools.partial(
        _pool_kernel, scale=scale, kv_len=kv_len,
        block_q=block_q, block_k=block_k, quantized=quantized)
    ml_spec = pl.BlockSpec((1, 1, block_q),
                           lambda bi, hi, qi, si, ki: (bi, hi, qi))
    acc_spec = pl.BlockSpec((1, block_q, 1, d),
                            lambda bi, hi, qi, si, ki: (bi, qi, hi, 0))
    out_shapes = [jax.ShapeDtypeStruct((b, h, c), jnp.float32)] * 2 \
        + [jax.ShapeDtypeStruct((b, c, h, d), jnp.float32)]
    kv_spec = pl.BlockSpec((1, 1, block_k, 1, d),
                           lambda bi, hi, qi, si, ki: (si, bi, ki, hi // g, 0))
    in_specs = [
        pl.BlockSpec((1, block_q, 1, d),
                     lambda bi, hi, qi, si, ki: (bi, qi, hi, 0)),
        kv_spec,
        kv_spec,
        pl.BlockSpec((1, 1), lambda bi, hi, qi, si, ki: (si, 0),
                     memory_space=pltpu.SMEM),
    ]
    args = [q, k, v, valid.astype(jnp.int32)]
    if quantized:
        sc_spec = pl.BlockSpec((1, 1, block_k, 1),
                               lambda bi, hi, qi, si, ki: (si, bi, ki, hi // g))
        in_specs += [sc_spec, sc_spec]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    m, l, acc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[ml_spec, ml_spec, acc_spec],
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denom
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(*args)
    return m, l, acc


def _paged_kernel(handles_ref, valid_ref, q_ref, k_src, v_src, *refs,
                  scale: float, kv_len: int, block_q: int, pt: int,
                  ppc: int, np_eff: int, group: int, quantized: bool,
                  use_dma: bool):
    """Ragged paged pool attention: ONE launch straight off the page store.

    Grid = (B, H, nq, S, np_eff) with (slot, page) innermost and sequential.
    ``handles_ref`` [S*ppc] and ``valid_ref`` [S] are scalar-prefetch SMEM
    refs — available BEFORE the grid runs, so they can steer data movement:

    - ``use_dma=True`` (the TPU-native path): ``k_src``/``v_src`` are the
      UNBLOCKED page stores (``pltpu.ANY`` memory space). Each grid step
      issues a ``make_async_copy`` of the NEXT valid page's ``[pt, hd]``
      slice into the other half of a double buffer while the MXU consumes
      the current half — the handle indirection happens in the DMA source
      index, so no gathered stack ever exists in HBM.
    - ``use_dma=False`` (portable fallback): ``k_src``/``v_src`` arrive as
      automatically pipelined VMEM blocks whose index map already applied
      ``handles_ref[si*ppc+pi]`` — same zero-gather property, buffering
      delegated to the Pallas pipeline.

    A slot with ``valid == 0`` contributes the exact identity state: its
    steps issue no copies (the prefetch for step t+1 is validity-gated) and
    no MXU work. Quantized payloads are dequantized ON THE LANDING BUFFER:
    the per-page scale rides in SMEM (indexed by the same handle) and the
    multiply fuses into the fp32 upcast."""
    if quantized:  # extra inputs: per-page per-(batch, kv-head) fp32 scales
        ksc_ref, vsc_ref, *refs = refs
    else:
        ksc_ref = vsc_ref = None
    mo_ref, lo_ref, ao_ref, *refs = refs
    if use_dma:
        kbuf, vbuf, sem, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs

    bi, hi = pl.program_id(0), pl.program_id(1)
    si, pi = pl.program_id(3), pl.program_id(4)
    ns = pl.num_programs(3)
    hk = hi // group
    step = si * np_eff + pi          # page step within this (bi, hi, qi)
    nsteps = ns * np_eff
    cur_valid = valid_ref[si] != 0

    if use_dma:
        def page_copies(buf_i, s2, p2):
            h = handles_ref[s2 * ppc + p2]
            ck = pltpu.make_async_copy(k_src.at[h, bi, :, hk, :],
                                       kbuf.at[buf_i], sem.at[buf_i, 0])
            cv = pltpu.make_async_copy(v_src.at[h, bi, :, hk, :],
                                       vbuf.at[buf_i], sem.at[buf_i, 1])
            return ck, cv

        # warm-up: the first page of each (bi, hi, qi) program has no
        # predecessor to prefetch it — one stall per q-block program
        @pl.when((step == 0) & cur_valid)
        def _warm():
            for c in page_copies(0, 0, 0):
                c.start()

        # land the NEXT page in the other buffer half while this page's
        # block update runs; invalid targets issue no copy at all
        nxt = step + 1
        n_si = jnp.minimum(nxt // np_eff, ns - 1)  # clamp: last step only
        n_pi = jax.lax.rem(nxt, np_eff)

        @pl.when((nxt < nsteps) & (valid_ref[n_si] != 0))
        def _prefetch():
            for c in page_copies(jax.lax.rem(nxt, 2), n_si, n_pi):
                c.start()

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_pos = pi * pt + jax.lax.broadcasted_iota(jnp.int32, (block_q, pt), 1)

    @pl.when(cur_valid)
    def _compute():
        if use_dma:
            buf_i = jax.lax.rem(step, 2)
            for c in page_copies(buf_i, si, pi):
                c.wait()
            k = kbuf[buf_i].astype(jnp.float32)
            v = vbuf[buf_i].astype(jnp.float32)
        else:
            k = k_src[0, 0, :, 0, :].astype(jnp.float32)
            v = v_src[0, 0, :, 0, :].astype(jnp.float32)
        if ksc_ref is not None:  # dequant on the landing buffer
            k = k * ksc_ref[0, 0]
            v = v * vsc_ref[0, 0]
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        # stored chunks are fully visible: only the partial last page masks
        _block_update(q, k, v, k_pos < kv_len, scale, m_ref, l_ref, acc_ref)

    @pl.when(step == nsteps - 1)
    def _finish():
        mo_ref[0, 0, :] = m_ref[...]
        lo_ref[0, 0, :] = l_ref[...]
        ao_ref[0, :, 0, :] = acc_ref[...]


def pool_attention_paged_pallas(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    handles: jax.Array, valid: jax.Array, *, ppc: int,
    scale: Optional[float] = None, kv_len: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q, interpret: bool = False,
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
    use_dma: bool = True,
):
    """Ragged paged pool attention: q [B, C, H, D] vs the PAGE STORE
    ``k_pages``/``v_pages`` [P, B, pt, KVH, D] (one layer's slice, storage
    dtype), addressed through ``handles`` [S*ppc] int32 (the flattened
    page-handle rows of the visited slots) with per-slot occupancy ``valid``
    [S] int32 — both delivered as scalar-prefetch arguments. Returns the
    online-softmax state ``(m, l) [B, H, C]`` fp32 + unnormalized ``acc
    [B, C, H, D]`` fp32, exactly like ``pool_attention_pallas``, but with NO
    gathered ``[S, B, C, KVH, D]`` intermediate: pages stream HBM→VMEM per
    grid step (double-buffered ``make_async_copy`` when ``use_dma``).

    ``kv_len``: valid tokens per chunk (< ppc*pt for a partial last page —
    trailing fully-empty pages are excluded from the grid, the straddling
    page is masked). ``k_scale``/``v_scale`` [P, B*KVH] fp32: per-page
    dequant scales, SMEM-indexed by the same handles."""
    b, c, h, d = q.shape
    pt, kvh = k_pages.shape[2], k_pages.shape[3]
    assert k_pages.shape[-1] == d, (k_pages.shape, d)
    ns = valid.shape[0]
    assert ns >= 1 and handles.shape == (ns * ppc,), (handles.shape, ns, ppc)
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = kv_len if kv_len is not None else ppc * pt
    np_eff = max(1, min(ppc, -(-kv_len // pt)))  # drop fully-empty pages
    block_q = min(block_q, c)
    assert c % block_q == 0, (c, block_q)
    nq = c // block_q
    quantized = k_scale is not None
    assert quantized == (v_scale is not None)

    grid = (b, h, nq, ns, np_eff)
    kernel = functools.partial(
        _paged_kernel, scale=scale, kv_len=kv_len, block_q=block_q, pt=pt,
        ppc=ppc, np_eff=np_eff, group=g, quantized=quantized, use_dma=use_dma)
    # index maps take the grid indices PLUS the scalar-prefetch refs
    q_spec = pl.BlockSpec((1, block_q, 1, d),
                          lambda bi, hi, qi, si, pi, hr, vr: (bi, qi, hi, 0))
    if use_dma:  # unblocked page stores; the kernel DMAs page slices itself
        kv_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    else:        # handle indirection inside the automatic pipeline
        kv_spec = pl.BlockSpec(
            (1, 1, pt, 1, d),
            lambda bi, hi, qi, si, pi, hr, vr:
                (hr[si * ppc + pi], bi, 0, hi // g, 0))
    in_specs = [q_spec, kv_spec, kv_spec]
    args = [q, k_pages, v_pages]
    if quantized:
        sc_spec = pl.BlockSpec(
            (1, 1),
            lambda bi, hi, qi, si, pi, hr, vr:
                (hr[si * ppc + pi], bi * kvh + hi // g),
            memory_space=pltpu.SMEM)
        in_specs += [sc_spec, sc_spec]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    ml_spec = pl.BlockSpec((1, 1, block_q),
                           lambda bi, hi, qi, si, pi, hr, vr: (bi, hi, qi))
    acc_spec = pl.BlockSpec((1, block_q, 1, d),
                            lambda bi, hi, qi, si, pi, hr, vr: (bi, qi, hi, 0))
    out_shapes = [jax.ShapeDtypeStruct((b, h, c), jnp.float32)] * 2 \
        + [jax.ShapeDtypeStruct((b, c, h, d), jnp.float32)]
    scratch = []
    if use_dma:
        scratch += [
            pltpu.VMEM((2, pt, d), k_pages.dtype),   # k landing buffers
            pltpu.VMEM((2, pt, d), v_pages.dtype),   # v landing buffers
            pltpu.SemaphoreType.DMA((2, 2)),         # [buffer, k|v]
        ]
    scratch += [
        pltpu.VMEM((block_q,), jnp.float32),      # running max
        pltpu.VMEM((block_q,), jnp.float32),      # running denom
        pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=grid, in_specs=in_specs,
        out_specs=[ml_spec, ml_spec, acc_spec], scratch_shapes=scratch)
    m, l, acc = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shapes,
        interpret=interpret,
    )(handles.astype(jnp.int32), valid.astype(jnp.int32), *args)
    return m, l, acc


def chunk_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal_offset: int = 0, scale: Optional[float] = None,
    kv_len: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False, return_state: bool = False,
    k_scale: Optional[jax.Array] = None, v_scale: Optional[jax.Array] = None,
):
    """q [B, C, H, D]; k, v [B, T, KVH, D] (T = prefix + C, padded to a
    multiple of block_k). Returns [B, C, H, D].

    ``causal_offset``: absolute position of q[0] minus the position of k[0]
    (= prefix length for chunked prefill). ``kv_len``: number of VALID kv
    positions (defaults to T; use when T includes padding).

    ``return_state``: also return the online-softmax residuals — ``(m, l)
    [B, H, C]`` (fp32 running max / denominator) and the UNNORMALIZED fp32
    accumulator ``acc [B, C, H, D]`` straight from VMEM scratch — so the
    caller can COMBINE this kernel's result with other partial-attention
    states at full precision even when the normalized output is bf16. This
    is the seam the pipeline's pluggable attention backend plugs into.

    ``k_scale``/``v_scale`` [B, T, KVH] fp32: when given, k/v are QUANTIZED
    page payloads (int8 / fp8 from ``kvstore.quant``) and the kernel
    dequantizes each block in its epilogue — the KV bytes that cross HBM and
    land in VMEM stay compressed. One scale row per kv token (the page
    store's per-page per-head scales, expanded by the caller), so scales may
    vary across the pages inside one kv block.
    """
    b, c, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = kv_len if kv_len is not None else t
    block_q = min(block_q, c)
    block_k = min(block_k, t)
    assert c % block_q == 0 and t % block_k == 0, (c, t, block_q, block_k)
    nq, nk = c // block_q, t // block_k
    quantized = k_scale is not None
    assert quantized == (v_scale is not None)

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal_offset=causal_offset, kv_len=kv_len,
        block_q=block_q, block_k=block_k, return_state=return_state,
        quantized=quantized)
    out_shape = jax.ShapeDtypeStruct((b, c, h, d), q.dtype)
    out_spec = pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0))
    out_shapes = [out_shape]
    out_specs = [out_spec]
    if return_state:
        ml_spec = pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi, ki: (bi, hi, qi))
        acc_spec = pl.BlockSpec((1, block_q, 1, d),
                                lambda bi, hi, qi, ki: (bi, qi, hi, 0))
        out_shapes += [jax.ShapeDtypeStruct((b, h, c), jnp.float32)] * 2
        out_shapes += [jax.ShapeDtypeStruct((b, c, h, d), jnp.float32)]
        out_specs += [ml_spec, ml_spec, acc_spec]
    in_specs = [
        pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
        pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // g, 0)),
    ]
    args = [q, k, v]
    if quantized:
        sc_spec = pl.BlockSpec((1, block_k, 1),
                               lambda bi, hi, qi, ki: (bi, ki, hi // g))
        in_specs += [sc_spec, sc_spec]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if return_state else out_spec,
        out_shape=out_shapes if return_state else out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denom
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(*args)
    return tuple(res) if return_state else res
