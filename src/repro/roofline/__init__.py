from repro.roofline.analysis import (HW_V5E, RooflineTerms, analyze_lowered,
                                     collective_bytes, model_flops)
