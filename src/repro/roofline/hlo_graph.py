"""Call-graph HLO cost analyzer (trip-count aware).

``compiled.cost_analysis()`` counts every computation ONCE — a scan body that
executes 31 times contributes 1x. Our models are scan-over-layers inside
scan-over-ticks, so we parse the optimized HLO text into a computation call
graph, cost each computation (dot FLOPs, HBM bytes, collective wire bytes),
and roll up through ``while`` ops scaled by XLA's ``known_trip_count``.

Costing rules (per-DEVICE, since post-SPMD HLO is the per-device program):
- dot:           2 * out_elems * contracted_extent  (batch dims included)
- bytes:         output + operands for materializing ops; ops INSIDE fused
                 computations contribute FLOPs but not bytes (fusion does not
                 materialize); gather/dynamic-slice read only what they emit.
- collectives:   ring wire bytes per participant:
                   all-gather / all-to-all: size * (n-1)/n
                   all-reduce:              2 * size * (n-1)/n
                   reduce-scatter:          size (counted on input)
                   collective-permute:      size (point-to-point)
- while:         (body + cond) * known_trip_count
- fusion:        call-site bytes + callee FLOPs
- call/cond:     callee cost once (branches summed — conservative)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1 = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "broadcast",
             "reshape"}
_SLICE_OPS = {"gather", "dynamic-slice", "slice"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start", "reduce-scatter-start",
                "all-to-all-start"}


def _shape_bytes(txt: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_ATOM.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(txt: str) -> List[int]:
    m = _SHAPE_ATOM.search(txt)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes_: float = 0.0
    # "stream" bytes: HBM traffic under PERFECT producer-consumer fusion
    # (Pallas/flash asymptote): only parameter/carry reads, root writes,
    # in-place pool updates and collective payloads touch HBM. This is the
    # roofline's minimum-traffic memory term; bytes_ is the as-compiled one.
    stream_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    # (kind, callee(s), trip) — kind: while | fusion | call
    calls: List[Tuple[str, List[str], int]] = field(default_factory=list)
    # fusion call sites: (callee, out_bytes, [operand_bytes])
    fusion_sites: List[Tuple[str, float, List[float]]] = field(default_factory=list)
    has_dus: bool = False    # contains dynamic-update-slice (in-place pattern)
    has_slice: bool = False  # contains dynamic-slice/gather (windowed read)


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_V2.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_V1.search(line)
    if m and m.group(1).strip():
        return max(len(m.group(1).split(",")), 1)
    return default


def _operands(rest: str) -> List[str]:
    """Operand names from the text following 'op(' (up to its close paren)."""
    depth, out, cur = 1, [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    args = "".join(cur)
    return re.findall(r"%([\w.\-]+)", args)


def _parse_op(line: str) -> Optional[Tuple[str, str, str, str]]:
    """'%name = SHAPE opcode(rest...' -> (name, shape_txt, opcode, rest).
    Bracket-matched: tuple shapes may contain commas, parens and
    '/*index=N*/' comments."""
    s = line.strip()
    root = s.startswith("ROOT ")
    if root:
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):           # tuple shape: match parens
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape_txt, tail = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_txt, tail = rest[:sp], rest[sp + 1:].lstrip()
    par = tail.find("(")
    if par <= 0:
        return None
    op = tail[:par]
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    return name, shape_txt, op, tail[par + 1:], root


_ALIAS_OPS = {"reshape", "bitcast", "transpose", "copy"}


def parse_hlo(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    shapes: Dict[str, str] = {}   # op name -> shape text (global: names unique)
    entry = None
    cur: Optional[_Comp] = None
    real: set = set()             # names backed by HBM (params/carry + aliases)
    # CPU float-normalization promotes bf16 collectives to f32 (reducers named
    # *_promoted). The TPU target keeps them bf16 — project large f32
    # collective payloads back to their logical width (documented in
    # EXPERIMENTS.md §Roofline; calibration tests use f32 models, unaffected).
    bf16_promoted = "clone_promoted" in text
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and "=" not in line.split("(")[0]:
            cur = _Comp(hdr.group(1))
            comps[cur.name] = cur
            real = set()
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        parsed = _parse_op(line)
        if parsed is None or cur is None:
            continue
        name, shape_txt, op, rest, is_root = parsed
        shapes[name] = shape_txt
        out_bytes = _shape_bytes(shape_txt)
        ops_ = _operands(rest)
        ob = [_shape_bytes(shapes.get(o, "")) for o in ops_]
        real_reads = sum(b for o, b in zip(ops_, ob) if o in real)

        if op in ("parameter", "get-tuple-element"):
            real.add(name)
            continue
        if op in _ALIAS_OPS:
            if ops_ and ops_[0] in real:
                real.add(name)
            continue

        if op in _COLLECTIVES:
            kind = op.replace("-start", "")
            n = _group_size(line)
            if kind == "all-gather":
                wire = out_bytes * (n - 1) / n
            elif kind == "all-reduce":
                wire = 2 * out_bytes * (n - 1) / n
            elif kind == "reduce-scatter":
                wire = out_bytes * (n - 1)   # input = out*n; wire = in*(n-1)/n
            elif kind == "all-to-all":
                wire = out_bytes * (n - 1) / n
            else:
                wire = out_bytes
            if bf16_promoted and shape_txt.startswith("f32") \
                    and out_bytes > (1 << 20):
                wire *= 0.5          # TPU dtype projection (see header note)
            cur.coll[kind] = cur.coll.get(kind, 0.0) + wire
            cur.bytes_ += 2 * out_bytes
            cur.stream_bytes += 2 * out_bytes    # wire payloads materialize
            real.add(name)
            continue

        if op == "dot":
            lhs_shape = shapes.get(ops_[0], "") if ops_ else ""
            cdims = _CONTRACT.search(line)
            k = 1
            if cdims and lhs_shape:
                dims = _shape_dims(lhs_shape)
                for ci in cdims.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
            out_elems = out_bytes / max(_DTYPE_BYTES.get(
                _SHAPE_ATOM.search(shape_txt).group(1), 4), 1) \
                if _SHAPE_ATOM.search(shape_txt) else 0
            cur.flops += 2.0 * out_elems * k
            cur.bytes_ += out_bytes + sum(ob[:2])
            cur.stream_bytes += real_reads + (out_bytes if is_root else 0.0)
            continue

        if op == "while":
            trip = 1
            mt = _TRIP.search(line)
            if mt:
                trip = int(mt.group(1))
            callees = _CALLS.findall(line)
            cur.calls.append(("while", callees, trip))
            real.add(name)  # carry round-trips through HBM
            continue

        if op == "fusion":
            callees = _CALLS.findall(line)
            cur.calls.append(("fusion", callees, 1))
            cur.fusion_sites.append(
                (callees[0] if callees else "", out_bytes, list(ob)))
            cur.stream_bytes += real_reads + (out_bytes if is_root else 0.0)
            continue

        if op in ("call", "custom-call", "async-start"):
            callees = _CALLS.findall(line)
            if callees:
                cur.calls.append(("call", callees, 1))
            cur.bytes_ += out_bytes + sum(ob)
            cur.stream_bytes += real_reads + out_bytes
            continue

        if op == "conditional":
            mb = _BRANCHES.search(line)
            callees = []
            if mb:
                callees = re.findall(r"%?([\w.\-]+)", mb.group(1))
            callees += _CALLS.findall(line)
            cur.calls.append(("call", callees, 1))
            continue

        if op == "dynamic-update-slice":
            cur.has_dus = True
            upd = ob[1] if len(ob) > 1 else 0.0
            cur.bytes_ += 2 * upd
            cur.stream_bytes += 2 * upd          # in-place pool write
            if is_root or (ops_ and ops_[0] in real):
                real.add(name)
            continue
        if op in _SLICE_OPS:
            cur.has_slice = True
            cur.bytes_ += 2 * out_bytes          # read only what is emitted
            if ops_ and ops_[0] in real:
                cur.stream_bytes += 2 * out_bytes
            continue
        if op == "scatter":
            upd = ob[1] if len(ob) > 1 else 0.0
            cur.bytes_ += 2 * upd
            cur.stream_bytes += 2 * upd
            continue
        if op in _NO_BYTES:
            continue
        cur.bytes_ += out_bytes + sum(ob)
        cur.stream_bytes += real_reads + (out_bytes if is_root else 0.0)
    comps["__entry__"] = comps.get(entry, _Comp("none"))
    return comps


@dataclass
class GraphCost:
    flops: float
    bytes_: float
    coll: Dict[str, float]
    stream_bytes: float = 0.0

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def rollup(comps: Dict[str, _Comp]) -> GraphCost:
    """DFS from ENTRY accumulating (flops, bytes, collective bytes).
    Fused computations contribute FLOPs + collectives but not bytes; fusion
    call-site bytes are alias-corrected: an in-place-update fusion (callee
    contains dynamic-update-slice, one operand shape == output shape) does
    NOT re-read/re-write the big aliased buffer — only the update slice."""
    memo: Dict[Tuple[str, bool], Tuple[float, float, float, Dict[str, float]]] = {}

    def site_bytes(c: _Comp) -> float:
        total = 0.0
        for callee, out_b, op_bs in c.fusion_sites:
            cal = comps.get(callee)
            aliased = (cal is not None and cal.has_dus
                       and any(abs(b - out_b) < 1 for b in op_bs))
            if aliased:
                rest = [b for b in op_bs]
                for i, b in enumerate(rest):
                    if abs(b - out_b) < 1:      # drop the aliased read
                        rest[i] = 0.0
                        break
                total += sum(rest) * 2          # update read + in-place write
                continue
            ops_eff = list(op_bs)
            if cal is not None and cal.has_slice:
                # windowed-read fusion: a dynamic-slice/gather inside reads
                # only what it emits — cap big operands at the output size
                ops_eff = [min(b, out_b) if b > 4 * out_b else b
                           for b in ops_eff]
            total += out_b + sum(ops_eff)
        return total

    def visit(name: str, fused: bool):
        key = (name, fused)
        if key in memo:
            return memo[key]
        c = comps.get(name)
        if c is None:
            return 0.0, 0.0, 0.0, {}
        memo[key] = (0.0, 0.0, 0.0, {})  # cycle guard
        fl = c.flops
        by = 0.0 if fused else (c.bytes_ + site_bytes(c))
        sb = 0.0 if fused else c.stream_bytes
        co = dict(c.coll)
        for kind, callees, trip in c.calls:
            for callee in callees:
                f2, b2, s2, c2 = visit(callee, fused or kind == "fusion")
                fl += f2 * trip
                by += b2 * trip
                sb += s2 * trip
                for k, v in c2.items():
                    co[k] = co.get(k, 0.0) + v * trip
        memo[key] = (fl, by, sb, co)
        return memo[key]

    f, b, sb, co = visit(comps["__entry__"].name, False)
    return GraphCost(f, b, co, stream_bytes=sb)


def analyze_text(text: str) -> GraphCost:
    return rollup(parse_hlo(text))
