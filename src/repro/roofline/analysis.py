"""Three-term roofline from a compiled dry-run artifact.

Definitions (per task spec, restated for per-device artifacts — post-SPMD
HLO is the per-device program, and ``compiled.cost_analysis()`` is per-device
and counts loop bodies ONCE, so we use the trip-count-aware call-graph
analyzer in ``hlo_graph``):

    compute term    = flops_per_device / peak_FLOP/s
                    (== global_HLO_FLOPs / (chips * peak))
    memory term     = hbm_bytes_per_device / HBM_bw
    collective term = collective_wire_bytes_per_device / link_bw

    MODEL_FLOPS     = 6*N_active*D (train) | 2*N_active*D (prefill/decode)
    useful_ratio    = MODEL_FLOPS / (flops_per_device * chips)
    roofline_fraction = ideal_time(MODEL_FLOPS) / max(term)
                      — the score: how close the USEFUL work runs to peak.

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.roofline import hlo_graph

HW_V5E = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # B/s per chip
    "link_bw": 50e9,          # B/s per ICI link
    "hbm_cap": 16e9,
}


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs (GLOBAL): 6*N*D train, 2*N*D forward; MoE counts
    active params only."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token / request


@dataclass
class RooflineTerms:
    flops_dev: float              # per-device, trip-scaled
    bytes_dev: float              # minimum-traffic (perfect-fusion / Pallas)
    coll_dev: float
    coll_by_kind: Dict[str, float]
    chips: int
    model_flops: float
    bytes_dev_xla: float = 0.0    # as-compiled bytes (CPU XLA materializes
                                  # attention scores etc.) for reference
    xla_flops_raw: float = 0.0    # cost_analysis (loop bodies x1) for reference
    peak_bytes_per_dev: float = 0.0
    hw: Dict[str, float] = field(default_factory=lambda: dict(HW_V5E))

    @property
    def compute_s(self) -> float:
        return self.flops_dev / self.hw["peak_flops"]

    @property
    def memory_s(self) -> float:
        return self.bytes_dev / self.hw["hbm_bw"]

    @property
    def collective_s(self) -> float:
        return self.coll_dev / self.hw["link_bw"]

    @property
    def dominant(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        tot = self.flops_dev * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops / (self.chips * self.hw["peak_flops"])
        return ideal / self.bound_s

    def to_dict(self) -> Dict[str, Any]:
        return {
            "flops_dev": self.flops_dev, "bytes_dev": self.bytes_dev,
            "coll_dev": self.coll_dev, "coll_by_kind": self.coll_by_kind,
            "chips": self.chips, "model_flops": self.model_flops,
            "bytes_dev_xla": self.bytes_dev_xla,
            "xla_flops_raw": self.xla_flops_raw,
            "peak_bytes_per_dev": self.peak_bytes_per_dev,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "RooflineTerms":
        return RooflineTerms(
            flops_dev=d["flops_dev"], bytes_dev=d["bytes_dev"],
            coll_dev=d["coll_dev"], coll_by_kind=d.get("coll_by_kind", {}),
            chips=d["chips"], model_flops=d["model_flops"],
            bytes_dev_xla=d.get("bytes_dev_xla", 0.0),
            xla_flops_raw=d.get("xla_flops_raw", 0.0),
            peak_bytes_per_dev=d.get("peak_bytes_per_dev", 0.0))

    def summary(self) -> str:
        return (f"compute {self.compute_s*1e3:8.2f} ms | memory "
                f"{self.memory_s*1e3:8.2f} ms | collective "
                f"{self.collective_s*1e3:8.2f} ms | {self.dominant:<10} | "
                f"useful {self.useful_ratio*100:5.1f}% | roofline "
                f"{self.roofline_fraction*100:5.1f}%")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    return hlo_graph.analyze_text(hlo_text).coll


def analyze_lowered(lowered, compiled, cfg, shape, chips: int,
                    hw: Optional[Dict[str, float]] = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # old jax: one dict per computation
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    g = hlo_graph.analyze_text(text)
    mem = compiled.memory_analysis()
    peak = float(getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0))
    return RooflineTerms(
        flops_dev=g.flops, bytes_dev=g.stream_bytes, coll_dev=g.coll_total,
        coll_by_kind=g.coll, chips=chips,
        model_flops=model_flops(cfg, shape),
        bytes_dev_xla=g.bytes_,
        xla_flops_raw=float(cost.get("flops", 0.0)),
        peak_bytes_per_dev=peak,
        hw=dict(hw or HW_V5E),
    )
