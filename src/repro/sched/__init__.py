"""Continuous chunk-level scheduling: cross-request pipelining subsystem.

``ChunkScheduler`` keeps the chunked pipeline bubble-free across request
boundaries; ``KVLeaseManager`` guards the MBKR slot budget under concurrent
in-flight requests; ``SchedMetrics``/``TraceRecorder`` provide TTFT/SLO
accounting and Chrome-format JSON traces.
"""
from repro.sched.kvlease import (KVLeaseManager, Lease, LeaseEvent,
                                 request_lease_events, slot_budget_bytes)
from repro.sched.metrics import RequestRecord, SchedMetrics, fleet_summary
from repro.sched.scheduler import (POLICIES, ChunkPlan, ChunkScheduler,
                                   SchedRequest, poisson_arrivals)
from repro.sched.trace import TraceRecorder
