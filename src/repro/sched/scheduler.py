"""Continuous chunk-level scheduler: cross-request pipelining.

MOCAP's engine fills and drains the pipeline once per request (or per
bucket-batch), so at serving scale the N-1-tick fill/drain bubble is paid on
every request boundary. This scheduler admits a stream of TIMESTAMPED
requests and injects the next request's chunk 0 into stage 0 the moment the
previous request's tail chunk vacates it, keeping the pipeline bubble-free
across request boundaries (chunk-granular multiplexing, cf. chunked-prefill
continuous batching and token-grained pipelining).

Mechanics:
- each request carries a per-bucket LBCP chunk plan (``ChunkPlan``: chunk
  sizes + analytic per-chunk cost vectors from ``core.costmodel``);
- stages are in-order, non-preemptive FIFOs; one admitted request's full
  chunk schedule is appended to the per-stage frontier via the shared
  list-scheduling core ``sim.engine.schedule_request``. MBKR spill/fetch
  costs are carried per chunk, and the creditor's serve obligation is folded
  in with the lockstep phase approximation (0.5 x the pair phase's
  spill+fetch, as in the simulator's tick model) rather than the event
  simulator's exact serve-due bookkeeping — schedules are the same
  list-scheduling recurrence but can be slightly optimistic about
  cross-pair serve contention;
- ADMISSION is policy-ordered (FCFS / SJF / EDF, pluggable) and gated by the
  ``KVLeaseManager``: a request is deferred while its projected KV lease
  would push any stage's occupancy over the MBKR slot budget, and rejected
  only if it cannot fit an empty pool;
- TTFT/queueing/SLO metrics (``SchedMetrics``) and a Chrome-format JSON
  trace (``TraceRecorder``) are produced for offline analysis.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel as cm
from repro.core import mbkr as mb
from repro.sched.kvlease import KVLeaseManager, request_lease_events
from repro.sched.metrics import RequestRecord, SchedMetrics
from repro.sched.trace import TraceRecorder
from repro.sim.engine import schedule_request


@dataclass(frozen=True)
class ChunkPlan:
    """Per-bucket chunk plan + analytic cost vectors (all ``[M]``)."""
    bucket: int
    chunks: Tuple[int, ...]
    dur: np.ndarray
    comm: np.ndarray
    kvb: np.ndarray
    spill_t: np.ndarray
    fetch_t: np.ndarray
    serve_t: np.ndarray       # creditor serve approximation (lockstep phase)
    p2: int

    @property
    def task_cost(self) -> np.ndarray:
        return self.dur + self.spill_t + self.fetch_t + self.serve_t

    @property
    def work(self) -> float:
        """Total stage-seconds of one request — the SJF size key."""
        return float(self.task_cost.sum())

    @staticmethod
    def build(bucket: int, chunks: Sequence[int], sm: cm.StageModel,
              hw: cm.ProfileSpec, *, mbkr_plan: Optional[mb.MBKRPlan] = None,
              compress: float = 1.0, prefix_hit_chunks: int = 0
              ) -> "ChunkPlan":
        dur, comm, kvb, spill_t, fetch_t = cm.chunk_cost_arrays(
            sm, chunks, hw, mbkr_plan=mbkr_plan, compress=compress,
            prefix_hit_chunks=prefix_hit_chunks)
        m = len(chunks)
        p2 = m if mbkr_plan is None else mbkr_plan.p2
        # creditor serve time: while my pair (N/2 phases away) spills/fetches,
        # my HBM+link serve half the transfer — the simulator's lockstep
        # approximation folded into the chunk occupying that phase
        serve_t = np.zeros(m)
        if p2 < m:
            n2 = mbkr_plan.num_stages // 2
            for i in range(m):
                pp = (i + m - n2) % m
                serve_t[i] = 0.5 * (spill_t[pp] + fetch_t[pp])
        return ChunkPlan(bucket, tuple(int(c) for c in chunks), dur, comm,
                         kvb, spill_t, fetch_t, serve_t, p2)


@dataclass
class SchedRequest:
    rid: int
    arrival: float
    seq_len: int
    bucket: int = 0
    deadline: float = math.inf      # absolute; inf = no SLO
    state: str = "pending"          # pending | done | rejected
    admit_time: float = math.inf
    finish_time: float = math.inf
    payload: object = None          # opaque engine-side handle (e.g. Request)
    # chained chunk-content hashes (kvstore.prefix.chunk_hashes): the radix
    # index key for cross-request prefix KV reuse; () = never shared
    prefix_hashes: Tuple[int, ...] = ()


# -------------------------------------------------------------- policies

def _fcfs_key(r: SchedRequest, plan: ChunkPlan) -> Tuple:
    return (r.arrival, r.rid)


def _sjf_key(r: SchedRequest, plan: ChunkPlan) -> Tuple:
    return (plan.work, r.arrival, r.rid)


def _edf_key(r: SchedRequest, plan: ChunkPlan) -> Tuple:
    return (r.deadline, r.arrival, r.rid)


POLICIES: Dict[str, Callable[[SchedRequest, ChunkPlan], Tuple]] = {
    "fcfs": _fcfs_key,
    "sjf": _sjf_key,
    "edf": _edf_key,
}


def poisson_arrivals(rate: float, n: int, seed: int = 0,
                     start: float = 0.0) -> List[float]:
    """Open-loop Poisson arrival timestamps: ``n`` i.i.d. exponential gaps at
    ``rate`` req/s. ``rate <= 0`` degenerates to a closed-loop burst at
    ``start`` (everything arrives at once)."""
    if rate <= 0:
        return [start] * n
    rng = np.random.default_rng(seed)
    return list(start + np.cumsum(rng.exponential(1.0 / rate, size=n)))


# -------------------------------------------------------------- scheduler

class ChunkScheduler:
    def __init__(
        self,
        num_stages: int,
        plan_for: Callable[[int], ChunkPlan],
        *,
        policy: str = "fcfs",
        lease: Optional[KVLeaseManager] = None,
        trace: Optional[TraceRecorder] = None,
        compress: float = 1.0,
        kv_compress: float = 1.0,
        stage_scale: Optional[Sequence[float]] = None,
        page_tokens: int = 0,
        prefix_cache: Optional[object] = None,   # kvstore.prefix.PrefixPageCache
        prefix_min_pages: int = 1,
        plan_for_prefix: Optional[Callable[[int, int], ChunkPlan]] = None,
    ):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {sorted(POLICIES)}")
        self.num_stages = num_stages
        self.plan_for = plan_for
        self.policy = policy
        self._key = POLICIES[policy]
        self.lease = lease
        self.trace = trace or TraceRecorder(enabled=False)
        self.compress = compress
        # stored-bytes factor of the KV page codec (kvstore.quant): leases
        # count QUANTIZED bytes, so a one-byte kv_dtype admits ~2x the
        # concurrency against the same physical budget
        self.kv_compress = kv_compress
        # page size for PAGE-GRANULAR lease events (kvlease.chunk_page_
        # bytes): a request leases only the pages its seq_len touches, so
        # bucket-tail padding stops reserving phantom bytes; 0 = one page
        # per chunk (chunks beyond seq_len still lease nothing)
        self.page_tokens = page_tokens
        self.stage_scale = (np.asarray(stage_scale, float)
                            if stage_scale is not None else None)
        # radix prefix index (kvstore.prefix): requests whose leading chunks
        # are resident lease only their novel suffix and are priced against
        # the shorter effective sequence via plan_for_prefix(bucket, k)
        self.prefix_cache = prefix_cache
        self.prefix_min_pages = prefix_min_pages
        self.plan_for_prefix = plan_for_prefix
        self._prefix_leases: Dict[int, object] = {}
        self.pair = [mb.pair_of(s, num_stages) for s in range(num_stages)]
        self.stage_free = np.zeros(num_stages)
        self.requests: List[SchedRequest] = []
        self.admitted: List[SchedRequest] = []   # in admission order
        self.metrics = SchedMetrics(num_stages)

    # ------------------------------------------------------------- intake
    def submit(self, req: SchedRequest) -> None:
        self.requests.append(req)
        self.trace.mark(req.rid, "arrival", req.arrival)

    def rebase_costs(self, plan_for: Callable[[int], ChunkPlan]) -> None:
        """Swap the admission cost source — e.g. nominal -> CALIBRATED
        profile once ``obs.calibrate`` lands a fit. Already-admitted
        requests, the per-stage busy frontier, and live KV leases are
        untouched; only FUTURE candidates are policy-keyed and scheduled
        with the new cost vectors, so a mid-stream recalibration never
        reorders history (asserted in tests/test_calibration.py)."""
        self.plan_for = plan_for

    # ------------------------------------------------------------- prefix
    def _prefix_hit(self, plan: ChunkPlan, prefix_hashes: Sequence[int],
                    seq_len: int) -> int:
        """Clamped hit length, in chunks: what the radix index serves of
        this request.  Clamps: only chunks fully inside ``seq_len`` can be
        shared, the tail chunk always runs (it produces the logits), hit
        chunks never exceed ``p2`` (spilled chunks are pair-hosted, not
        index-addressed), and hits below ``prefix_min_pages`` are ignored
        (tiny prefixes aren't worth the indexing churn)."""
        if self.prefix_cache is None or not prefix_hashes:
            return 0
        k = self.prefix_cache.match(prefix_hashes)
        covered, start = 0, 0
        for c in plan.chunks:
            if start + int(c) > seq_len:
                break
            covered += 1
            start += int(c)
        k = min(k, covered, plan.p2, len(plan.chunks) - 1)
        if k * self.prefix_cache.pages_per_chunk < self.prefix_min_pages:
            return 0
        return k

    def _effective(self, bucket: int, plan: ChunkPlan, k: int
                   ) -> Tuple[ChunkPlan, Optional[List[int]]]:
        """The plan + per-chunk shared-page vector a hit of ``k`` chunks is
        priced with: zero compute/wire rows for served chunks, zero lease
        bytes for their pages."""
        if k <= 0:
            return plan, None
        if self.plan_for_prefix is not None:
            plan = self.plan_for_prefix(bucket, k)
        ppc = self.prefix_cache.pages_per_chunk
        shared = [ppc] * k + [0] * (len(plan.chunks) - k)
        return plan, shared

    def _prune_prefix(self) -> None:
        """Release radix references of requests whose KV lease was pruned
        (drained): their shared pages drop to the cache's LRU pool."""
        if self.prefix_cache is None or not self._prefix_leases:
            return
        live = set(self.lease.leases) if self.lease is not None else set()
        for rid in [r for r in self._prefix_leases if r not in live]:
            self.prefix_cache.release(self._prefix_leases.pop(rid))

    def prefix_stats(self) -> Dict:
        return (dict(self.prefix_cache.stats())
                if self.prefix_cache is not None else {})

    # ------------------------------------------------------------ preview
    def preview(self, bucket: int, seq_len: int,
                release: float = 0.0,
                prefix_hashes: Sequence[int] = ()) -> Tuple[float, bool]:
        """Placement signal (``repro.fleet``): the finish time a request of
        ``seq_len`` in ``bucket`` WOULD get if admitted against the current
        per-stage frontier, plus whether its KV lease fits the committed
        timeline right now. Pure — no scheduler state is mutated. When the
        lease does not fit, the ETA is padded by the wait until the next
        committed release (the earliest instant a deferred admission could
        retry), so a lease-packed "hot" cell quotes an honestly later finish
        than an idle "cold" one; a request that can NEVER fit (empty pool
        and still refused) quotes ``inf``.

        ``prefix_hashes`` folds the radix index into the quote: a resident
        prefix prices the shorter effective sequence AND a suffix-only
        lease, so a cell already holding the prefix quotes an earlier ETA
        (the fleet's prefix-affinity signal)."""
        plan = self.plan_for(bucket)
        k = self._prefix_hit(plan, prefix_hashes, seq_len)
        plan, shared = self._effective(bucket, plan, k)
        frontier = self.stage_free.copy()
        finish = schedule_request(plan.task_cost, plan.comm, self.num_stages,
                                  frontier, release=release,
                                  stage_scale=self.stage_scale)
        eta = float(finish[-1][-1])
        fits = True
        if self.lease is not None:
            lease = request_lease_events(-1, finish, plan.kvb, plan.p2,
                                         self.pair, self.compress,
                                         self.kv_compress, seq_len=seq_len,
                                         chunks=plan.chunks,
                                         page_tokens=self.page_tokens,
                                         shared_pages=shared)
            fits = self.lease.would_fit(lease)
            if not fits:
                t_now = max(float(self.stage_free[0]), release)
                nxt = self.lease.next_release(t_now)
                eta = (eta + max(nxt - t_now, 0.0) if math.isfinite(nxt)
                       else math.inf)
        return eta, fits

    # ------------------------------------------------------------ running
    def _try_admit(self, r: SchedRequest, release: float) -> bool:
        """Tentatively schedule ``r`` from ``release``; commit if its KV
        lease fits every stage budget. Mutates scheduler state on success."""
        plan = self.plan_for(r.bucket)
        k = self._prefix_hit(plan, r.prefix_hashes, r.seq_len)
        plan, shared = self._effective(r.bucket, plan, k)
        frontier = self.stage_free.copy()
        finish = schedule_request(plan.task_cost, plan.comm, self.num_stages,
                                  frontier, release=release,
                                  stage_scale=self.stage_scale)
        if self.lease is not None:
            lease = request_lease_events(r.rid, finish, plan.kvb, plan.p2,
                                         self.pair, self.compress,
                                         self.kv_compress,
                                         seq_len=r.seq_len,
                                         chunks=plan.chunks,
                                         page_tokens=self.page_tokens,
                                         shared_pages=shared)
            if not self.lease.admit(lease):
                return False
        # commit: reference the hit prefix + index the novel suffix
        if self.prefix_cache is not None and r.prefix_hashes:
            self._prefix_leases[r.rid] = self.prefix_cache.acquire(
                r.rid, r.prefix_hashes)
        # commit: replay for the hooks (busy accounting + trace)
        self.stage_free = frontier
        m = len(plan.chunks)
        for i in range(m):
            for s in range(self.num_stages):
                tf = finish[i][s]
                d = plan.task_cost[i] * (self.stage_scale[s]
                                         if self.stage_scale is not None else 1.0)
                self.metrics.observe_busy(s, float(d))
                self.trace.task(r.rid, i, s, float(tf - d), float(tf))
        d0 = plan.task_cost[0] * (self.stage_scale[0]
                                  if self.stage_scale is not None else 1.0)
        r.state = "done"
        r.admit_time = float(finish[0][0] - d0)   # chunk-0 start at stage 0
        r.finish_time = float(finish[m - 1][self.num_stages - 1])
        self.admitted.append(r)
        self.trace.mark(r.rid, "admit", r.admit_time)
        self.trace.mark(r.rid, "finish", r.finish_time)
        self.metrics.observe(RequestRecord(
            rid=r.rid, arrival=r.arrival, seq_len=r.seq_len, bucket=r.bucket,
            admit=r.admit_time, finish=r.finish_time, deadline=r.deadline))
        return True

    def _reject(self, r: SchedRequest, now: float) -> None:
        r.state = "rejected"
        self.trace.mark(r.rid, "reject", now)
        self.metrics.observe(RequestRecord(
            rid=r.rid, arrival=r.arrival, seq_len=r.seq_len, bucket=r.bucket,
            deadline=r.deadline, rejected=True))

    def run(self) -> List[SchedRequest]:
        """Drain all submitted requests; returns them in admission order.

        Event loop: whenever stage 0 can accept a new head chunk, pick the
        policy-preferred request among those that have ARRIVED by then; a
        request whose KV lease does not fit is passed over (the next
        candidate is tried) and retried at the next lease release or
        arrival — it is rejected only if it cannot fit an empty pool.
        """
        pending = [r for r in self.requests if r.state == "pending"]
        guard = 0
        while pending:
            guard += 1
            if guard > 100_000:
                raise RuntimeError("scheduler event loop did not converge")
            t_now = max(float(self.stage_free[0]),
                        min(r.arrival for r in pending))
            arrived = [r for r in pending if r.arrival <= t_now]
            arrived.sort(key=lambda r: self._key(r, self.plan_for(r.bucket)))
            admitted_one = False
            for r in arrived:
                if self._try_admit(r, t_now):
                    pending.remove(r)
                    admitted_one = True
                    break
            if admitted_one:
                if self.lease is not None:
                    self.lease.prune(before=t_now)
                self._prune_prefix()
                continue
            # every arrived candidate was lease-refused: wait for the next
            # release or arrival; reject candidates that can never fit
            future = [r.arrival for r in pending if r.arrival > t_now]
            t_retry = min(future) if future else math.inf
            if self.lease is not None:
                t_retry = min(t_retry, self.lease.next_release(t_now))
                if not self.lease.leases:
                    for r in arrived:          # empty pool and still refused
                        self._reject(r, t_now)
                        pending.remove(r)
                    continue
            if math.isinf(t_retry):
                for r in arrived:
                    self._reject(r, t_now)
                    pending.remove(r)
                continue
            # advance the head frontier so the next candidate set is drawn
            # at the retry instant
            self.stage_free[0] = max(self.stage_free[0], t_retry)
        return self.admitted

    # ------------------------------------------------------------ results
    def summary(self) -> Dict:
        out = self.metrics.summary()
        out["policy"] = self.policy
        if self.lease is not None:
            out["lease_refusals"] = self.lease.refusals
            out["lease_hwm_frac"] = float(
                (self.lease.hwm / np.maximum(self.lease.budget, 1e-12)).max())
        out.update(self.prefix_stats())
        return out
