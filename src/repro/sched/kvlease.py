"""Shared KV-pool lease manager for cross-request chunk pipelining.

With one request in flight, MBKR's static slot plan (``core.mbkr``) proves
per-stage occupancy stays within ``num_slots`` chunk slots. Continuous
scheduling admits the NEXT request's chunks into early stages while the
previous request's KV still drains from late stages — and may mix buckets
whose chunks have different byte sizes — so the slot-plan guarantee no longer
comes for free. The lease manager restores it by accounting:

- a LEASE per admitted request: the full timestamped alloc/free event stream
  the request will generate at every stage (local chunk KV below p2, hosted
  spill bytes at the MBKR pair stage from p2 on), known analytically at
  admission time because stages are in-order FIFOs;
- a per-stage byte BUDGET (the MBKR slot pool: ``num_slots`` x the largest
  admitted chunk's KV bytes, never more than the stage's physical capacity);
- an admission check: a request is admitted only if merging its lease into
  the committed timeline keeps every stage's peak occupancy <= budget — the
  scheduler defers (or ultimately rejects) the request otherwise.

The high-water mark per stage is tracked so tests can assert the invariant
``hwm <= budget`` under arbitrary concurrent workloads.
"""
from __future__ import annotations

import math
from bisect import insort
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class LeaseEvent:
    stage: int
    time: float
    nbytes: float        # positive = alloc, negative = free


@dataclass
class Lease:
    """One admitted request's reservation: its full event stream plus the
    time at which the last byte is released (all stages drained)."""
    rid: int
    events: Tuple[LeaseEvent, ...]
    release_time: float


def chunk_page_bytes(
    kvb: Sequence[float],
    chunks: Sequence[int],
    seq_len: Optional[int],
    page_tokens: int,
    shared_pages: Optional[Sequence[int]] = None,
) -> List[float]:
    """Per-chunk STORED bytes at PAGE granularity.

    ``kvb[i]`` prices the whole bucket chunk; the page store only allocates
    pages for the request's VALID tokens (a request near the bottom of its
    bucket fills only part of its tail chunk, and chunks entirely beyond
    ``seq_len`` allocate nothing). Bytes round UP to whole pages — page
    granularity, not token granularity — and never exceed the whole-chunk
    figure. ``page_tokens <= 0`` means one page per chunk (the coarsest
    paging: a touched chunk allocates fully, an untouched chunk nothing).
    ``seq_len=None`` keeps the legacy whole-bucket accounting.

    ``shared_pages[i]`` is the number of chunk-``i`` pages already resident
    in the prefix index (``kvstore.prefix``): shared pages cost ZERO lease
    bytes — the holder of the radix refcount pays for them once — so a
    request whose prefix hits leases only its novel suffix.  With
    ``seq_len=None`` sharing applies against the whole-chunk page count.
    """
    if seq_len is None and shared_pages is None:
        return [float(b) for b in kvb]
    out: List[float] = []
    start = 0
    for i, (b, c) in enumerate(zip(kvb, chunks)):
        pt = page_tokens if page_tokens > 0 else int(c)
        full_pages = -(-int(c) // pt)
        if seq_len is None:
            n_pages = full_pages
        else:
            valid = min(max(seq_len - start, 0), int(c))
            n_pages = min(-(-valid // pt), full_pages)
        if shared_pages is not None and i < len(shared_pages):
            n_pages = max(n_pages - int(shared_pages[i]), 0)
        out.append(float(b) * n_pages / full_pages)
        start += int(c)
    return out


def request_lease_events(
    rid: int,
    finish: np.ndarray,            # [M][N] chunk completion times
    kvb: Sequence[float],          # [M] chunk KV bytes (model dtype)
    p2: int,
    pair: Sequence[int],           # stage -> MBKR pair stage
    compress: float = 1.0,
    kv_compress: float = 1.0,
    *,
    seq_len: Optional[int] = None,
    chunks: Optional[Sequence[int]] = None,
    page_tokens: int = 0,
    shared_pages: Optional[Sequence[int]] = None,
) -> Lease:
    """Build the lease for one scheduled request from its chunk finish times.

    Chunk i's KV materializes at the stage when the chunk completes there
    (locally for i < p2, at the pair stage scaled by ``compress`` for spilled
    chunks); everything a request holds at stage s frees when its tail chunk
    clears s — the same lifecycle the event simulator's memory tracker uses.
    Alloc AND free events are per-chunk page allocations (see
    ``chunk_page_bytes``): with ``seq_len``/``chunks``/``page_tokens`` given,
    a request leases only the pages its valid tokens touch — a long unused
    bucket tail (seq_len far below the bucket) stops reserving phantom
    bytes, so longer-tail buckets admit sooner (asserted in test_sched).

    ``kv_compress`` is the KV page store's stored-bytes factor
    (``kvstore.quant.kv_compress_factor``): with a quantized ``kv_dtype``
    EVERY resident byte — local and hosted — shrinks by it, which is what
    grows admission capacity ~2x per one-byte codec at a fixed physical
    budget. ``compress`` stays the legacy wire/creditor factor applied to
    spilled chunks only.

    ``shared_pages`` (per chunk, from the prefix index ``kvstore.prefix``)
    zeroes the lease price of pages another live lease already holds —
    suffix-only leasing (DESIGN.md §11): the alloc/free EVENTS of
    fully-shared chunks vanish, so peaks, headroom and the high-water mark
    all see only novel bytes.
    """
    m, n = finish.shape
    if chunks is None:
        seq_len = None  # page accounting needs the chunk split
        shared_pages = None
    pkvb = chunk_page_bytes(kvb, chunks if chunks is not None else [1] * m,
                            seq_len, page_tokens, shared_pages)
    ev: List[LeaseEvent] = []
    for s in range(n):
        t_drain = float(finish[m - 1][s])
        for i in range(m):
            b = pkvb[i] * kv_compress
            if i >= p2:
                b *= compress
            if b == 0.0:
                continue  # beyond seq_len: no pages, no events
            stage = s if i < p2 else pair[s]
            ev.append(LeaseEvent(stage, float(finish[i][s]), b))
            ev.append(LeaseEvent(stage, t_drain, -b))
    release = float(finish[m - 1].max())
    return Lease(rid, tuple(ev), release)


class KVLeaseManager:
    """Per-stage KV occupancy accounting with admission control.

    ``budget[s]`` is in bytes (derive it from an MBKR plan with
    ``slot_budget_bytes``). Frees sort before allocs at equal timestamps —
    the slot plan reuses a slot at the very tick its tenant dies.
    """

    def __init__(self, num_stages: int, budget: Sequence[float]):
        assert len(budget) == num_stages
        self.num_stages = num_stages
        self.budget = np.asarray(budget, float)
        # committed timeline per stage: sorted (time, delta) with frees first
        self._timeline: List[List[Tuple[float, float]]] = [
            [] for _ in range(num_stages)]
        self.leases: Dict[int, Lease] = {}
        self.hwm = np.zeros(num_stages)
        self._refused_rids: set = set()

    @property
    def refusals(self) -> int:
        """DISTINCT requests ever refused (a deferred request retried many
        times counts once)."""
        return len(self._refused_rids)

    # ------------------------------------------------------------- queries
    def _peak_with(self, stage: int, extra: List[Tuple[float, float]]) -> float:
        ev = sorted(self._timeline[stage] + extra)
        cur = peak = 0.0
        for _, d in ev:
            cur += d
            peak = max(peak, cur)
        return peak

    def _fit_peaks(self, lease: Lease) -> Optional[Dict[int, float]]:
        """Per-touched-stage peaks with the lease merged in, or None if any
        stage would exceed its budget."""
        per_stage: Dict[int, List[Tuple[float, float]]] = {}
        for e in lease.events:
            per_stage.setdefault(e.stage, []).append((e.time, e.nbytes))
        peaks: Dict[int, float] = {}
        for s, extra in per_stage.items():
            pk = self._peak_with(s, extra)
            if pk > self.budget[s] * (1 + 1e-9):
                return None
            peaks[s] = pk
        return peaks

    def would_fit(self, lease: Lease) -> bool:
        return self._fit_peaks(lease) is not None

    def headroom(self, after: float = 0.0) -> np.ndarray:
        """Per-stage FREE bytes guaranteed from ``after`` on: budget minus
        the peak committed occupancy over ``[after, inf)`` (the level carried
        into ``after`` counts — a lease allocated before and freed after
        still occupies the pool at ``after``). This is the router's
        free-KV-lease signal (``repro.fleet``): a cell whose pool is packed
        with long-lived leases reports near-zero headroom even if nothing is
        executing this instant."""
        free = np.empty(self.num_stages)
        for s, tl in enumerate(self._timeline):
            events = sorted(tl)
            cur = 0.0
            i = 0
            while i < len(events) and events[i][0] < after:
                cur += events[i][1]
                i += 1
            peak = cur
            for _, d in events[i:]:
                cur += d
                peak = max(peak, cur)
            free[s] = self.budget[s] - peak
        return free

    # ------------------------------------------------------------ mutation
    def admit(self, lease: Lease) -> bool:
        """Commit the lease if it fits every stage's budget; else refuse."""
        peaks = self._fit_peaks(lease)
        if peaks is None:
            self._refused_rids.add(lease.rid)
            return False
        for e in lease.events:
            insort(self._timeline[e.stage], (e.time, e.nbytes))
        for s, pk in peaks.items():   # only touched stages can move the hwm
            self.hwm[s] = max(self.hwm[s], pk)
        self.leases[lease.rid] = lease
        return True

    def next_release(self, after: float) -> float:
        """Earliest committed lease release strictly after ``after`` — the
        next instant a deferred admission is worth retrying."""
        times = [l.release_time for l in self.leases.values()
                 if l.release_time > after]
        return min(times) if times else math.inf

    def prune(self, before: float) -> None:
        """Drop fully-released leases that ended before ``before`` (their
        alloc/free pairs cancel; keeps timelines from growing unboundedly)."""
        from collections import Counter
        dead = [rid for rid, l in self.leases.items()
                if l.release_time < before]
        if not dead:
            return
        drop = Counter((e.stage, e.time, e.nbytes)
                       for rid in dead for e in self.leases[rid].events)
        for s in range(self.num_stages):
            keep = []
            for t, d in self._timeline[s]:
                if drop.get((s, t, d), 0) > 0:
                    drop[(s, t, d)] -= 1
                else:
                    keep.append((t, d))
            self._timeline[s] = keep
        for rid in dead:
            del self.leases[rid]


def slot_budget_bytes(num_slots: int, chunk_bytes: float, num_stages: int,
                      capacity: Optional[float] = None) -> np.ndarray:
    """Per-stage byte budget for the MBKR slot pool: ``num_slots`` slots sized
    for the largest chunk, clamped to the physical KV capacity if given."""
    b = num_slots * chunk_bytes
    if capacity is not None:
        b = min(b, capacity)
    return np.full(num_stages, float(b))
