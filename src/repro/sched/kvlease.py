"""Shared KV-pool lease manager for cross-request chunk pipelining.

With one request in flight, MBKR's static slot plan (``core.mbkr``) proves
per-stage occupancy stays within ``num_slots`` chunk slots. Continuous
scheduling admits the NEXT request's chunks into early stages while the
previous request's KV still drains from late stages — and may mix buckets
whose chunks have different byte sizes — so the slot-plan guarantee no longer
comes for free. The lease manager restores it by accounting:

- a LEASE per admitted request: the full timestamped alloc/free event stream
  the request will generate at every stage (local chunk KV below p2, hosted
  spill bytes at the MBKR pair stage from p2 on), known analytically at
  admission time because stages are in-order FIFOs;
- a per-stage byte BUDGET (the MBKR slot pool: ``num_slots`` x the largest
  admitted chunk's KV bytes, never more than the stage's physical capacity);
- an admission check: a request is admitted only if merging its lease into
  the committed timeline keeps every stage's peak occupancy <= budget — the
  scheduler defers (or ultimately rejects) the request otherwise.

The high-water mark per stage is tracked so tests can assert the invariant
``hwm <= budget`` under arbitrary concurrent workloads.
"""
from __future__ import annotations

import math
from bisect import insort
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class LeaseEvent:
    stage: int
    time: float
    nbytes: float        # positive = alloc, negative = free


@dataclass
class Lease:
    """One admitted request's reservation: its full event stream plus the
    time at which the last byte is released (all stages drained)."""
    rid: int
    events: Tuple[LeaseEvent, ...]
    release_time: float


def request_lease_events(
    rid: int,
    finish: np.ndarray,            # [M][N] chunk completion times
    kvb: Sequence[float],          # [M] chunk KV bytes (model dtype)
    p2: int,
    pair: Sequence[int],           # stage -> MBKR pair stage
    compress: float = 1.0,
    kv_compress: float = 1.0,
) -> Lease:
    """Build the lease for one scheduled request from its chunk finish times.

    Chunk i's KV materializes at the stage when the chunk completes there
    (locally for i < p2, at the pair stage scaled by ``compress`` for spilled
    chunks); everything a request holds at stage s frees when its tail chunk
    clears s — the same lifecycle the event simulator's memory tracker uses.

    ``kv_compress`` is the KV page store's stored-bytes factor
    (``kvstore.quant.kv_compress_factor``): with a quantized ``kv_dtype``
    EVERY resident byte — local and hosted — shrinks by it, which is what
    grows admission capacity ~2x per one-byte codec at a fixed physical
    budget. ``compress`` stays the legacy wire/creditor factor applied to
    spilled chunks only.
    """
    m, n = finish.shape
    ev: List[LeaseEvent] = []
    local = sum(kvb[:p2]) * kv_compress
    hosted = sum(kvb[p2:]) * compress * kv_compress
    for s in range(n):
        for i in range(m):
            if i < p2:
                ev.append(LeaseEvent(s, float(finish[i][s]),
                                     float(kvb[i]) * kv_compress))
            else:
                ev.append(LeaseEvent(pair[s], float(finish[i][s]),
                                     float(kvb[i]) * compress * kv_compress))
        t_drain = float(finish[m - 1][s])
        if local:
            ev.append(LeaseEvent(s, t_drain, -float(local)))
        if hosted:
            ev.append(LeaseEvent(pair[s], t_drain, -float(hosted)))
    release = float(finish[m - 1].max())
    return Lease(rid, tuple(ev), release)


class KVLeaseManager:
    """Per-stage KV occupancy accounting with admission control.

    ``budget[s]`` is in bytes (derive it from an MBKR plan with
    ``slot_budget_bytes``). Frees sort before allocs at equal timestamps —
    the slot plan reuses a slot at the very tick its tenant dies.
    """

    def __init__(self, num_stages: int, budget: Sequence[float]):
        assert len(budget) == num_stages
        self.num_stages = num_stages
        self.budget = np.asarray(budget, float)
        # committed timeline per stage: sorted (time, delta) with frees first
        self._timeline: List[List[Tuple[float, float]]] = [
            [] for _ in range(num_stages)]
        self.leases: Dict[int, Lease] = {}
        self.hwm = np.zeros(num_stages)
        self._refused_rids: set = set()

    @property
    def refusals(self) -> int:
        """DISTINCT requests ever refused (a deferred request retried many
        times counts once)."""
        return len(self._refused_rids)

    # ------------------------------------------------------------- queries
    def _peak_with(self, stage: int, extra: List[Tuple[float, float]]) -> float:
        ev = sorted(self._timeline[stage] + extra)
        cur = peak = 0.0
        for _, d in ev:
            cur += d
            peak = max(peak, cur)
        return peak

    def _fit_peaks(self, lease: Lease) -> Optional[Dict[int, float]]:
        """Per-touched-stage peaks with the lease merged in, or None if any
        stage would exceed its budget."""
        per_stage: Dict[int, List[Tuple[float, float]]] = {}
        for e in lease.events:
            per_stage.setdefault(e.stage, []).append((e.time, e.nbytes))
        peaks: Dict[int, float] = {}
        for s, extra in per_stage.items():
            pk = self._peak_with(s, extra)
            if pk > self.budget[s] * (1 + 1e-9):
                return None
            peaks[s] = pk
        return peaks

    def would_fit(self, lease: Lease) -> bool:
        return self._fit_peaks(lease) is not None

    # ------------------------------------------------------------ mutation
    def admit(self, lease: Lease) -> bool:
        """Commit the lease if it fits every stage's budget; else refuse."""
        peaks = self._fit_peaks(lease)
        if peaks is None:
            self._refused_rids.add(lease.rid)
            return False
        for e in lease.events:
            insort(self._timeline[e.stage], (e.time, e.nbytes))
        for s, pk in peaks.items():   # only touched stages can move the hwm
            self.hwm[s] = max(self.hwm[s], pk)
        self.leases[lease.rid] = lease
        return True

    def next_release(self, after: float) -> float:
        """Earliest committed lease release strictly after ``after`` — the
        next instant a deferred admission is worth retrying."""
        times = [l.release_time for l in self.leases.values()
                 if l.release_time > after]
        return min(times) if times else math.inf

    def prune(self, before: float) -> None:
        """Drop fully-released leases that ended before ``before`` (their
        alloc/free pairs cancel; keeps timelines from growing unboundedly)."""
        from collections import Counter
        dead = [rid for rid, l in self.leases.items()
                if l.release_time < before]
        if not dead:
            return
        drop = Counter((e.stage, e.time, e.nbytes)
                       for rid in dead for e in self.leases[rid].events)
        for s in range(self.num_stages):
            keep = []
            for t, d in self._timeline[s]:
                if drop.get((s, t, d), 0) > 0:
                    drop[(s, t, d)] -= 1
                else:
                    keep.append((t, d))
            self._timeline[s] = keep
        for rid in dead:
            del self.leases[rid]


def slot_budget_bytes(num_slots: int, chunk_bytes: float, num_stages: int,
                      capacity: Optional[float] = None) -> np.ndarray:
    """Per-stage byte budget for the MBKR slot pool: ``num_slots`` slots sized
    for the largest chunk, clamped to the physical KV capacity if given."""
    b = num_slots * chunk_bytes
    if capacity is not None:
        b = min(b, capacity)
    return np.full(num_stages, float(b))
