"""Compatibility shim: the trace recorder moved to ``repro.obs.trace``.

The scheduler-facing surface (``TaskEvent``/``MarkEvent``/``TraceRecorder``)
is unchanged; the recorder additionally accepts engine spans and counter
tracks so one file merges scheduler + engine + device telemetry (ISSUE 6).
"""
from repro.obs.trace import (  # noqa: F401
    CounterEvent,
    MarkEvent,
    SpanEvent,
    TaskEvent,
    TraceRecorder,
)

__all__ = ["CounterEvent", "MarkEvent", "SpanEvent", "TaskEvent",
           "TraceRecorder"]
