"""JSON trace exporter for the chunk-level scheduler.

Records per-task (request, chunk, stage) execution intervals plus request
lifecycle instants (arrival, admission, completion, rejection) and exports
them in the Chrome trace-event format (``chrome://tracing`` / Perfetto):
one "process" per pipeline stage, one "thread" per request, so the pipeline
occupancy and cross-request interleaving are directly visible. Plain
offline-analysis access is available through ``events()``.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass
from typing import Any, Dict, List


@dataclass(frozen=True)
class TaskEvent:
    rid: int
    chunk: int
    stage: int
    start: float          # seconds (scheduler clock)
    finish: float


@dataclass(frozen=True)
class MarkEvent:
    rid: int
    kind: str             # arrival | admit | finish | reject
    time: float


class TraceRecorder:
    """Accumulates scheduler events; cheap no-op when disabled."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.tasks: List[TaskEvent] = []
        self.marks: List[MarkEvent] = []

    def task(self, rid: int, chunk: int, stage: int,
             start: float, finish: float) -> None:
        if self.enabled:
            self.tasks.append(TaskEvent(rid, chunk, stage, start, finish))

    def mark(self, rid: int, kind: str, time: float) -> None:
        if self.enabled:
            self.marks.append(MarkEvent(rid, kind, time))

    # ------------------------------------------------------------- export
    def events(self) -> Dict[str, List[Dict[str, Any]]]:
        """Raw event dicts for offline analysis."""
        return {"tasks": [asdict(t) for t in self.tasks],
                "marks": [asdict(m) for m in self.marks]}

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: pid = stage, tid = request, ts in us."""
        ev: List[Dict[str, Any]] = []
        for t in self.tasks:
            ev.append({
                "name": f"r{t.rid}/c{t.chunk}",
                "cat": "chunk",
                "ph": "X",
                "ts": t.start * 1e6,
                "dur": (t.finish - t.start) * 1e6,
                "pid": t.stage,
                "tid": t.rid,
                "args": {"rid": t.rid, "chunk": t.chunk, "stage": t.stage},
            })
        for m in self.marks:
            ev.append({
                "name": m.kind,
                "cat": "request",
                "ph": "i",
                "s": "g",
                "ts": m.time * 1e6,
                "pid": 0,
                "tid": m.rid,
            })
        for t in sorted({t.stage for t in self.tasks}):
            ev.append({"name": "process_name", "ph": "M", "pid": t,
                       "args": {"name": f"stage {t}"}})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path`` (dirs created)."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path
