"""Serving metrics for the chunk-level scheduler: TTFT, queueing delay,
SLO attainment, throughput, and pipeline-bubble accounting.

For prefill-only serving the first output token materializes when the LAST
chunk clears the LAST stage, so TTFT == request completion latency
(arrival -> finish); it decomposes into queueing delay (arrival -> admission
into stage 0) plus pipeline execution. SLO attainment is the fraction of
deadline-carrying requests that finish by their deadline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Sequence

import numpy as np


@dataclass
class RequestRecord:
    rid: int
    arrival: float
    seq_len: int
    bucket: int
    admit: float = math.inf
    finish: float = math.inf
    deadline: float = math.inf
    rejected: bool = False

    @property
    def ttft(self) -> float:
        return self.finish - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.admit - self.arrival

    @property
    def met_slo(self) -> bool:
        return (not self.rejected) and self.finish <= self.deadline


class SchedMetrics:
    """Accumulates per-request records plus per-stage busy seconds."""

    def __init__(self, num_stages: int):
        self.records: List[RequestRecord] = []
        self.busy = np.zeros(num_stages)
        self.makespan = 0.0

    def observe(self, rec: RequestRecord) -> None:
        self.records.append(rec)
        if not rec.rejected and math.isfinite(rec.finish):
            self.makespan = max(self.makespan, rec.finish)

    def observe_busy(self, stage: int, seconds: float) -> None:
        self.busy[stage] += seconds

    # ------------------------------------------------------------- summary
    def summary(self) -> Dict[str, Any]:
        done = [r for r in self.records if not r.rejected
                and math.isfinite(r.finish)]
        ttft = np.array([r.ttft for r in done])
        wait = np.array([r.queue_wait for r in done])
        with_slo = [r for r in self.records if math.isfinite(r.deadline)]
        mk = self.makespan
        util = self.busy / mk if mk > 0 else np.zeros_like(self.busy)
        # peak concurrency: max overlap of the [admit, finish) intervals —
        # the admits-more-at-equal-budget signal prefix sharing moves
        ev = sorted([(r.admit, 1) for r in done if math.isfinite(r.admit)]
                    + [(r.finish, -1) for r in done if math.isfinite(r.admit)])
        cur = peak = 0
        for _, d in ev:
            cur += d
            peak = max(peak, cur)
        return {
            "completed": len(done),
            "peak_inflight": peak,
            "rejected": sum(r.rejected for r in self.records),
            "makespan": mk,
            "throughput": len(done) / mk if mk > 0 else 0.0,
            "avg_ttft": float(ttft.mean()) if len(ttft) else math.nan,
            "p50_ttft": float(np.percentile(ttft, 50)) if len(ttft) else math.nan,
            "p99_ttft": float(np.percentile(ttft, 99)) if len(ttft) else math.nan,
            "avg_queue_wait": float(wait.mean()) if len(wait) else math.nan,
            "p99_queue_wait": float(np.percentile(wait, 99)) if len(wait) else math.nan,
            "slo_total": len(with_slo),
            "slo_met": sum(r.met_slo for r in with_slo),
            "slo_attainment": (sum(r.met_slo for r in with_slo) / len(with_slo)
                               if with_slo else math.nan),
            # bubble fraction of the busiest stage: 1 - busy/makespan
            "bubble_frac": float(1.0 - util.max()) if mk > 0 else math.nan,
            "avg_stage_util": float(util.mean()) if mk > 0 else math.nan,
        }


def fleet_summary(
        records_by_cell: Mapping[str, Sequence[RequestRecord]],
        router_rejections: int = 0,
) -> Dict[str, Any]:
    """Fleet-level serving summary over MANY cells' request records
    (``repro.fleet``): the SLO-attainment / TTFT view of the WHOLE arrival
    stream, regardless of which cell served each request, plus a per-cell
    breakdown. Cells share the arrival clock (each scheduler's virtual time
    starts at the stream's t=0), so records merge directly: fleet makespan
    is the latest finish anywhere, fleet throughput is total completions
    over it.

    ``router_rejections`` counts requests the FLEET-LEVEL admission
    controller turned away before any cell saw them (``FleetRouter.place``
    reject-with-retry-after when every cell's lease headroom is exhausted);
    they fold into the fleet ``rejected`` total and get their own key."""
    merged: List[RequestRecord] = [r for recs in records_by_cell.values()
                                   for r in recs]
    done = [r for r in merged if not r.rejected and math.isfinite(r.finish)]
    ttft = np.array([r.ttft for r in done])
    with_slo = [r for r in merged if math.isfinite(r.deadline)]
    mk = max((r.finish for r in done), default=0.0)
    per_cell: Dict[str, Dict[str, Any]] = {}
    for name, recs in records_by_cell.items():
        cdone = [r for r in recs if not r.rejected and math.isfinite(r.finish)]
        cttft = np.array([r.ttft for r in cdone])
        cslo = [r for r in recs if math.isfinite(r.deadline)]
        per_cell[name] = {
            "completed": len(cdone),
            "rejected": sum(r.rejected for r in recs),
            "p99_ttft": float(np.percentile(cttft, 99)) if len(cttft)
                        else math.nan,
            "slo_attainment": (sum(r.met_slo for r in cslo) / len(cslo)
                               if cslo else math.nan),
        }
    return {
        "cells": len(records_by_cell),
        "completed": len(done),
        "rejected": sum(r.rejected for r in merged) + int(router_rejections),
        "router_rejections": int(router_rejections),
        "makespan": float(mk),
        "throughput": len(done) / mk if mk > 0 else 0.0,
        "avg_ttft": float(ttft.mean()) if len(ttft) else math.nan,
        "p50_ttft": float(np.percentile(ttft, 50)) if len(ttft) else math.nan,
        "p99_ttft": float(np.percentile(ttft, 99)) if len(ttft) else math.nan,
        "slo_total": len(with_slo),
        "slo_met": sum(r.met_slo for r in with_slo),
        "slo_attainment": (sum(r.met_slo for r in with_slo) / len(with_slo)
                           if with_slo else math.nan),
        "per_cell": per_cell,
    }
