"""Cell builder: one (arch x shape x mode) -> a lowerable jit'd step.

This is the single source of truth for WHAT gets lowered per cell, shared by
the dry-run, the roofline report, serve.py and train.py.

Shape -> step function and sharding (DESIGN.md §5):
  train_4k     train_step: batch over batch_axes; params/opt FSDP("data") +
               TP("model"); grad all-reduce over "pod".
  prefill_32k  two first-class modes:
                 baseline_tp    full-sequence forward, batch over batch_axes
                 mocap/terapipe/gpipe  chunked pipeline over stage axis
  decode_32k   serve_step: batch over batch_axes, KV seq-sharded over "model"
               (distributed flash-decode), TP weights.
  long_500k    serve_step, batch=1: KV/state seq-sharded over ("data","model");
               SSM/hybrid only (sub-quadratic) — full-attention archs SKIP.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, RunConfig, ShapeConfig, SHAPES,
                                get_config)
from repro.core import pipeline as pp
from repro.models.api import Model, build_model
from repro.models.topology import Topology
from repro.train.optim import AdamWConfig
from repro.train.step import make_train_step, train_state_specs

PREFILL_MODES = ("mocap", "terapipe", "gpipe", "baseline_tp")


def enumerate_cell_meshes(n_cells: int, num_stages: int, tp: int,
                          devices=None) -> Tuple[Topology, ...]:
    """Per-cell (stages x tp) meshes for the multi-cell serving fleet
    (``repro.fleet``): partition the device pool into ``n_cells`` disjoint
    blocks, one ``Topology`` each. When the pool is too small for disjoint
    blocks, later cells WRAP onto the same devices (replicated-cell mode:
    correct but serialized — fine for tests on fake host devices, called
    out by the serve driver). Device order is preserved so cell i is stable
    across calls with the same pool."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.compat import axis_types_kw as _axis_kw
    devs = list(devices) if devices is not None else list(jax.devices())
    per = num_stages * tp
    if per > len(devs):
        raise ValueError(
            f"cell shape {num_stages}x{tp} needs {per} devices; "
            f"pool has {len(devs)}")
    topos = []
    for i in range(n_cells):
        lo = i * per
        block = (devs[lo:lo + per] if lo + per <= len(devs)
                 else devs[:per])          # wrap: share the first block
        mesh = Mesh(np.asarray(block, dtype=object).reshape(num_stages, tp),
                    ("data", "model"), **_axis_kw(2))
        topos.append(Topology(mesh=mesh))
    return tuple(topos)


@dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    mode: str
    fn: Callable                      # jit-able python callable
    args: Tuple[Any, ...]             # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    meta: Dict[str, Any] = field(default_factory=dict)

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings)
        return jitted.lower(*self.args)


class SkipCell(Exception):
    """This (arch x shape) combination is intentionally not runnable."""


def _named(topo: Topology, tree):
    return jax.tree.map(lambda s: NamedSharding(topo.mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def build_cell(arch: str, shape_name: str, topo: Topology, *,
               mode: str = "auto", run: Optional[RunConfig] = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    run = run or RunConfig(num_stages=topo.num_stages)
    model = build_model(cfg)

    if shape.kind == "decode" and shape.seq_len >= 200_000 and not cfg.subquadratic:
        raise SkipCell(
            f"{arch} x {shape_name}: full-attention arch skips the 500k "
            f"decode shape (quadratic; DESIGN.md §4 shape-skips)")

    if shape.kind == "train":
        return _train_cell(model, shape, topo, run)
    if shape.kind == "prefill":
        m = "mocap" if mode == "auto" else mode
        if m == "baseline_tp":
            return _prefill_baseline_cell(model, shape, topo, run)
        return _prefill_pipeline_cell(model, shape, topo, run, m)
    return _decode_cell(model, shape, topo, run)


# ------------------------------------------------------------------- train

def _train_cell(model: Model, shape: ShapeConfig, topo: Topology,
                run: RunConfig) -> Cell:
    cfg = model.cfg
    from repro.train.step import init_train_state
    state_sh = _abstract(lambda key: init_train_state(model, key),
                         jax.random.key(0))
    specs = train_state_specs(model, topo, fsdp=run.fsdp)
    step = make_train_step(model, topo, AdamWConfig(),
                           grad_accum=run.grad_accum, remat=run.remat)
    batch = model.input_specs(shape)
    bspecs = model.input_sharding_specs(shape, batch_axes=topo.batch_axes)
    return Cell(
        arch=cfg.arch, shape=shape, mode="train",
        fn=step, args=(state_sh, batch),
        in_shardings=(_named(topo, specs), _named(topo, bspecs)),
        meta={"family": cfg.family},
    )


# ----------------------------------------------------------------- prefill

def _prefill_io(model: Model, shape: ShapeConfig, topo: Topology):
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    pod_axes = tuple(a for a in topo.batch_axes if a != topo.stage_axis) or None
    ins = model.input_specs(shape)
    tokens = ins["tokens"]
    embeds = ins.get("embeds")
    tok_spec = P(pod_axes, None)
    emb_spec = P(pod_axes, None, None)
    return tokens, embeds, tok_spec, emb_spec


def _kv_split_topo(cfg, topo: Topology) -> Optional[Topology]:
    """Reshape the TP axis into ("kv","qg") — same physical chips, a view
    where GQA attention shards by kv head / query group with no collectives.
    Returns None when head counts don't divide (falls back to "auto")."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.compat import axis_types_kw as _axis_kw
    factors = pp.kv_split_axes(cfg, topo.mesh.shape[topo.tp_axis]
                               if not isinstance(topo.tp_axis, tuple)
                               else topo.tp_size)
    if factors is None:
        return None
    kv_ax, qg_ax, _ = factors
    devs = np.asarray(topo.mesh.devices)
    view = Mesh(devs.reshape(devs.shape[:-1] + (kv_ax, qg_ax)),
                topo.mesh.axis_names[:-1] + ("kv", "qg"),
                **_axis_kw(len(topo.mesh.axis_names) + 1))
    return Topology(mesh=view, batch_axes=topo.batch_axes,
                    tp_axis=("kv", "qg"), stage_axis=topo.stage_axis)


def _prefill_pipeline_cell(model: Model, shape: ShapeConfig, topo: Topology,
                           run: RunConfig, mode: str) -> Cell:
    cfg = model.cfg
    init_cfg = cfg
    g_pad = None
    e_pad = None
    if run.attn_sharding == "kv_split" and cfg.family in ("dense", "moe", "vlm"):
        split = _kv_split_topo(cfg, topo)
        if split is not None:
            topo = split
            factors = pp.kv_split_axes(cfg, topo.tp_size)
            kvh = cfg.num_kv_heads
            if factors and kvh * factors[2] != cfg.num_heads:
                g_pad = factors[2]  # zero-pad q heads per kv group (exact)
                from repro.configs.base import replace as cfg_replace
                cfg = cfg_replace(cfg, num_heads=kvh * g_pad)
            if cfg.moe is not None:
                tp = topo.tp_size  # EP: pad experts to the axis size
                e_pad = -(-cfg.moe.num_experts // tp) * tp
                import dataclasses
                from repro.configs.base import replace as cfg_replace
                cfg = cfg_replace(cfg, moe=dataclasses.replace(
                    cfg.moe, num_experts=e_pad,
                    num_real_experts=cfg.moe.real_experts))
    plan = pp.build_plan(cfg, topo.num_stages, shape.seq_len, run, mode=mode)

    def _init_staged(key):
        params = model._mod.init(init_cfg, key)
        mid_cfg = init_cfg
        if g_pad is not None:
            mid_cfg, params = pp.pad_q_heads(mid_cfg, params, g_pad)
        if e_pad is not None:
            mid_cfg, params = pp.pad_experts(mid_cfg, params, e_pad)
        return pp.stage_params(cfg, params, plan)

    staged_sh = _abstract(_init_staged, jax.random.key(0))
    specs = pp.stage_param_specs(cfg, plan, topo)
    # whisper keeps enc params under the same spec tree
    spec_tree = {k: specs[k] for k in staged_sh.keys() if k in specs}
    for k in staged_sh:
        if k not in spec_tree:  # lm_head etc.
            spec_tree[k] = specs.get(k, P(None, "model"))
    tokens, embeds, tok_spec, emb_spec = _prefill_io(model, shape, topo)

    if mode == "gpipe":
        fn = lambda st, tk: pp.prefill_pipeline(cfg, st, tk, plan, topo)
        args = (staged_sh, tokens)
        shard = (_named(topo, spec_tree), NamedSharding(topo.mesh, tok_spec))
    elif embeds is not None:
        fn = lambda st, tk, em: pp.prefill_pipeline(cfg, st, tk, plan, topo,
                                                    embeds=em)
        args = (staged_sh, tokens, embeds)
        shard = (_named(topo, spec_tree), NamedSharding(topo.mesh, tok_spec),
                 NamedSharding(topo.mesh, emb_spec))
    else:
        fn = lambda st, tk: pp.prefill_pipeline(cfg, st, tk, plan, topo)
        args = (staged_sh, tokens)
        shard = (_named(topo, spec_tree), NamedSharding(topo.mesh, tok_spec))
    from repro.core import transport as _tx
    wire = (None if mode == "gpipe" or cfg.family == "ssm" else
            _tx.analytic_wire_bytes(plan, cfg, int(tokens.shape[0])))
    return Cell(cfg.arch, shape, mode, fn, args, shard,
                meta={"family": cfg.family, "plan": plan, "mesh": topo.mesh,
                      "wire_model": wire})


def _prefill_baseline_cell(model: Model, shape: ShapeConfig, topo: Topology,
                           run: RunConfig) -> Cell:
    """Full-sequence TP prefill (no pipeline): batch over ALL batch axes,
    the paper's 'conventional system' reference lowering."""
    cfg = model.cfg
    ins = model.input_specs(shape)
    specs = model.param_specs(fsdp=run.fsdp)
    params_sh = _abstract(model.init, jax.random.key(0))
    bspecs = model.input_sharding_specs(shape, batch_axes=topo.batch_axes)

    def fn(params, batch):
        kw = {}
        if "embeds" in batch:
            kw["embeds"] = batch["embeds"]
        logits = model.forward(params, batch["tokens"], topo=topo,
                               remat=False, **kw)
        return logits[:, -1]          # prefill-only: ONE next-token logit

    return Cell(cfg.arch, shape, "baseline_tp", fn, (params_sh, ins),
                (_named(topo, specs), _named(topo, bspecs)),
                meta={"family": cfg.family})


# ------------------------------------------------------------------ decode

def _decode_cell(model: Model, shape: ShapeConfig, topo: Topology,
                 run: RunConfig) -> Cell:
    cfg = model.cfg
    b = shape.global_batch
    long_ctx = shape.seq_len >= 200_000
    if long_ctx:
        batch_axes: Tuple[str, ...] = ()
        seq_axes: Tuple[str, ...] = ("data", "model") \
            if cfg.family == "hybrid" else ()
    else:
        batch_axes = topo.batch_axes
        seq_axes = ("model",) if cfg.family != "ssm" else ()
    dtopo = Topology(mesh=topo.mesh, batch_axes=batch_axes,
                     tp_axis=topo.tp_axis, stage_axis=topo.stage_axis)

    ins = model.input_specs(shape)
    ispecs = model.input_sharding_specs(shape, batch_axes=batch_axes,
                                        seq_axes=seq_axes)
    params_sh = _abstract(model.init, jax.random.key(0))
    pspecs = model.param_specs(fsdp=False)   # decode: TP weights, no FSDP

    def fn(params, cache, tokens):
        if long_ctx or cfg.family == "ssm":
            logits, cache = model.decode_step(params, cache, tokens,
                                              seq_axes=seq_axes or ())
        else:
            logits, cache = model.decode_step(params, cache, tokens,
                                              topo=dtopo, seq_axes=seq_axes)
        return logits, cache

    return Cell(
        cfg.arch, shape, "decode", fn,
        (params_sh, ins["cache"], ins["tokens"]),
        (_named(topo, pspecs), _named(topo, ispecs["cache"]),
         NamedSharding(topo.mesh, ispecs["tokens"])),
        meta={"family": cfg.family, "seq_axes": seq_axes},
    )
