import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   512 placeholder host devices back the 16x16 pod and 2x16x16 multi-pod
#   meshes for lower()+compile() — no arrays are ever materialized.
"""Multi-pod dry-run: lower + compile EVERY (arch x shape x mesh) cell and
record memory_analysis / cost_analysis / roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape prefill_32k
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
  python -m repro.launch.dryrun --all --jobs 4        # subprocess per cell

Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>__<mode>.json — consumed by
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline_report.py.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import List, Optional, Tuple

ARCHS = (
    "whisper-small", "qwen3-8b", "stablelm-3b", "granite-3-2b", "qwen3-14b",
    "granite-moe-3b-a800m", "qwen2-moe-a2.7b", "llava-next-34b",
    "zamba2-7b", "mamba2-130m",
)
SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def run_cell(arch: str, shape_name: str, mesh_kind: str, mode: str,
             out_dir: str, attn_backend: str = "jnp",
             kv_dtype: str = "auto", kv_page_tokens: int = 0,
             pool_backend: str = "auto", tp_lowering: str = "auto",
             calibrated_profile: Optional[str] = None) -> dict:
    from repro import compat
    from repro.configs.base import SHAPES, get_config
    from repro.launch.cells import SkipCell, build_cell
    from repro.launch.mesh import make_topology
    from repro.roofline.analysis import analyze_lowered

    from repro.configs.base import RunConfig

    topo = make_topology(multi_pod=(mesh_kind == "multipod"))
    chips = topo.mesh.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": mode,
           "chips": chips, "attn_backend": attn_backend,
           "pool_backend": pool_backend, "kv_dtype": kv_dtype, "ok": False}
    t0 = time.time()
    try:
        if mode == "mocap_opt":
            # the beyond-paper optimized lowering (§Perf): kv_split attention
            # + sequence-parallel residual + EP for MoE + compact host scan
            run = RunConfig(num_stages=topo.num_stages,
                            attn_sharding="kv_split",
                            attn_backend=attn_backend,
                            pool_backend=pool_backend, kv_dtype=kv_dtype,
                            kv_page_tokens=kv_page_tokens,
                            tp_lowering=tp_lowering)
            cell = build_cell(arch, shape_name, topo, mode="mocap", run=run)
        else:
            run = RunConfig(num_stages=topo.num_stages,
                            attn_backend=attn_backend,
                            pool_backend=pool_backend, kv_dtype=kv_dtype,
                            kv_page_tokens=kv_page_tokens,
                            tp_lowering=tp_lowering)
            cell = build_cell(arch, shape_name, topo, mode=mode, run=run)
    except SkipCell as e:
        rec.update(ok=True, skipped=True, reason=str(e))
        return rec
    if cell.meta.get("wire_model"):
        # §3.4 analytic per-run wire bytes (core.transport.analytic_wire_
        # bytes) — the runtime CollectiveLedger is pinned to this model
        # within 1% by tests/test_transport.py
        rec["wire_model"] = cell.meta["wire_model"]
        rec["tp_lowering"] = cell.meta["plan"].tp_lowering
        # tick x stage slot-occupancy profile off the same plan — the
        # device StageTelemetry counters are pinned to this analytic twin
        # by tests/test_obs.py
        from repro.obs.telemetry import occupancy_model
        rec["occupancy_model"] = occupancy_model(cell.meta["plan"])
        if calibrated_profile:
            # per-(chunk, stage) calibration residuals + how far the
            # measured profile moved this cell's predicted chunk costs —
            # recorded NEXT TO wire_model / occupancy_model (obs.calibrate)
            from repro.core import costmodel as _cm
            from repro.core import mbkr as _mb
            from repro.obs import calibrate as _cal
            plan = cell.meta["plan"]
            sm = _cm.StageModel.build(get_config(arch), plan.num_stages, 1)
            mplan = (_mb.plan(plan.num_chunks, plan.num_stages)
                     if plan.mode == "mocap" else None)
            rec["calibration"] = _cal.calibration_record(
                sm, [plan.chunk_len] * plan.num_chunks, _cm.WSC_PAPER,
                calibrated_profile, mbkr_plan=mplan)
    try:
        with compat.set_mesh(cell.meta.get("mesh", topo.mesh)):
            lowered = cell.lower()
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                             + mem.temp_size_in_bytes
                                             + mem.output_size_in_bytes),
            }
            cfg = get_config(arch)
            terms = analyze_lowered(lowered, compiled, cfg,
                                    SHAPES[shape_name], chips)
            rec["roofline"] = terms.to_dict()
            rec["ok"] = True
            rec["summary"] = terms.summary()
    except Exception as e:  # noqa: BLE001 — a failed cell is a data point
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def cell_modes(shape_name: str) -> Tuple[str, ...]:
    # prefill lowers the paper technique (faithful + optimized) AND the
    # conventional baseline as first-class modes
    if shape_name == "prefill_32k":
        return ("mocap", "baseline_tp", "mocap_opt")
    return ("auto",)


def save(rec: dict, out_dir: str) -> str:
    os.makedirs(os.path.join(out_dir, rec["mesh"]), exist_ok=True)
    path = os.path.join(out_dir, rec["mesh"],
                        f"{rec['arch']}__{rec['shape']}__{rec['mode']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return path


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=("pod", "multipod", "both"))
    ap.add_argument("--mode", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1,
                    help="run cells in parallel subprocesses")
    ap.add_argument("--attn-backend", default="jnp",
                    choices=("jnp", "pallas"),
                    help="attention backend for pipeline modes "
                         "(core.attention registry)")
    ap.add_argument("--pool-backend", default="auto",
                    choices=("auto", "jnp", "pallas", "paged"),
                    help="backend for pool-sourced partials (own-pool scan "
                         "+ fetch/qship); auto follows --attn-backend; "
                         "paged = gather-free ragged pool kernel")
    ap.add_argument("--tp-lowering", default="auto",
                    choices=("auto", "manual"),
                    help="TP lowering for pipeline modes (core.transport): "
                         "auto = GSPMD partial-auto (manual fallback on old "
                         "jaxlib); manual = explicit transport psums")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=("auto", "bfloat16", "int8", "fp8"),
                    help="KV page-store codec for pipeline modes "
                         "(repro.kvstore; changes lowered pool bytes)")
    ap.add_argument("--kv-page-tokens", type=int, default=0,
                    help="tokens per KV page (0 = one page per chunk)")
    ap.add_argument("--calibrated-profile", default=None,
                    help="calibrated-profile JSON (obs.calibrate / serve "
                         "--calibrate): records per-(chunk, stage) fit "
                         "residuals and the nominal-vs-calibrated predicted "
                         "chunk costs next to wire_model/occupancy_model")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)

    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    cells: List[Tuple[str, str, str, str]] = []
    archs = ARCHS if (args.all or not args.arch) else (args.arch,)
    shapes = SHAPE_NAMES if (args.all or not args.shape) else (args.shape,)
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                for mode in (cell_modes(shape) if args.mode is None
                             else (args.mode,)):
                    cells.append((arch, shape, mesh, mode))

    if args.jobs > 1:
        return _run_parallel(cells, args.out, args.jobs, args.attn_backend,
                             args.kv_dtype, args.kv_page_tokens,
                             args.pool_backend, args.tp_lowering,
                             args.calibrated_profile)

    failures = 0
    for arch, shape, mesh, mode in cells:
        rec = run_cell(arch, shape, mesh, mode, args.out, args.attn_backend,
                       args.kv_dtype, args.kv_page_tokens, args.pool_backend,
                       args.tp_lowering, args.calibrated_profile)
        path = save(rec, args.out)
        status = ("SKIP" if rec.get("skipped") else
                  "OK" if rec["ok"] else "FAIL")
        extra = rec.get("summary", rec.get("reason", rec.get("error", "")))
        print(f"[{status:4}] {mesh:8} {arch:22} {shape:12} {mode:12} "
              f"{extra}", flush=True)
        failures += 0 if rec["ok"] else 1
    return 1 if failures else 0


def _run_parallel(cells, out_dir: str, jobs: int,
                  attn_backend: str = "jnp", kv_dtype: str = "auto",
                  kv_page_tokens: int = 0, pool_backend: str = "auto",
                  tp_lowering: str = "auto",
                  calibrated_profile: Optional[str] = None) -> int:
    procs: List[Tuple[subprocess.Popen, tuple]] = []
    pending = list(cells)
    failures = 0

    def launch(cell):
        arch, shape, mesh, mode = cell
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--mode", mode,
               "--attn-backend", attn_backend, "--pool-backend", pool_backend,
               "--kv-dtype", kv_dtype, "--tp-lowering", tp_lowering,
               "--kv-page-tokens", str(kv_page_tokens), "--out", out_dir]
        if calibrated_profile:
            cmd += ["--calibrated-profile", calibrated_profile]
        return subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)

    while pending or procs:
        while pending and len(procs) < jobs:
            cell = pending.pop(0)
            procs.append((launch(cell), cell))
        done = [i for i, (p, _) in enumerate(procs) if p.poll() is not None]
        for i in sorted(done, reverse=True):
            p, cell = procs.pop(i)
            out = p.stdout.read() if p.stdout else ""
            print(out.strip(), flush=True)
            failures += 1 if p.returncode else 0
        time.sleep(0.3)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
