"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; smoke tests
see the real single device).

Axis semantics (DESIGN.md §3):
  "data"  — 16-wide: chunked-pipeline STAGE axis for prefill; batch/FSDP axis
            for train and decode shapes.
  "model" — 16-wide: tensor parallelism inside a stage (Megatron split).
  "pod"   — multi-pod replica axis (independent request streams / data
            parallel across pods); gradients all-reduce over it in training.
"""
from __future__ import annotations


import jax

from repro.compat import axis_types_kw as _axis_kw  # shared jax-drift shim
from repro.models.topology import Topology


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_topology(*, multi_pod: bool = False) -> Topology:
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch = ("pod", "data") if multi_pod else ("data",)
    return Topology(mesh=mesh, batch_axes=batch, tp_axis="model",
                    stage_axis="data")


def make_test_topology(num_stages: int = 4, tp: int = 2) -> Topology:
    """Small mesh over however many (fake) devices the process has."""
    mesh = jax.make_mesh((num_stages, tp), ("data", "model"), **_axis_kw(2))
    return Topology(mesh=mesh)
