"""End-to-end training driver.

Runs for real on whatever devices exist (CPU here; the production mesh on a
pod). Supports every --arch via its smoke/full config, checkpoints
atomically, and resumes bit-exact (params, optimizer, data stream).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --steps 200 \
      --preset 100m --ckpt-dir /tmp/run1 [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config, replace
from repro.data import SyntheticLM
from repro.models.api import build_model
from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state


def preset_config(arch: str, preset: str):
    if preset == "full":
        return get_config(arch)
    if preset == "smoke":
        return get_smoke_config(arch)
    # ~100M-class: scale the family's smoke config up
    cfg = get_smoke_config(arch)
    return replace(cfg, num_layers=max(cfg.num_layers, 8), d_model=512,
                   num_heads=8, num_kv_heads=max(cfg.num_kv_heads // max(cfg.num_heads, 1) * 8, 4),
                   d_ff=2048, head_dim=64, vocab_size=32768)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--preset", default="100m", choices=("smoke", "100m", "full"))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"arch={args.arch} preset={args.preset} params~{n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, None, opt,
                                      grad_accum=args.grad_accum))
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)

    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, extra = restore_checkpoint(args.ckpt_dir)
        data.restore(extra["data"])
        start = int(extra["step"])
        print(f"resumed from step {start}")
    else:
        state = init_train_state(model, jax.random.key(args.seed))

    embeds = None
    if cfg.frontend.kind in ("vision_stub", "audio_stub") or cfg.family == "encdec":
        nf = min(cfg.frontend.num_embeds or 16, 32)
        embeds = jnp.asarray(
            np.random.default_rng(0).normal(0, 0.02, (args.batch, nf, cfg.d_model)),
            jnp.float32)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        if embeds is not None:
            batch["embeds"] = embeds
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            tok_s = args.batch * args.seq * (step - start + 1) / (time.time() - t0)
            print(f"step {step:5d}  loss {loss:.4f}  |g| {gn:.3f}  "
                  f"{tok_s:,.0f} tok/s", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, state,
                            extra={"data": data.checkpoint(), "step": step + 1})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state,
                        extra={"data": data.checkpoint(), "step": args.steps})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
