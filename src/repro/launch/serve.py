"""Prefill-only serving driver: the MOCAP engine end-to-end.

Real execution on the available devices (chunked pipeline via shard_map needs
>= 2 devices; on a bare CPU host the driver forces 8 fake host devices
itself), or --executor sim for the analytic executor at production scale.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --requests 12 \
      --executor jax --attn-backend pallas

--attn-backend picks the attention inner loop (core.attention registry):
"jnp" is the pure-jnp online-softmax reference, "pallas" the flash kernel
``kernels.ops.chunk_attention`` (interpret mode off-TPU, Mosaic on TPU).
--pool-backend overrides the backend for POOL-sourced partials only (the
own-pool scan + fetch/qship) — backend-per-source mixing; under pallas the
pool scan is a single batched slot-grid kernel launch per (layer, tick);
under paged it is a single RAGGED launch reading pages in place from the
page store (no gather_chunks copy — DESIGN.md §3.7).

Continuous chunk-level scheduling (cross-request pipelining, repro.sched):

  PYTHONPATH=src python -m repro.launch.serve --executor sim \
      --scheduler continuous --policy edf --arrival-rate 4 --slo-ms 2000 \
      --trace-out artifacts/sched_trace.json

--arrival-rate R > 0 draws open-loop Poisson arrivals at R req/s (0 =
closed-loop burst at t=0); --policy picks the admission order (fcfs | sjf |
edf); --slo-ms stamps deadlines so EDF and the SLO-attainment metric bite.
"""
from __future__ import annotations

import argparse
import math
import time

import numpy as np

from repro.configs.base import RunConfig, get_config, get_smoke_config, replace
from repro.core import costmodel as cm
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.runtime.engine import (ContinuousEngine, EngineConfig, JaxExecutor,
                                  PrefillEngine, Request, SimExecutor)


H2D_BW = 16e9  # host<->device staging bandwidth for the cold tier (B/s)


def _print_tier_summary(cfg, ec, kv_dtype: str, kv_page_tokens: int) -> None:
    """--kv-offload: plan the hot/warm/cold page placement for the engine's
    largest bucket and print it (kvstore.tiers; analytic prefetch scheduled
    off the same chunk-cost vectors the scheduler uses)."""
    from repro.core import mbkr
    from repro.kvstore import pages as kvp
    from repro.kvstore import quant as kvq
    from repro.kvstore import tiers as kvt
    m, n = ec.num_chunks, ec.num_stages
    bucket = max(ec.buckets)
    c = -(-bucket // m)
    mplan = mbkr.plan(m, n, mbkr=ec.mbkr and not cfg.attn_free)
    geom = kvp.page_geometry(c, mplan.num_slots, kv_page_tokens)
    tbl = kvp.build_slot_pages(geom)
    codec = kvq.get_codec(kv_dtype, cfg.dtype)
    sm = cm.StageModel.build(cfg, n, ec.tp)
    dur, _, _, _, _ = cm.chunk_cost_arrays(sm, [c] * m, ec.hw,
                                           mbkr_plan=mplan)
    # per-STAGE budget: tp chips' HBM minus the stage's weight slice
    # (param_count*2/n bytes, resident on those same chips)
    tp = max(ec.tp, 1)
    hot = max(ec.hw.hbm_cap * tp - cfg.param_count() * 2 / n, 0.0) * 0.5
    host_slots = (np.unique(np.concatenate(
        [mplan.host_slot_a[mplan.p2:], mplan.host_slot_b[mplan.p2:]]))
        if mplan.p2 < m else None)
    plan = kvt.plan_tiers(
        geom, codec, tbl, mplan.own_slot, mplan.p2, m,
        kvt.TierSpec(hot_bytes=hot, cold_bw=H2D_BW),
        lps=sm.attn_layers, b=1, kvh=cfg.num_kv_heads,
        hd=cfg.resolved_head_dim, tick_s=dur, host_slots=host_slots)
    s = plan.summary()
    print(f"[kv-offload] bucket {bucket} kv_dtype={codec.name} "
          f"page_tokens={geom.page_tokens}: pages {s['pages']} | "
          f"hot {s['hot_bytes']/1e9:.2f} GB | warm {s['warm_bytes']/1e9:.2f} GB"
          f" | cold {s['cold_bytes']/1e9:.2f} GB | "
          f"prefetch ops {s['prefetch_ops']} "
          f"(peak {s['worst_tick_bw']/1e9:.2f} GB/s vs {H2D_BW/1e9:.0f}) | "
          f"{'FEASIBLE' if s['feasible'] else 'INFEASIBLE'}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--preset", default="smoke", choices=("smoke", "full"))
    ap.add_argument("--executor", default="jax", choices=("jax", "sim"))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--num-chunks", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--attn-backend", default="jnp",
                    choices=("jnp", "pallas"),
                    help="attention inner-loop backend (core.attention): "
                         "jnp = pure-jnp reference, pallas = the flash "
                         "kernel (interpret mode off-TPU)")
    ap.add_argument("--pool-backend", default="auto",
                    choices=("auto", "jnp", "pallas", "paged"),
                    help="backend for POOL-sourced partials (own-pool scan "
                         "+ fetch/qship) — mixable with --attn-backend, "
                         "e.g. pallas self-block + jnp remote partials; "
                         "auto follows --attn-backend. pallas = ONE batched "
                         "slot-grid kernel launch per pool scan; paged = "
                         "one RAGGED launch straight off the page store "
                         "(scalar-prefetched handles, double-buffered DMA, "
                         "no gather — DESIGN.md §3.7)")
    ap.add_argument("--ssm-backend", default="jnp",
                    choices=("jnp", "pallas"),
                    help="SSD inner loop for ssm/hybrid archs "
                         "(kernels.ops.ssd behind the same knob pattern)")
    ap.add_argument("--tp-lowering", default="auto",
                    choices=("auto", "manual"),
                    help="TP lowering (core.transport, DESIGN.md §3.6): "
                         "auto = GSPMD partial-auto shard_map (falls back "
                         "to manual on old jaxlib); manual = all mesh axes "
                         "manual with explicit transport psums — restores "
                         "TP>1 on old jaxlib")
    ap.add_argument("--transport", default="jax",
                    help="transport registry entry for cross-stage/"
                         "cross-rank collectives (core.transport)")
    ap.add_argument("--fetch-batch", default="auto",
                    choices=("auto", "on", "off"),
                    help="batched fetch: land remote chunk-layers in a "
                         "staging buffer + ONE pool_attention launch "
                         "(auto follows the pool backend's batched_pool)")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=("auto", "bfloat16", "int8", "fp8"),
                    help="KV page-store codec (repro.kvstore): auto = model "
                         "dtype; int8/fp8 store+ship quantized pages and "
                         "leases count quantized bytes (~2x admission "
                         "capacity)")
    ap.add_argument("--kv-page-tokens", type=int, default=0,
                    help="tokens per KV page (0 = one page per chunk)")
    ap.add_argument("--kv-offload", action="store_true",
                    help="plan the cold KV tier: host-offload placement + "
                         "analytic prefetch off the chunk plan "
                         "(kvstore.tiers); prints the tier summary")
    ap.add_argument("--scheduler", default="batch",
                    choices=("batch", "continuous"),
                    help="batch = batch-synchronous PrefillEngine; "
                         "continuous = cross-request chunk pipelining")
    ap.add_argument("--policy", default="fcfs", choices=("fcfs", "sjf", "edf"),
                    help="continuous-mode admission policy")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrivals (req/s); 0 = closed loop")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request SLO (deadline = arrival + slo)")
    ap.add_argument("--trace-out", default=None,
                    help="write ONE merged Chrome/Perfetto trace here "
                         "(scheduler task spans + engine wave/tick spans + "
                         "KV/wire counter tracks; repro.obs). With the jax "
                         "executor this also turns on per-(stage, tick) "
                         "device telemetry")
    ap.add_argument("--metrics-out", default=None,
                    help="export serving metrics here (repro.obs.metrics): "
                         ".prom extension = Prometheus textfile, anything "
                         "else = JSON lines")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the run in jax.profiler.trace(dir) — a real "
                         "XLA profile next to the repro.obs timeline "
                         "(jax executor only)")
    ap.add_argument("--calibrated-profile", default=None,
                    help="HardwareProfile for planning/admission costs: a "
                         "registered name (wsc-gr24 | hgx-b200 | tpu-v5e) "
                         "or a calibrated-profile JSON written by "
                         "--calibrate (obs.calibrate) — LBCP and SJF/EDF "
                         "then run on MEASURED effective rates")
    ap.add_argument("--calibrate", default=None, metavar="OUT",
                    help="measure per-(stage, tick) wall-clock spans (jax "
                         "executor only), least-squares fit the effective "
                         "HardwareProfile rates (obs.calibrate) and write "
                         "the calibrated-profile JSON to OUT; feed it back "
                         "with --calibrated-profile")
    ap.add_argument("--health", action="store_true",
                    help="arm the runtime health sentinels (obs.health): "
                         "non-finite activations per stage, telemetry-vs-"
                         "analytic occupancy drift, SLO burn-rate; alerts "
                         "land in the metrics export and the merged trace")
    args = ap.parse_args(argv)

    hw = cm.TPU_V5E
    if args.calibrated_profile:
        hw = cm.resolve_profile(args.calibrated_profile)
        print(f"[profile] {args.calibrated_profile} -> {hw.name} "
              f"(gemm_eff={hw.gemm_eff:.3f} attn_eff={hw.attn_eff:.3f})")

    if args.executor == "sim":
        cfg = get_config(args.arch)
        ec = EngineConfig(model=cfg, hw=hw, num_stages=16, tp=16,
                          num_chunks=16, max_batch=args.max_batch,
                          buckets=(8192, 32768, 131072), partition="lbcp",
                          kv_dtype=args.kv_dtype,
                          kv_page_tokens=args.kv_page_tokens)
        executor = SimExecutor(cfg, hw)
    else:
        from repro import compat
        compat.ensure_host_devices()
        import jax
        cfg = replace(get_smoke_config(args.arch)
                      if args.preset == "smoke" else get_config(args.arch),
                      dtype="float32")
        n_dev = jax.device_count()
        # tp=2 when the device count affords it; old jaxlib takes the
        # MANUAL TP lowering (build_plan resolves tp_lowering="auto" via
        # compat.resolve_tp_lowering — no more tp=1 fallback)
        tp = 2 if n_dev >= 4 else 1
        stages = max(n_dev // tp, 2)
        from repro.launch.mesh import make_test_topology
        topo = make_test_topology(stages, tp)
        run = RunConfig(num_chunks=args.num_chunks, num_stages=stages,
                        attn_backend=args.attn_backend,
                        pool_backend=args.pool_backend,
                        ssm_backend=args.ssm_backend,
                        tp_lowering=args.tp_lowering,
                        transport=args.transport,
                        fetch_batch=args.fetch_batch,
                        kv_dtype=args.kv_dtype,
                        kv_page_tokens=args.kv_page_tokens,
                        kv_offload=args.kv_offload)
        plan = pp.build_plan(cfg, stages, args.seq, run)
        if plan.tp_lowering == "manual" and tp > 1:
            print(f"[transport] manual TP lowering (tp={tp}, "
                  f"transport={plan.transport})")
        model = build_model(cfg)
        params = model.init(jax.random.key(args.seed))
        staged = pp.stage_params(cfg, params, plan)
        ec = EngineConfig(model=cfg, hw=hw, num_stages=stages, tp=tp,
                          num_chunks=args.num_chunks, max_batch=args.max_batch,
                          buckets=(args.seq,), partition="uniform",
                          kv_dtype=args.kv_dtype,
                          kv_page_tokens=args.kv_page_tokens)
        executor = JaxExecutor(cfg, staged, topo, run)

    if args.kv_offload:
        _print_tier_summary(cfg, ec, args.kv_dtype, args.kv_page_tokens)

    slo = args.slo_ms / 1e3 if args.slo_ms else None
    if args.scheduler == "continuous":
        eng = ContinuousEngine(ec, executor, policy=args.policy, slo=slo,
                               trace=args.trace_out is not None)
    else:
        eng = PrefillEngine(ec, executor)
    if args.trace_out and isinstance(executor, JaxExecutor):
        # the merged timeline wants the device-side (stage, tick) profile:
        # switch the jit cache to the return_telemetry=True pipeline
        executor.collect_telemetry = True
    monitor = None
    if args.health:
        from repro.obs.health import HealthMonitor
        monitor = HealthMonitor()
        # jax: arms the non-finite sentinels at trace time; sim: carried
        # for the host-side drift/SLO checks + exports
        executor.health = monitor
    if args.calibrate:
        if isinstance(executor, JaxExecutor):
            executor.collect_measured = True
        else:
            print("note: --calibrate measures the jax executor; the sim "
                  "path IS the analytic model — skipping (the sim-backed "
                  "calibration leg lives in benchmarks/calibration.py)")
            args.calibrate = None

    from repro.sched import poisson_arrivals
    if args.scheduler == "batch" and args.arrival_rate > 0:
        # the batch-synchronous engine admits everything at clock 0 and its
        # E2E metric is finish - arrival: staggered arrivals would produce
        # negative latencies there, so open-loop arrivals are continuous-only
        print("note: --arrival-rate requires --scheduler continuous; "
              "running the batch engine as a closed loop (arrivals at t=0)")
        args.arrival_rate = 0.0
    arrivals = poisson_arrivals(args.arrival_rate, args.requests,
                                seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        toks = rng.integers(0, ec.model.vocab_size, size=args.seq).astype(np.int32)
        eng.submit(Request(rid=i, arrival=float(arrivals[i]), seq_len=args.seq,
                           tokens=toks if args.executor == "jax" else None))
    t0 = time.time()
    if args.profile_dir and args.executor == "jax":
        import jax
        with jax.profiler.trace(args.profile_dir):
            eng.run_until_drained()
        print(f"xla profile -> {args.profile_dir}")
    else:
        if args.profile_dir:
            print("note: --profile-dir needs --executor jax; skipping")
        eng.run_until_drained()
    wall = time.time() - t0

    if args.calibrate:
        meas = [w for w in executor.waves if w.get("measured") is not None]
        if not meas:
            print("note: no measured waves; nothing to calibrate")
        else:
            from repro.core import mbkr
            from repro.obs import calibrate as cal
            w = meas[-1]            # later waves are warm (compile is paid)
            sm = cm.StageModel.build(cfg, w["num_stages"], ec.tp)
            mplan = (mbkr.plan(len(w["chunks"]), w["num_stages"])
                     if not cfg.attn_free else None)
            fit = cal.fit_profile(sm, w["chunks"], w["measured"], ec.hw,
                                  mbkr_plan=mplan)
            cal.save_profile(args.calibrate, fit.profile, fit=fit,
                             meta={"arch": args.arch, "seq": args.seq,
                                   "source": "serve"})
            print(f"[calibrate] {ec.hw.name} -> {fit.profile.name}: span "
                  f"MAPE {fit.mape_nominal:.3f} -> {fit.mape_calibrated:.3f}"
                  f" over {len(fit.rows)} spans -> {args.calibrate}")
    if monitor is not None:
        if slo is not None and args.scheduler == "continuous":
            from repro.obs.metrics import Histogram
            h = Histogram("ttft")
            for rec in eng.scheduler.metrics.records:
                if math.isfinite(rec.finish):
                    h.observe(rec.finish - rec.arrival)
            monitor.check_slo(h, slo)
        s = monitor.summary()
        burn = (f" | burn {s['burn_rate']:.2f}x"
                if s["burn_rate"] is not None else "")
        print(f"[health] alerts {s['alerts_total']} {s['by_kind']}{burn}")

    m = eng.metrics()
    if args.scheduler == "continuous":
        slo_txt = (f" | SLO {m['slo_met']}/{m['slo_total']}"
                   if m["slo_total"] else "")
        print(f"[{args.policy}] completed {m['completed']} "
              f"(rejected {m['rejected']}) in {wall:.2f}s wall | "
              f"sched clock {m['makespan']:.3f}s | "
              f"avg TTFT {m['avg_ttft']:.3f}s | p99 {m['p99_ttft']:.3f}s | "
              f"avg queue {m['avg_queue_wait']:.3f}s | "
              f"{m['throughput']:.3f} req/s | "
              f"bubble {m['bubble_frac']*100:.1f}%{slo_txt}")
        if args.trace_out or args.metrics_out:
            paths = eng.export_obs(trace_out=args.trace_out,
                                   metrics_out=args.metrics_out,
                                   extra={"wall_seconds": wall})
            for kind, path in paths.items():
                print(f"{kind} -> {path}")
    else:
        print(f"completed {m['completed']} requests in {wall:.2f}s wall | "
              f"engine clock {eng.clock:.3f}s | avg E2E {m['avg_e2e']:.3f}s | "
              f"p99 {m['p99_e2e']:.3f}s | {m['throughput']:.3f} req/s | "
              f"stages {m['num_stages']}")
        if args.trace_out:
            print("note: --trace-out needs --scheduler continuous; skipping")
        if args.metrics_out:
            from repro.obs.metrics import export_engine_metrics
            path = export_engine_metrics(args.metrics_out, m,
                                         extra={"wall_seconds": wall},
                                         health=monitor)
            print(f"metrics -> {path}")
    if args.executor == "jax":
        done = sorted(eng.done, key=lambda r: r.rid)[:3]
        for r in done:
            top = int(np.argmax(r.result))
            print(f"  request {r.rid}: next-token argmax = {top}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
