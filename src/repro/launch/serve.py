"""Prefill-only serving driver: the MOCAP engine end-to-end.

Real execution on the available devices (chunked pipeline via shard_map needs
>= 2 devices; on a bare CPU host the driver forces 8 fake host devices
itself), or --executor sim for the analytic executor at production scale.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --requests 12 \
      --executor jax --attn-backend pallas

Every flag maps to a ``launch.options.ServeOptions`` field: ``--options-out``
writes the resolved options JSON, ``--options-in`` replays one, and flags
the user actually types act as overrides on top (argparse.SUPPRESS — see
launch/options.py). The engine is driven ONLY through the ``CellHandle``
protocol (runtime.engine) — no scheduler/executor internals; that seam is
what lets the same driver run one cell or a fleet.

Continuous chunk-level scheduling (cross-request pipelining, repro.sched):

  PYTHONPATH=src python -m repro.launch.serve --executor sim \
      --scheduler continuous --policy edf --arrival-rate 4 --slo-ms 2000 \
      --trace-out artifacts/sched_trace.json

Multi-cell fleet (repro.fleet): one shared arrival stream routed over N
cells — ``--cells N`` replicates the base options; ``--fleet-spec spec.json``
lists per-cell overrides (heterogeneous kv_dtype / buckets / calibrated
profiles); ``--router`` picks jsf | rr | least-loaded:

  PYTHONPATH=src python -m repro.launch.serve --executor sim \
      --scheduler continuous --cells 2 --router jsf --arrival-rate 6 \
      --requests 24 --seq 30000 --trace-out artifacts/fleet_trace.json
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.configs.base import RunConfig, get_config, get_smoke_config, replace
from repro.core import costmodel as cm
from repro.launch.options import (ServeOptions, add_serve_args,
                                  options_from_args, resolve_fleet)
from repro.runtime.engine import (ContinuousEngine, EngineConfig, JaxExecutor,
                                  PrefillEngine, Request, SimExecutor)

H2D_BW = 16e9  # host<->device staging bandwidth for the cold tier (B/s)

SIM_BUCKETS = (8192, 32768, 131072)


def _print_tier_summary(cfg, ec, kv_dtype: str, kv_page_tokens: int) -> None:
    """--kv-offload: plan the hot/warm/cold page placement for the engine's
    largest bucket and print it (kvstore.tiers; analytic prefetch scheduled
    off the same chunk-cost vectors the scheduler uses)."""
    from repro.core import mbkr
    from repro.kvstore import pages as kvp
    from repro.kvstore import quant as kvq
    from repro.kvstore import tiers as kvt
    m, n = ec.num_chunks, ec.num_stages
    bucket = max(ec.buckets)
    c = -(-bucket // m)
    mplan = mbkr.plan(m, n, mbkr=ec.mbkr and not cfg.attn_free)
    geom = kvp.page_geometry(c, mplan.num_slots, kv_page_tokens)
    tbl = kvp.build_slot_pages(geom)
    codec = kvq.get_codec(kv_dtype, cfg.dtype)
    sm = cm.StageModel.build(cfg, n, ec.tp)
    dur, _, _, _, _ = cm.chunk_cost_arrays(sm, [c] * m, ec.hw,
                                           mbkr_plan=mplan)
    # per-STAGE budget: tp chips' HBM minus the stage's weight slice
    # (param_count*2/n bytes, resident on those same chips)
    tp = max(ec.tp, 1)
    hot = max(ec.hw.hbm_cap * tp - cfg.param_count() * 2 / n, 0.0) * 0.5
    host_slots = (np.unique(np.concatenate(
        [mplan.host_slot_a[mplan.p2:], mplan.host_slot_b[mplan.p2:]]))
        if mplan.p2 < m else None)
    plan = kvt.plan_tiers(
        geom, codec, tbl, mplan.own_slot, mplan.p2, m,
        kvt.TierSpec(hot_bytes=hot, cold_bw=H2D_BW),
        lps=sm.attn_layers, b=1, kvh=cfg.num_kv_heads,
        hd=cfg.resolved_head_dim, tick_s=dur, host_slots=host_slots)
    s = plan.summary()
    print(f"[kv-offload] bucket {bucket} kv_dtype={codec.name} "
          f"page_tokens={geom.page_tokens}: pages {s['pages']} | "
          f"hot {s['hot_bytes']/1e9:.2f} GB | warm {s['warm_bytes']/1e9:.2f} GB"
          f" | cold {s['cold_bytes']/1e9:.2f} GB | "
          f"prefetch ops {s['prefetch_ops']} "
          f"(peak {s['worst_tick_bw']/1e9:.2f} GB/s vs {H2D_BW/1e9:.0f}) | "
          f"{'FEASIBLE' if s['feasible'] else 'INFEASIBLE'}")


def _resolve_hw(opts: ServeOptions):
    if opts.calibrated_profile:
        hw = cm.resolve_profile(opts.calibrated_profile)
        print(f"[profile] {opts.calibrated_profile} -> {hw.name} "
              f"(gemm_eff={hw.gemm_eff:.3f} attn_eff={hw.attn_eff:.3f})")
        return hw
    return cm.TPU_V5E


def _build_engine(opts: ServeOptions, *, topo=None, jax_ctx=None):
    """One serving cell from ONE declarative ServeOptions: (cfg, ec, engine).

    ``jax_ctx`` (a dict) carries the device-dependent pieces shared across
    fleet cells: {"stages": N, "tp": T}. ``topo`` pins the cell to a
    specific mesh block (``launch.cells.enumerate_cell_meshes``); None =
    one mesh over all devices. Engines come out config-constructed — all
    policy/slo/trace knobs ride on EngineConfig, none on kwargs."""
    hw = _resolve_hw(opts)
    slo = opts.slo_ms / 1e3 if opts.slo_ms else None
    want_trace = opts.trace_out is not None
    if opts.executor == "sim":
        cfg = get_config(opts.arch)
        ec = EngineConfig(model=cfg, hw=hw, num_stages=16, tp=16,
                          num_chunks=16, max_batch=opts.max_batch,
                          buckets=opts.buckets or SIM_BUCKETS,
                          partition="lbcp", kv_dtype=opts.kv_dtype,
                          kv_page_tokens=opts.kv_page_tokens,
                          policy=opts.policy, slo=slo, trace=want_trace,
                          prefix_cache=opts.prefix_cache,
                          prefix_min_pages=opts.prefix_min_pages)
        executor = SimExecutor(cfg, hw)
    else:
        from repro import compat
        compat.ensure_host_devices()
        import jax
        from repro.core import pipeline as pp
        from repro.launch.mesh import make_test_topology
        from repro.models.api import build_model
        cfg = replace(get_smoke_config(opts.arch)
                      if opts.preset == "smoke" else get_config(opts.arch),
                      dtype="float32")
        if jax_ctx is None:
            n_dev = jax.device_count()
            # tp=2 when the device count affords it; old jaxlib takes the
            # MANUAL TP lowering (build_plan resolves tp_lowering="auto" via
            # compat.resolve_tp_lowering — no more tp=1 fallback)
            tp = 2 if n_dev >= 4 else 1
            jax_ctx = {"stages": max(n_dev // tp, 2), "tp": tp}
        stages, tp = jax_ctx["stages"], jax_ctx["tp"]
        if topo is None:
            topo = make_test_topology(stages, tp)
        run = RunConfig(num_chunks=opts.num_chunks, num_stages=stages,
                        attn_backend=opts.attn_backend,
                        pool_backend=opts.pool_backend,
                        ssm_backend=opts.ssm_backend,
                        tp_lowering=opts.tp_lowering,
                        transport=opts.transport,
                        fetch_batch=opts.fetch_batch,
                        kv_dtype=opts.kv_dtype,
                        kv_page_tokens=opts.kv_page_tokens,
                        kv_offload=opts.kv_offload)
        plan = pp.build_plan(cfg, stages, opts.seq, run)
        if plan.tp_lowering == "manual" and tp > 1:
            print(f"[transport] manual TP lowering (tp={tp}, "
                  f"transport={plan.transport})")
        model = build_model(cfg)
        params = model.init(jax.random.key(opts.seed))
        staged = pp.stage_params(cfg, params, plan)
        ec = EngineConfig(model=cfg, hw=hw, num_stages=stages, tp=tp,
                          num_chunks=opts.num_chunks,
                          max_batch=opts.max_batch,
                          buckets=opts.buckets or (opts.seq,),
                          partition="uniform", kv_dtype=opts.kv_dtype,
                          kv_page_tokens=opts.kv_page_tokens,
                          policy=opts.policy, slo=slo, trace=want_trace,
                          prefix_cache=opts.prefix_cache,
                          prefix_min_pages=opts.prefix_min_pages)
        executor = JaxExecutor(cfg, staged, topo, run)
    if opts.scheduler == "continuous":
        eng = ContinuousEngine(ec, executor)
    else:
        eng = PrefillEngine(ec, executor)
    return cfg, ec, eng


def _make_requests(opts: ServeOptions, vocab_size: int):
    from repro.sched import poisson_arrivals
    arrivals = poisson_arrivals(opts.arrival_rate, opts.requests,
                                seed=opts.seed)
    rng = np.random.default_rng(opts.seed)
    out = []
    for i in range(opts.requests):
        toks = (rng.integers(0, vocab_size, size=opts.seq).astype(np.int32)
                if opts.executor == "jax" else None)
        out.append(Request(rid=i, arrival=float(arrivals[i]),
                           seq_len=opts.seq, tokens=toks))
    return out


# ------------------------------------------------------------------- fleet

def _run_fleet(opts: ServeOptions) -> int:
    """N cells behind the fleet router: one shared arrival stream, per-cell
    EngineConfigs from the fleet spec, roll-up metrics + ONE merged trace
    with per-cell process rows."""
    from repro.fleet import FleetFabric, FleetRouter
    router_policy, cell_opts = resolve_fleet(opts)
    if any(co.scheduler != "continuous" for co in cell_opts):
        print("note: fleet cells require --scheduler continuous; overriding")
        cell_opts = [co.override(scheduler="continuous") for co in cell_opts]
    topos = [None] * len(cell_opts)
    jax_ctx = None
    if opts.executor == "jax":
        from repro import compat
        compat.ensure_host_devices()
        import jax
        from repro.launch.cells import enumerate_cell_meshes
        n_dev = jax.device_count()
        tp = 2 if n_dev >= 4 else 1
        stages = max(n_dev // tp, 2)
        jax_ctx = {"stages": stages, "tp": tp}
        topos = list(enumerate_cell_meshes(len(cell_opts), stages, tp))
        if len(cell_opts) * stages * tp > n_dev:
            print(f"note: {len(cell_opts)} cells x {stages}x{tp} exceeds "
                  f"{n_dev} devices; cells share device blocks "
                  f"(replicated-cell mode, serialized execution)")
    cells = {}
    vocab = 0
    for i, (co, topo) in enumerate(zip(cell_opts, topos)):
        cfg, ec, eng = _build_engine(co, topo=topo, jax_ctx=jax_ctx)
        cells[f"cell{i}"] = eng
        vocab = cfg.vocab_size
    fab = FleetFabric(cells, FleetRouter(router_policy))
    monitor = None
    if opts.health:
        from repro.obs.health import HealthMonitor
        monitor = HealthMonitor()
        fab.configure_obs(health=monitor)
    if opts.trace_out:
        fab.configure_obs(telemetry=True)

    t0 = time.time()
    for req in _make_requests(opts, vocab):
        fab.submit(req)
    fab.pump()
    wall = time.time() - t0

    m = fab.metrics()
    slo_txt = (f" | SLO {m['slo_met']}/{m['slo_total']}"
               if m["slo_total"] else "")
    print(f"[fleet {router_policy} x{m['cells']}] completed {m['completed']} "
          f"(rejected {m['rejected']}) in {wall:.2f}s wall | "
          f"makespan {m['makespan']:.3f}s | "
          f"avg TTFT {m['avg_ttft']:.3f}s | p99 {m['p99_ttft']:.3f}s | "
          f"{m['throughput']:.3f} req/s{slo_txt}")
    for name, pc in m["per_cell"].items():
        print(f"  {name}: {pc['completed']} done "
              f"(rejected {pc['rejected']}) | p99 {pc['p99_ttft']:.3f}s")
    if opts.trace_out or opts.metrics_out:
        paths = fab.export_obs(trace_out=opts.trace_out,
                               metrics_out=opts.metrics_out)
        for kind, path in paths.items():
            print(f"{kind} -> {path}")
    return 0


# ------------------------------------------------------------- single cell

def _run_single(opts: ServeOptions) -> int:
    cfg, ec, eng = _build_engine(opts)
    if opts.kv_offload:
        _print_tier_summary(cfg, ec, opts.kv_dtype, opts.kv_page_tokens)
    slo = opts.slo_ms / 1e3 if opts.slo_ms else None

    if opts.trace_out:
        # the merged timeline wants the device-side (stage, tick) profile:
        # switch the jit cache to the return_telemetry=True pipeline (the
        # sim executor has no telemetry switch — configure_obs skips it)
        eng.configure_obs(telemetry=True)
    monitor = None
    if opts.health:
        from repro.obs.health import HealthMonitor
        monitor = HealthMonitor()
        eng.configure_obs(health=monitor)
    calibrate_out = opts.calibrate
    if calibrate_out:
        if opts.executor == "jax":
            eng.configure_obs(measured=True)
        else:
            print("note: --calibrate measures the jax executor; the sim "
                  "path IS the analytic model — skipping (the sim-backed "
                  "calibration leg lives in benchmarks/calibration.py)")
            calibrate_out = None

    arrival_rate = opts.arrival_rate
    if opts.scheduler == "batch" and arrival_rate > 0:
        # the batch-synchronous engine admits everything at clock 0 and its
        # E2E metric is finish - arrival: staggered arrivals would produce
        # negative latencies there, so open-loop arrivals are continuous-only
        print("note: --arrival-rate requires --scheduler continuous; "
              "running the batch engine as a closed loop (arrivals at t=0)")
        opts = opts.override(arrival_rate=0.0)
    for req in _make_requests(opts, cfg.vocab_size):
        eng.submit(req)
    t0 = time.time()
    if opts.profile_dir and opts.executor == "jax":
        import jax
        with jax.profiler.trace(opts.profile_dir):
            eng.run_until_drained()
        print(f"xla profile -> {opts.profile_dir}")
    else:
        if opts.profile_dir:
            print("note: --profile-dir needs --executor jax; skipping")
        eng.run_until_drained()
    wall = time.time() - t0
    finished = eng.poll()

    if calibrate_out:
        meas = eng.measured_waves()
        if not meas:
            print("note: no measured waves; nothing to calibrate")
        else:
            from repro.core import mbkr
            from repro.obs import calibrate as cal
            w = meas[-1]            # later waves are warm (compile is paid)
            sm = cm.StageModel.build(cfg, w["num_stages"], ec.tp)
            mplan = (mbkr.plan(len(w["chunks"]), w["num_stages"])
                     if not cfg.attn_free else None)
            fit = cal.fit_profile(sm, w["chunks"], w["measured"], ec.hw,
                                  mbkr_plan=mplan)
            cal.save_profile(calibrate_out, fit.profile, fit=fit,
                             meta={"arch": opts.arch, "seq": opts.seq,
                                   "source": "serve"})
            print(f"[calibrate] {ec.hw.name} -> {fit.profile.name}: span "
                  f"MAPE {fit.mape_nominal:.3f} -> {fit.mape_calibrated:.3f}"
                  f" over {len(fit.rows)} spans -> {calibrate_out}")
    if monitor is not None:
        if slo is not None and opts.scheduler == "continuous":
            from repro.obs.metrics import Histogram
            h = Histogram("ttft")
            for rec in eng.records():
                if math.isfinite(rec.finish):
                    h.observe(rec.finish - rec.arrival)
            monitor.check_slo(h, slo)
        s = monitor.summary()
        burn = (f" | burn {s['burn_rate']:.2f}x"
                if s["burn_rate"] is not None else "")
        print(f"[health] alerts {s['alerts_total']} {s['by_kind']}{burn}")

    m = eng.metrics()
    if opts.scheduler == "continuous":
        slo_txt = (f" | SLO {m['slo_met']}/{m['slo_total']}"
                   if m["slo_total"] else "")
        print(f"[{opts.policy}] completed {m['completed']} "
              f"(rejected {m['rejected']}) in {wall:.2f}s wall | "
              f"sched clock {m['makespan']:.3f}s | "
              f"avg TTFT {m['avg_ttft']:.3f}s | p99 {m['p99_ttft']:.3f}s | "
              f"avg queue {m['avg_queue_wait']:.3f}s | "
              f"{m['throughput']:.3f} req/s | "
              f"bubble {m['bubble_frac']*100:.1f}%{slo_txt}")
        if opts.trace_out or opts.metrics_out:
            paths = eng.export_obs(trace_out=opts.trace_out,
                                   metrics_out=opts.metrics_out,
                                   extra={"wall_seconds": wall})
            for kind, path in paths.items():
                print(f"{kind} -> {path}")
    else:
        print(f"completed {m['completed']} requests in {wall:.2f}s wall | "
              f"engine clock {eng.clock:.3f}s | avg E2E {m['avg_e2e']:.3f}s | "
              f"p99 {m['p99_e2e']:.3f}s | {m['throughput']:.3f} req/s | "
              f"stages {m['num_stages']}")
        if opts.trace_out:
            print("note: --trace-out needs --scheduler continuous; skipping")
        if opts.metrics_out:
            from repro.obs.metrics import export_engine_metrics
            path = export_engine_metrics(opts.metrics_out, m,
                                         extra={"wall_seconds": wall},
                                         health=monitor)
            print(f"metrics -> {path}")
    if opts.executor == "jax":
        for r in sorted(finished, key=lambda r: r.rid)[:3]:
            top = int(np.argmax(r.result))
            print(f"  request {r.rid}: next-token argmax = {top}")
    return 0


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    ap.add_argument("--options-in", default=None,
                    help="load a ServeOptions JSON (written by "
                         "--options-out); explicit flags override it")
    ap.add_argument("--options-out", default=None,
                    help="write the RESOLVED options JSON here (replayable "
                         "via --options-in), then run")
    ns = ap.parse_args(argv)
    base = ServeOptions()
    if ns.options_in:
        with open(ns.options_in) as f:
            base = ServeOptions.from_json(f.read())
    opts = options_from_args(ns, base)
    if ns.options_out:
        from repro.obs._io import atomic_write_text
        path = atomic_write_text(ns.options_out, opts.to_json())
        print(f"options -> {path}")
    if opts.cells > 1 or opts.fleet_spec:
        return _run_fleet(opts)
    return _run_single(opts)


if __name__ == "__main__":
    raise SystemExit(main())
