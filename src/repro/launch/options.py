"""Declarative serving options: ONE serializable dataclass for the whole
serve surface.

``ServeOptions`` replaces serve.py's loose ~25-flag argparse namespace:

- every flag maps to a same-named field (dashes -> underscores), so a run
  is reproducible from a JSON file (``--options-out`` writes it,
  ``--options-in`` replays it);
- CLI flags are OVERRIDES: the parser registers every flag with
  ``argparse.SUPPRESS`` defaults, so only flags the user actually typed
  land in the namespace — merge order is dataclass defaults <-
  ``--options-in`` JSON <- explicit flags;
- a FLEET SPEC is a list of per-cell ServeOptions override dicts plus a
  router policy: ``{"router": "jsf", "cells": [{"kv_dtype": "int8"}, {}]}``
  — each cell's EngineConfig derives from the base options with that
  cell's overrides applied (heterogeneous cells by construction).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from dataclasses import dataclass, field, fields, replace as dc_replace
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ServeOptions:
    # model / scale
    arch: str = "qwen3-8b"
    preset: str = "smoke"              # smoke | full (jax executor)
    executor: str = "jax"              # jax | sim
    requests: int = 12
    seq: int = 256
    num_chunks: int = 8
    max_batch: int = 4
    seed: int = 0
    buckets: Optional[Tuple[int, ...]] = None   # None = executor default
    # kernel / transport backends
    attn_backend: str = "jnp"
    pool_backend: str = "auto"
    ssm_backend: str = "jnp"
    tp_lowering: str = "auto"
    transport: str = "jax"
    fetch_batch: str = "auto"
    # KV page store
    kv_dtype: str = "auto"
    kv_page_tokens: int = 0
    kv_offload: bool = False
    # cross-request prefix KV reuse (repro.kvstore.prefix)
    prefix_cache: str = "off"          # off | on
    prefix_min_pages: int = 1
    # scheduling
    scheduler: str = "batch"           # batch | continuous
    policy: str = "fcfs"               # fcfs | sjf | edf
    arrival_rate: float = 0.0
    slo_ms: Optional[float] = None
    # fleet (multi-cell)
    cells: int = 1
    router: str = "jsf"                # jsf | rr | least-loaded
    fleet_spec: Optional[str] = None   # path to the fleet-spec JSON
    # observability
    trace_out: Optional[str] = None
    metrics_out: Optional[str] = None
    profile_dir: Optional[str] = None
    calibrated_profile: Optional[str] = None
    calibrate: Optional[str] = None
    health: bool = False

    # ------------------------------------------------------------ round-trip
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if d["buckets"] is not None:
            d["buckets"] = list(d["buckets"])
        return d

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServeOptions":
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown ServeOptions keys: {unknown} "
                             f"(expected a subset of {sorted(known)})")
        d = dict(d)
        if d.get("buckets") is not None:
            d["buckets"] = tuple(int(b) for b in d["buckets"])
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "ServeOptions":
        return cls.from_dict(json.loads(text))

    def override(self, **kw) -> "ServeOptions":
        """Same validation as from_dict, replace() semantics."""
        if kw.get("buckets") is not None:
            kw["buckets"] = tuple(int(b) for b in kw["buckets"])
        known = {f.name for f in fields(type(self))}
        unknown = sorted(set(kw) - known)
        if unknown:
            raise ValueError(f"unknown ServeOptions keys: {unknown}")
        return dc_replace(self, **kw)


# ------------------------------------------------------------------- parser

def _csv_ints(text: str) -> Tuple[int, ...]:
    return tuple(int(t) for t in text.split(",") if t.strip())


def add_serve_args(ap: argparse.ArgumentParser) -> None:
    """Register every ServeOptions field as a flag with SUPPRESS defaults —
    the namespace carries ONLY flags the user explicitly typed, so they
    overlay cleanly on ``--options-in`` / fleet-spec values."""
    S = argparse.SUPPRESS
    ap.add_argument("--arch", default=S)
    ap.add_argument("--preset", default=S, choices=("smoke", "full"))
    ap.add_argument("--executor", default=S, choices=("jax", "sim"))
    ap.add_argument("--requests", type=int, default=S)
    ap.add_argument("--seq", type=int, default=S)
    ap.add_argument("--num-chunks", type=int, default=S)
    ap.add_argument("--max-batch", type=int, default=S)
    ap.add_argument("--seed", type=int, default=S)
    ap.add_argument("--buckets", type=_csv_ints, default=S,
                    help="comma-separated bucket boundaries (tokens); "
                         "default: executor-specific")
    ap.add_argument("--attn-backend", default=S, choices=("jnp", "pallas"),
                    help="attention inner-loop backend (core.attention): "
                         "jnp = pure-jnp reference, pallas = the flash "
                         "kernel (interpret mode off-TPU)")
    ap.add_argument("--pool-backend", default=S,
                    choices=("auto", "jnp", "pallas", "paged"),
                    help="backend for POOL-sourced partials (own-pool scan "
                         "+ fetch/qship) — mixable with --attn-backend; "
                         "paged = one RAGGED launch straight off the page "
                         "store (DESIGN.md §3.7)")
    ap.add_argument("--ssm-backend", default=S, choices=("jnp", "pallas"),
                    help="SSD inner loop for ssm/hybrid archs")
    ap.add_argument("--tp-lowering", default=S, choices=("auto", "manual"),
                    help="TP lowering (core.transport, DESIGN.md §3.6)")
    ap.add_argument("--transport", default=S,
                    help="transport registry entry for cross-stage/"
                         "cross-rank collectives (core.transport)")
    ap.add_argument("--fetch-batch", default=S, choices=("auto", "on", "off"),
                    help="batched fetch: land remote chunk-layers in a "
                         "staging buffer + ONE pool_attention launch")
    ap.add_argument("--kv-dtype", default=S,
                    choices=("auto", "bfloat16", "int8", "fp8"),
                    help="KV page-store codec (repro.kvstore): int8/fp8 "
                         "store+ship quantized pages; leases count "
                         "quantized bytes (~2x admission capacity)")
    ap.add_argument("--kv-page-tokens", type=int, default=S,
                    help="tokens per KV page (0 = one page per chunk)")
    ap.add_argument("--kv-offload", action="store_true", default=S,
                    help="plan the cold KV tier (kvstore.tiers) and print "
                         "the tier summary")
    ap.add_argument("--prefix-cache", default=S, choices=("off", "on"),
                    help="radix prefix KV index (kvstore.prefix): admitted "
                         "requests whose leading chunks are already "
                         "resident lease only their novel suffix "
                         "(continuous scheduler); off = bit-identical to a "
                         "build without the feature")
    ap.add_argument("--prefix-min-pages", type=int, default=S,
                    help="ignore prefix hits smaller than this many pages")
    ap.add_argument("--scheduler", default=S, choices=("batch", "continuous"),
                    help="batch = batch-synchronous PrefillEngine; "
                         "continuous = cross-request chunk pipelining")
    ap.add_argument("--policy", default=S, choices=("fcfs", "sjf", "edf"),
                    help="continuous-mode admission policy")
    ap.add_argument("--arrival-rate", type=float, default=S,
                    help="open-loop Poisson arrivals (req/s); 0 = closed loop")
    ap.add_argument("--slo-ms", type=float, default=S,
                    help="per-request SLO (deadline = arrival + slo)")
    ap.add_argument("--cells", type=int, default=S,
                    help="fleet mode: run N serving cells behind the fleet "
                         "router (repro.fleet); implies --scheduler "
                         "continuous")
    ap.add_argument("--router", default=S, choices=("jsf", "rr", "least-loaded"),
                    help="fleet placement policy: jsf = join-shortest-"
                         "finish (lease/cost-aware ETA), rr = round-robin, "
                         "least-loaded = smallest queue depth")
    ap.add_argument("--fleet-spec", default=S,
                    help="fleet-spec JSON: {\"router\": ..., \"cells\": "
                         "[per-cell ServeOptions overrides, ...]} — "
                         "heterogeneous cells (kv_dtype, buckets, "
                         "calibrated_profile, ...)")
    ap.add_argument("--trace-out", default=S,
                    help="write ONE merged Chrome/Perfetto trace here; in "
                         "fleet mode each cell gets its own process rows")
    ap.add_argument("--metrics-out", default=S,
                    help="export serving metrics here (repro.obs.metrics): "
                         ".prom = Prometheus textfile, else JSON lines")
    ap.add_argument("--profile-dir", default=S,
                    help="wrap the run in jax.profiler.trace(dir) "
                         "(jax executor only)")
    ap.add_argument("--calibrated-profile", default=S,
                    help="HardwareProfile for planning/admission costs: a "
                         "registered name or a calibrated-profile JSON "
                         "(obs.calibrate)")
    ap.add_argument("--calibrate", default=S, metavar="OUT",
                    help="fit the effective HardwareProfile from measured "
                         "spans (jax executor only) and write it to OUT")
    ap.add_argument("--health", action="store_true", default=S,
                    help="arm the runtime health sentinels (obs.health)")


def options_from_args(ns: argparse.Namespace,
                      base: Optional[ServeOptions] = None) -> ServeOptions:
    """Overlay the explicitly-typed flags (SUPPRESS leaves the rest out of
    the namespace) onto ``base`` (defaults or ``--options-in``)."""
    base = base or ServeOptions()
    known = {f.name for f in fields(ServeOptions)}
    explicit = {k: v for k, v in vars(ns).items() if k in known}
    return base.override(**explicit)


# --------------------------------------------------------------- fleet spec

@dataclass(frozen=True)
class FleetSpec:
    """Router policy + per-cell option overrides. ``cell_options(base)``
    materializes the per-cell ServeOptions list: base <- overrides[i]."""
    router: str = "jsf"
    cells: Tuple[Dict[str, Any], ...] = field(default_factory=tuple)

    @classmethod
    def from_json(cls, text: str) -> "FleetSpec":
        d = json.loads(text)
        unknown = sorted(set(d) - {"router", "cells"})
        if unknown:
            raise ValueError(f"unknown fleet-spec keys: {unknown}")
        return cls(router=d.get("router", "jsf"),
                   cells=tuple(d.get("cells", ())))

    def cell_options(self, base: ServeOptions) -> List[ServeOptions]:
        return [base.override(**dict(ov)) for ov in self.cells]


def resolve_fleet(opts: ServeOptions) -> Tuple[str, List[ServeOptions]]:
    """(router policy, per-cell options) from ``--fleet-spec`` (wins) or
    ``--cells N`` homogeneous replication."""
    if opts.fleet_spec:
        with open(opts.fleet_spec) as f:
            spec = FleetSpec.from_json(f.read())
        router = opts.router if opts.router != "jsf" else spec.router
        cells = spec.cell_options(opts)
        if not cells:
            raise ValueError(f"fleet spec {opts.fleet_spec} lists no cells")
        return router, cells
    return opts.router, [opts] * max(opts.cells, 1)
