from repro.runtime.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.runtime.engine import (CellHandle, ContinuousEngine, EngineConfig,
                                  PrefillEngine, Request, SimExecutor,
                                  JaxExecutor)
