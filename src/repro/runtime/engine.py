"""Prefill-only serving engine: request queue -> chunked-pipeline execution
with MOCAP plans, plus the fault-tolerance / elasticity layer.

Responsibilities:
- ADMISSION: requests are bucketed by padded sequence length; each bucket has
  a cached LBCP plan (DP+SA is amortized across requests — plans are a pure
  function of (bucket, N, M)).
- EXECUTION: pluggable executor. ``JaxExecutor`` drives the real jit'd
  ``core.pipeline.prefill_pipeline``; ``SimExecutor`` drives the analytic cost
  model with fault/straggler injection (tests, capacity planning).
- FAULT TOLERANCE: a stage failure loses that stage's layer-slice KV, so
  in-flight requests cannot be resumed mid-chunk — the engine re-forms the
  pipeline WITHOUT the failed stage (N -> N-1... rounded down to even, MBKR
  needs pairs), re-plans all buckets, and REPLAYS in-flight requests from
  their admission watermark. Completed requests are never recomputed.
- STRAGGLER MITIGATION: per-stage chunk-latency EWMA; sustained skew above
  ``straggler_threshold`` triggers a re-plan with the observed per-stage speed
  factors folded into the cost model; a stage past ``evict_threshold`` is
  treated as failed (same re-mesh path).
- CHECKPOINT/RESTART: the full engine state (queue, watermarks, plans, clock,
  EWMA) serializes through ``runtime.checkpoint`` next to the model params.

Two engines share the executors:
- ``PrefillEngine``: BATCH-SYNCHRONOUS — one bucket-batch runs to completion
  before the next forms; every request pays the pipeline fill/drain bubble.
- ``ContinuousEngine``: drives the executor through the chunk-level scheduler
  (``repro.sched``) for cross-request pipelining — bubble-free across request
  boundaries, policy-ordered (FCFS/SJF/EDF) KV-lease-gated admission.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, replace as dc_replace
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import costmodel as cm
from repro.core import lbcp, mbkr


@dataclass
class Request:
    rid: int
    arrival: float
    seq_len: int
    tokens: Optional[np.ndarray] = None
    state: str = "queued"          # queued | running | done
    bucket: int = 0
    finish_time: float = math.inf
    replays: int = 0
    result: Any = None
    deadline: float = math.inf     # absolute SLO deadline (continuous mode)
    # chained chunk-content hashes (kvstore.prefix.chunk_hashes); filled by
    # ContinuousEngine.submit from tokens when the prefix cache is armed,
    # or supplied directly by token-free (sim / bench) callers
    prefix_hashes: Tuple[int, ...] = ()


def bucket_of(buckets: Sequence[int], seq_len: int) -> int:
    for b in buckets:
        if seq_len <= b:
            return b
    return buckets[-1]


@dataclass(frozen=True)
class EngineConfig:
    model: ModelConfig
    hw: cm.HardwareProfile = cm.TPU_V5E
    num_stages: int = 16
    tp: int = 16
    num_chunks: int = 16
    max_batch: int = 8
    buckets: Tuple[int, ...] = (8192, 32768, 131072)
    partition: str = "lbcp"        # uniform | lbcp
    mbkr: bool = True
    compress: float = 1.0
    # KV page store codec (repro.kvstore): admission leases count the
    # STORED (quantized) bytes, so "int8"/"fp8" grow capacity ~2x
    kv_dtype: str = "auto"
    kv_page_tokens: int = 0
    sa_iters: int = 60
    straggler_threshold: float = 1.3   # max/median EWMA tick latency
    evict_threshold: float = 3.0
    ewma_alpha: float = 0.3
    # Continuous-serving policy knobs (formerly ContinuousEngine kwargs):
    # engines are constructible from config alone, so a fleet cell is fully
    # described by ONE declarative EngineConfig (repro.fleet / fleet specs)
    policy: str = "fcfs"               # fcfs | sjf | edf admission order
    slo: Optional[float] = None        # seconds; deadline = arrival + slo
    inflight: int = 2                  # MBKR slot pools provisioned
    trace: bool = False                # record the scheduler trace
    # Cross-request prefix KV reuse (repro.kvstore.prefix, DESIGN.md §11):
    # "on" arms the radix index — an admitted request whose leading chunks
    # are already resident leases ONLY its novel suffix and is priced
    # against the shorter effective sequence; "off" (default) keeps the
    # lowering bit-identical to a build without the feature
    prefix_cache: str = "off"          # off | on
    prefix_min_pages: int = 1          # ignore hits smaller than this


class StageFailure(RuntimeError):
    def __init__(self, stage: int):
        super().__init__(f"stage {stage} failed")
        self.stage = stage


# ----------------------------------------------------------- cell protocol

@runtime_checkable
class CellHandle(Protocol):
    """The NARROW seam between one serving cell and everything above it.

    A cell is one pipeline (scheduler + lease manager + executor) behind a
    handful of methods; the fleet router (``repro.fleet``) and the serve
    driver (``launch.serve``) consume ONLY this protocol — no reaching into
    ``.scheduler`` / ``.lease`` / ``.executor`` internals (source-scan
    enforced by ``tests/test_fleet.py``, the same idiom as the PR 5
    transport grep). ``ContinuousEngine`` is the canonical implementation.

    Lifecycle: ``submit`` -> ``run_until_drained`` (re-entrant pump) ->
    ``poll`` (completed requests since the last poll). ``drain`` stops
    admission permanently and completes in-flight work. Router signals:
    ``queue_depth``, ``free_lease_bytes``, ``estimate_admission`` — the
    load-, lease- and cost-aware placement inputs.
    """

    draining: bool

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: "Request") -> None: ...
    def run_until_drained(self) -> None: ...
    def poll(self) -> List["Request"]: ...
    def drain(self) -> List["Request"]: ...

    # -------------------------------------------------------------- signals
    def queue_depth(self) -> int: ...
    def free_lease_bytes(self) -> float: ...
    def estimate_admission(self, seq_len: int, arrival: float = 0.0,
                           prefix_hashes: Optional[Sequence[int]] = None
                           ) -> Tuple[float, bool]: ...
    def prefix_stats(self) -> Dict[str, Any]: ...
    def prefix_hit_pages(self, prefix_hashes: Sequence[int]) -> int: ...

    # ----------------------------------------------------- metrics / obs
    def metrics(self) -> Dict[str, Any]: ...
    def records(self) -> List[Any]: ...
    def recalibrate(self, hw: Any) -> Any: ...
    def merged_trace(self) -> Any: ...
    def export_obs(self, trace_out: Optional[str] = None,
                   metrics_out: Optional[str] = None,
                   extra: Optional[Dict[str, float]] = None,
                   health: Any = None) -> Dict[str, str]: ...
    def configure_obs(self, *, telemetry: Optional[bool] = None,
                      measured: Optional[bool] = None,
                      health: Any = None) -> None: ...
    def measured_waves(self) -> List[Dict[str, Any]]: ...


# ---------------------------------------------------------------- executors

class SimExecutor:
    """Analytic executor: returns per-stage makespan from the cost model.
    Fault/straggler injection for engine tests:
      fail_at[(batch_counter)] = stage    -> raise StageFailure mid-batch
      slow = {stage: factor}              -> inflate that stage's tick times

    BATCH-SYNCHRONOUS semantics: requests in a batch run to completion one
    after another, each paying the full pipeline fill/drain (this is the
    baseline that ``ContinuousEngine`` + ``sched.ChunkScheduler`` eliminate).
    Straggler factors scale only the affected stage's task durations; the
    per-request makespan is recomputed from per-stage times by the shared
    list-scheduling core, so an off-critical-path slow stage no longer
    inflates the whole makespan.
    """

    def __init__(self, cfg: ModelConfig, hw: cm.HardwareProfile,
                 fail_at: Optional[Dict[int, int]] = None,
                 slow: Optional[Dict[int, float]] = None):
        self.cfg, self.hw = cfg, hw
        self.fail_at = fail_at or {}
        self.slow = slow or {}
        self.batch_counter = 0

    def stage_scale(self, num_stages: int) -> np.ndarray:
        scale = np.ones(num_stages)
        for s, f in self.slow.items():
            if s < num_stages:
                scale[s] = max(float(f), 1e-9)
        return scale

    def chunk_costs(self, chunks: Sequence[int], num_stages: int, tp: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """(per-chunk task seconds, per-chunk boundary comm seconds)."""
        sm = cm.StageModel.build(self.cfg, num_stages, tp)
        dur, comm, _, _, _ = cm.chunk_cost_arrays(sm, chunks, self.hw)
        return dur, comm

    def run(self, requests: Sequence[Request], chunks: Sequence[int],
            num_stages: int, tp: int) -> Tuple[float, np.ndarray]:
        """Returns (makespan seconds, per-stage avg tick latency [N])."""
        from repro.sim.engine import schedule_request
        self.batch_counter += 1
        if self.batch_counter in self.fail_at:
            raise StageFailure(self.fail_at[self.batch_counter])
        dur, comm, = self.chunk_costs(chunks, num_stages, tp)
        scale = self.stage_scale(num_stages)
        finish = schedule_request(dur, comm, num_stages, np.zeros(num_stages),
                                  stage_scale=scale)
        lat_req = float(finish[-1][-1])
        lat = np.full(num_stages, dur.mean()) * scale
        makespan = lat_req * max(len(requests), 1)
        return makespan, lat


class JaxExecutor:
    """Real executor: jit'd chunked-pipeline prefill on the current mesh.

    ``collect_telemetry`` (settable any time; keyed into the jit cache)
    switches the pipeline to ``return_telemetry=True`` and records one
    entry per wave in ``self.waves``: wall-clock (start, dur) relative to
    executor construction, the [N, T] StageTelemetry profile, and the
    per-event wire prices — ``ContinuousEngine.merged_trace`` turns these
    into engine wave spans, per-stage tick spans and KV/wire counter
    tracks. Off by default: the compiled program is the plain pipeline.

    ``collect_measured`` additionally arms the pipeline's ``tick_hook``
    (``obs.profile.TickSpanCollector``) and lands a measured per-(stage,
    tick) wall-clock span array in ``wave["measured"]`` — the calibration
    input (``obs.calibrate.fit_profile``). The first wave at a given key
    includes compile in tick 0; calibrate against a repeat wave.

    ``health`` (an ``obs.health.HealthMonitor``) arms the non-finite
    sentinels in the pipeline and, when telemetry is also on, runs the
    occupancy-drift check against each wave. Attach BEFORE the first run
    at a given shape — the monitor is captured at trace time.

    ``prefix_enabled`` (set by ``ContinuousEngine`` when
    ``EngineConfig.prefix_cache == "on"``) arms the DEVICE half of the
    prefix cache: every wave runs with ``return_kv=True`` and lands each
    request's batch element of the final paged pool in a per-geometry
    ``kvstore.prefix.DeviceSeedCache``; a later wave whose requests all
    share a cached prefix of ``k`` chunks is seeded from those snapshots
    and compiled with ``prefix_chunks=k`` (hit chunks read cached KV, their
    writes land in the scratch slot)."""

    def __init__(self, cfg: ModelConfig, staged_params, topo, run: RunConfig):
        import time
        from repro.core import pipeline as pp
        self.cfg, self.topo, self.run_cfg = cfg, topo, run
        self.staged = staged_params
        self._fns: Dict[Tuple, Tuple[Callable, Any]] = {}
        self._pp = pp
        self.collect_telemetry = False
        self.collect_measured = False
        self.health = None
        self._span_col = None
        self.waves: List[Dict[str, Any]] = []
        self._epoch = time.perf_counter()
        self.prefix_enabled = False
        self.prefix_seed_entries = 8       # DeviceSeedCache LRU bound
        self._seed_caches: Dict[Tuple, Any] = {}   # (seq, m) -> DeviceSeedCache
        self.prefix_device_hit_chunks = 0  # sum of seeded k over waves

    # ----------------------------------------------------- device prefix
    def _seed_cache(self, seq: int, m: int):
        from repro.kvstore.prefix import DeviceSeedCache
        key = (seq, m)
        if key not in self._seed_caches:
            self._seed_caches[key] = DeviceSeedCache(self.prefix_seed_entries)
        return self._seed_caches[key]

    @staticmethod
    def _wave_chains(requests: Sequence[Request]) -> List[Tuple[int, ...]]:
        return [tuple(getattr(r, "prefix_hashes", ()) or ()) for r in requests]

    def _assemble_seed(self, cache, chains: List[Tuple[int, ...]], k: int):
        """Stack each request's cached batch element into one stage-stacked
        ``PagedPool`` [n, P, lps, B, ...] for ``prefill_pipeline``'s
        ``prefix_pool`` input. None if any element is missing."""
        from repro.kvstore.pages import PagedPool
        elems = [cache.lookup(ch, k) for ch in chains]
        if any(e is None for e in elems):
            return None
        stack = lambda key: (None if elems[0][key] is None else
                             np.stack([e[key] for e in elems], axis=3))
        return PagedPool(stack("k"), stack("v"),
                         stack("k_scale"), stack("v_scale"))

    def run(self, requests: Sequence[Request], chunks: Sequence[int],
            num_stages: int, tp: int) -> Tuple[float, np.ndarray]:
        import time
        import jax
        seq = int(sum(chunks))
        collect = bool(self.collect_telemetry)
        measured = bool(self.collect_measured)
        health = self.health
        armed = bool(self.prefix_enabled)
        # ---- device prefix: wave-uniform seedable hit length k (static —
        # keyed into the jit cache) + the stacked seed pool when k > 0
        k, seed_pool, chains, seed_cache = 0, None, [], None
        if armed:
            seed_cache = self._seed_cache(seq, len(chunks))
            chains = self._wave_chains(requests)
            if all(chains):
                k = min(seed_cache.match(ch) for ch in chains)
        key = (seq, len(chunks), collect, measured, health is not None,
               armed, k)
        if key not in self._fns:
            plan = self._pp.build_plan(
                self.cfg, num_stages, seq,
                dc_replace(self.run_cfg, num_chunks=len(chunks)))
            self._fns[key] = (None, plan)   # fn built below (needs the plan)
        _, plan = self._fns[key]
        if armed:
            k = min(k, plan.p2, len(chunks) - 1)
            if k > 0:
                seed_pool = self._assemble_seed(seed_cache, chains, k)
                if seed_pool is None:
                    k = 0
            self.prefix_device_hit_chunks += k
        if self._fns[key][0] is None:
            cfg, topo = self.cfg, self.topo
            hook = None
            if measured:
                if self._span_col is None:
                    from repro.obs.profile import TickSpanCollector
                    self._span_col = TickSpanCollector()
                hook = self._span_col.note
            kk = k
            if armed and kk > 0:
                fn = jax.jit(lambda st, tk, pool: self._pp.prefill_pipeline(
                    cfg, st, tk, plan, topo, return_telemetry=collect,
                    prefix_chunks=kk, prefix_pool=pool, return_kv=True,
                    tick_hook=hook, health=health))
            elif armed:
                fn = jax.jit(lambda st, tk: self._pp.prefill_pipeline(
                    cfg, st, tk, plan, topo, return_telemetry=collect,
                    return_kv=True, tick_hook=hook, health=health))
            else:
                fn = jax.jit(lambda st, tk: self._pp.prefill_pipeline(
                    cfg, st, tk, plan, topo, return_telemetry=collect,
                    tick_hook=hook, health=health))
            self._fns[key] = (fn, plan)
        fn, plan = self._fns[key]
        toks = np.stack([np.pad(r.tokens, (0, seq - len(r.tokens)))
                         for r in requests]).astype(np.int32)
        if measured and self._span_col is not None:
            self._span_col.reset()
        t0 = time.perf_counter()
        with jax.profiler.TraceAnnotation(
                f"prefill_wave seq{seq} b{len(requests)}"):
            res = (fn(self.staged, toks, seed_pool) if seed_pool is not None
                   else fn(self.staged, toks))
            if not isinstance(res, tuple):
                res = (res,)
            out = res[0]
            tel = res[1] if collect else None
            kv = res[1 + int(collect)] if armed else None
            out.block_until_ready()
        dt = time.perf_counter() - t0
        if kv is not None and seed_cache is not None:
            # snapshot each request's batch element of the final pool for
            # future waves (keyed by its full hash chain)
            for i, ch in enumerate(chains):
                if ch:
                    seed_cache.put(ch, {
                        f: (None if getattr(kv, f) is None else
                            np.asarray(getattr(kv, f)[:, :, :, i]))
                        for f in ("k", "v", "k_scale", "v_scale")})
        if measured or health is not None:
            jax.effects_barrier()    # order debug callbacks before the reads
        for r, row in zip(requests, np.asarray(out)):
            r.result = row
        wave: Dict[str, Any] = {
            "start": t0 - self._epoch, "dur": dt, "seq": seq,
            "num_ticks": int(plan.num_ticks), "num_stages": num_stages,
            "chunks": list(chunks), "rids": [r.rid for r in requests],
            "prefix_chunks": k,
        }
        if measured and self._span_col is not None:
            wave["measured"] = self._span_col.finalize(
                num_stages, int(plan.num_ticks)).tick_s
        if tel is not None:
            from repro.obs import telemetry as obs_t
            wave["telemetry"] = {k: np.asarray(v) for k, v in tel.items()}
            wave["per_event_wire"] = obs_t.per_event_wire_bytes(
                plan, self.cfg, len(requests))
            if health is not None:
                health.check_occupancy(wave["telemetry"], plan)
        self.waves.append(wave)
        return dt, np.full(num_stages, dt / max(len(chunks), 1))


# ------------------------------------------------------------------- engine

class PrefillEngine:
    def __init__(self, ec: EngineConfig, executor):
        self.ec = ec
        self.executor = executor
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._polled = 0
        self.clock = 0.0
        self.num_stages = ec.num_stages
        self.failed_stages: List[int] = []
        self.ewma: Optional[np.ndarray] = None  # lazily seeded by first obs
        self.replans = 0
        self.remeshes = 0
        self._plans: Dict[Tuple[int, int], List[int]] = {}

    # ---------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        req.bucket = self._bucket(req.seq_len)
        self.queue.append(req)

    def _bucket(self, seq_len: int) -> int:
        return bucket_of(self.ec.buckets, seq_len)

    def _plan_for(self, bucket: int) -> List[int]:
        key = (bucket, self.num_stages)
        if key not in self._plans:
            if self.ec.partition == "lbcp":
                pp = lbcp.plan_partition(
                    self.ec.model, bucket, self.ec.num_chunks, self.num_stages,
                    self.ec.hw, tp=self.ec.tp, mbkr=self.ec.mbkr,
                    compress=self.ec.compress, sa_iters=self.ec.sa_iters)
                self._plans[key] = pp.chunks
            else:
                self._plans[key] = lbcp.uniform_partition(bucket, self.ec.num_chunks)
        return self._plans[key]

    # ---------------------------------------------------------- main loop
    def step(self) -> bool:
        """Admit and run ONE batch. Returns False when the queue is empty.

        The batch's bucket is the one holding the OLDEST eligible request
        (by arrival, then rid) across all buckets — not the first queue
        entry's bucket, which would let one hot bucket starve the others
        (head-of-line blocking). Within the bucket, oldest requests first.
        """
        pending = [r for r in self.queue if r.state == "queued"]
        if not pending:
            return False
        oldest = min(pending, key=lambda r: (r.arrival, r.rid))
        bucket = oldest.bucket
        batch = sorted((r for r in pending if r.bucket == bucket),
                       key=lambda r: (r.arrival, r.rid))[: self.ec.max_batch]
        chunks = self._plan_for(bucket)
        for r in batch:
            r.state = "running"
        try:
            makespan, stage_lat = self.executor.run(
                batch, chunks, self.num_stages, self.ec.tp)
        except StageFailure as e:
            self._handle_failure(e.stage, batch)
            return True
        self.clock += makespan
        self._observe(stage_lat)
        for r in batch:
            r.state = "done"
            r.finish_time = self.clock
            self.queue.remove(r)
            self.done.append(r)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                return

    def poll(self) -> List[Request]:
        """Requests completed since the last ``poll`` (completion order) —
        the same cell-handle surface ``ContinuousEngine`` exposes."""
        new = self.done[self._polled:]
        self._polled = len(self.done)
        return list(new)

    def configure_obs(self, *, telemetry: Optional[bool] = None,
                      measured: Optional[bool] = None,
                      health: Any = None) -> None:
        """See ``ContinuousEngine.configure_obs`` — shared executor seam."""
        ex = self.executor
        if telemetry is not None and hasattr(ex, "collect_telemetry"):
            ex.collect_telemetry = bool(telemetry)
        if measured is not None and hasattr(ex, "collect_measured"):
            ex.collect_measured = bool(measured)
        if health is not None:
            ex.health = health

    def measured_waves(self) -> List[Dict[str, Any]]:
        """See ``ContinuousEngine.measured_waves`` — the calibration input."""
        return [w for w in getattr(self.executor, "waves", [])
                if w.get("measured") is not None]

    # ------------------------------------------------------ fault handling
    def _handle_failure(self, stage: int, batch: Sequence[Request]) -> None:
        """Stage loss: its layer-slice KV for in-flight requests is gone ->
        re-form the pipeline without it and replay the batch from admission."""
        self.failed_stages.append(stage)
        new_n = self.num_stages - 1
        if new_n % 2:
            new_n -= 1  # MBKR pairs stages; keep N even
        self.num_stages = max(new_n, 2)
        self.remeshes += 1
        self._plans.clear()          # plans depend on N — rebuild lazily
        self.ewma = None
        for r in batch:
            r.state = "queued"       # replay from the admission watermark
            r.replays += 1

    # -------------------------------------------------- straggler handling
    def _observe(self, stage_lat: np.ndarray) -> None:
        a = self.ec.ewma_alpha
        if self.ewma is None or len(stage_lat) != len(self.ewma):
            self.ewma = np.asarray(stage_lat, float)
        self.ewma = (1 - a) * self.ewma + a * stage_lat
        med = float(np.median(self.ewma))
        worst = int(np.argmax(self.ewma))
        skew = float(self.ewma[worst] / max(med, 1e-12))
        if skew > self.ec.evict_threshold:
            self._handle_failure(worst, [r for r in self.queue
                                         if r.state == "running"])
        elif skew > self.ec.straggler_threshold:
            self._plans.clear()      # fold new latencies into fresh plans
            self.replans += 1

    # ----------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        lat = [r.finish_time - r.arrival for r in self.done]
        return {
            "completed": len(self.done),
            "avg_e2e": float(np.mean(lat)) if lat else math.nan,
            "p99_e2e": float(np.percentile(lat, 99)) if lat else math.nan,
            "throughput": len(self.done) / self.clock if self.clock else 0.0,
            "replans": self.replans,
            "remeshes": self.remeshes,
            "num_stages": self.num_stages,
        }

    # ------------------------------------------------------- checkpointing
    def state_dict(self) -> Dict[str, Any]:
        """JSON-serializable engine state for ``runtime.checkpoint``.

        ROUND-TRIPS: clock, num_stages, failed_stages, ewma, replans,
        remeshes; per QUEUED request (rid, arrival, seq_len, state, replays);
        per DONE request (rid, arrival, seq_len, finish_time).

        INTENTIONALLY DROPPED: ``Request.tokens`` and ``Request.result``
        (host arrays belong to the data plane — the caller re-submits tokens
        after restore), a queued request's ``finish_time`` (always inf until
        completion), and ``bucket`` (recomputed from seq_len on load). A
        running request is restored as queued: execution is not resumable
        mid-batch, so it replays from its admission watermark.
        """
        return {
            "clock": self.clock,
            "num_stages": self.num_stages,
            "failed_stages": list(self.failed_stages),
            "ewma": self.ewma.tolist() if self.ewma is not None else None,
            "replans": self.replans,
            "remeshes": self.remeshes,
            "queue": [(r.rid, r.arrival, r.seq_len, r.state, r.replays)
                      for r in self.queue],
            "done": [(r.rid, r.arrival, r.seq_len, r.finish_time)
                     for r in self.done],
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.clock = d["clock"]
        self.num_stages = int(d["num_stages"])
        self.failed_stages = list(d["failed_stages"])
        self.ewma = np.asarray(d["ewma"]) if d["ewma"] is not None else None
        self.replans = int(d["replans"])
        self.remeshes = int(d["remeshes"])
        self.queue = [Request(rid, arr, sl, state="queued", replays=rp)
                      for rid, arr, sl, state, rp in d["queue"]]
        for r in self.queue:
            r.bucket = self._bucket(r.seq_len)
        self.done = [Request(rid, arr, sl, state="done", finish_time=ft)
                     for rid, arr, sl, ft in d["done"]]
        self._plans.clear()


# -------------------------------------------------------- continuous engine

class ContinuousEngine:
    """Continuous-serving engine: drives the executor THROUGH the chunk-level
    scheduler (``sched.ChunkScheduler``) so the pipeline never drains between
    requests — the next request's chunk 0 enters stage 0 the moment the
    previous request's tail chunk vacates it.

    - ``SimExecutor``: makespans come from the scheduler's true overlapped
      schedule (the shared ``sim.engine.schedule_request`` list-scheduling
      core) — NOT the batch-synchronous per-request serialization; the
      executor's per-stage straggler factors fold in via ``stage_scale``.
    - ``JaxExecutor``: requests execute as chunk-interleaved token batches in
      scheduler admission order — consecutive same-bucket admissions are
      stacked (up to ``max_batch``) so every pipeline tick carries one chunk
      from each request in the wave, and a newly arrived request joins the
      next wave instead of waiting for the whole queue to drain.

    Admission is policy-ordered (``EngineConfig.policy``: fcfs | sjf | edf)
    and gated by the ``KVLeaseManager``, whose per-stage budget is the MBKR
    slot pool provisioned for ``EngineConfig.inflight`` concurrent requests
    (clamped to physical KV capacity). ``EngineConfig.slo`` (seconds), when
    set, stamps each submitted request's deadline = arrival + slo; EDF
    orders by it and metrics report attainment.

    The engine IS a ``CellHandle``: the fleet router and serve driver talk
    to it only through that protocol. The legacy ``policy``/``slo``/
    ``inflight``/``trace`` constructor kwargs are DEPRECATED — set the
    same-named ``EngineConfig`` fields instead (cells need declarative,
    config-only construction); passing one still works but warns.
    """

    def __init__(self, ec: EngineConfig, executor, *,
                 policy: Optional[str] = None, slo: Optional[float] = None,
                 inflight: Optional[int] = None,
                 trace: Optional[bool] = None):
        from repro.sched import (ChunkPlan, ChunkScheduler, KVLeaseManager,
                                 TraceRecorder, slot_budget_bytes)
        legacy = {k: v for k, v in dict(policy=policy, slo=slo,
                                        inflight=inflight,
                                        trace=trace).items() if v is not None}
        if legacy:
            warnings.warn(
                f"ContinuousEngine({', '.join(sorted(legacy))}=...) kwargs "
                "are deprecated; set the same-named EngineConfig fields "
                "instead (engines are constructible from config alone)",
                DeprecationWarning, stacklevel=2)
            ec = dc_replace(ec, **legacy)
        self.ec = ec
        self.executor = executor
        self.slo = ec.slo
        self.draining = False
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._polled = 0          # self.done prefix already handed to poll()
        self._consumed = 0        # scheduler.admitted prefix already drained
        self._plan_cls = ChunkPlan
        self._plans: Dict[int, Any] = {}
        self._mplans: Dict[int, Any] = {}      # bucket -> MBKR plan
        self._pplans: Dict[Tuple[int, int], Any] = {}  # (bucket, k) plans
        self._sm = cm.StageModel.build(ec.model, ec.num_stages, ec.tp)

        # MBKR slot budget for `inflight` concurrent requests, <= capacity
        mplan = mbkr.plan(ec.num_chunks, ec.num_stages, mbkr=ec.mbkr)
        cmax = -(-max(ec.buckets) // ec.num_chunks)
        weights = ec.model.param_count() * 2 / (ec.num_stages * max(ec.tp, 1))
        capacity = max(ec.hw.hbm_cap - weights, 0.0) * max(ec.tp, 1)
        budget = slot_budget_bytes(
            max(ec.inflight, 1) * mplan.num_slots,
            max(cm.kv_chunk_bytes(self._sm, cmax), 1.0),
            ec.num_stages, capacity=capacity if capacity > 0 else None)
        self.lease = KVLeaseManager(ec.num_stages, budget)
        self.trace = TraceRecorder(enabled=ec.trace)
        scale = (executor.stage_scale(ec.num_stages)
                 if hasattr(executor, "stage_scale") else None)
        # leases count the page store's STORED bytes (quantized kv_dtype
        # shrinks every resident byte -> more concurrent admissions fit the
        # same physical slot budget)
        from repro.kvstore import quant as kvq
        codec = kvq.get_codec(ec.kv_dtype, ec.model.dtype)
        kv_compress = kvq.kv_compress_factor(
            codec, model_dtype=ec.model.dtype,
            page_tokens=ec.kv_page_tokens or cmax,
            head_dim=ec.model.resolved_head_dim)
        # radix prefix index (kvstore.prefix): page geometry from the
        # LARGEST bucket's chunk — per-bucket plans with smaller chunks
        # clamp their shared-page subtraction in chunk_page_bytes
        self.prefix_cache = None
        if ec.prefix_cache == "on":
            from repro.kvstore.prefix import PrefixPageCache
            pt = ec.kv_page_tokens or cmax
            ppc = max(-(-cmax // pt), 1)
            self.prefix_cache = PrefixPageCache(
                pages_per_chunk=ppc,
                page_bytes=max(cm.kv_chunk_bytes(self._sm, cmax), 1.0)
                * kv_compress / ppc)
            if hasattr(executor, "prefix_enabled"):
                executor.prefix_enabled = True   # arm the device seed cache
        self.scheduler = ChunkScheduler(
            ec.num_stages, self._chunk_plan, policy=ec.policy, lease=self.lease,
            trace=self.trace, compress=ec.compress, kv_compress=kv_compress,
            stage_scale=scale, page_tokens=ec.kv_page_tokens,
            prefix_cache=self.prefix_cache,
            prefix_min_pages=ec.prefix_min_pages,
            plan_for_prefix=self._chunk_plan_prefix)

    # ---------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        if self.draining:
            raise RuntimeError(
                "cell is draining: admission is closed (route the request "
                "to another cell — the fleet router skips draining cells)")
        req.bucket = bucket_of(self.ec.buckets, req.seq_len)
        if self.slo is not None and not math.isfinite(req.deadline):
            req.deadline = req.arrival + self.slo
        if (self.prefix_cache is not None and not req.prefix_hashes
                and req.tokens is not None):
            from repro.kvstore.prefix import chunk_hashes
            req.prefix_hashes = chunk_hashes(
                np.asarray(req.tokens)[: req.seq_len],
                self._chunk_plan(req.bucket).chunks)
        self.queue.append(req)

    def _chunk_plan(self, bucket: int):
        """Per-bucket LBCP chunk plan + analytic cost vectors (cached).
        For the jax executor the analytic costs order/gate admission only —
        execution timing is real."""
        if bucket not in self._plans:
            ec = self.ec
            if ec.partition == "lbcp":
                pp = lbcp.plan_partition(
                    ec.model, bucket, ec.num_chunks, ec.num_stages, ec.hw,
                    tp=ec.tp, mbkr=ec.mbkr, compress=ec.compress,
                    sa_iters=ec.sa_iters)
                chunks, mplan = pp.chunks, pp.mbkr_plan
            else:
                chunks = lbcp.uniform_partition(bucket, ec.num_chunks)
                mplan = (mbkr.plan(ec.num_chunks, ec.num_stages)
                         if ec.mbkr and not ec.model.attn_free else None)
            self._mplans[bucket] = mplan
            self._plans[bucket] = self._plan_cls.build(
                bucket, chunks, self._sm, ec.hw, mbkr_plan=mplan,
                compress=ec.compress)
        return self._plans[bucket]

    def _chunk_plan_prefix(self, bucket: int, k: int):
        """The bucket's plan re-priced for a resident prefix of ``k``
        chunks (``costmodel.chunk_cost_arrays(prefix_hit_chunks=k)``):
        zero compute/wire rows for served chunks, same chunk partition."""
        if k <= 0:
            return self._chunk_plan(bucket)
        key = (bucket, int(k))
        if key not in self._pplans:
            base = self._chunk_plan(bucket)    # populates _mplans[bucket]
            self._pplans[key] = self._plan_cls.build(
                bucket, list(base.chunks), self._sm, self.ec.hw,
                mbkr_plan=self._mplans.get(bucket), compress=self.ec.compress,
                prefix_hit_chunks=int(k))
        return self._pplans[key]

    # ---------------------------------------------------------- main loop
    def run_until_drained(self) -> None:
        from repro.sched import SchedRequest
        for r in self.queue:
            if r.state != "queued":
                continue
            self.scheduler.submit(SchedRequest(
                rid=r.rid, arrival=r.arrival, seq_len=r.seq_len,
                bucket=r.bucket, deadline=r.deadline, payload=r,
                prefix_hashes=tuple(r.prefix_hashes)))
        # scheduler.admitted is cumulative across calls — only drain the new
        # suffix so run_until_drained stays re-entrant (submit/drain cycles)
        order = self.scheduler.run()[self._consumed:]
        self._consumed += len(order)
        for sr in order:
            req: Request = sr.payload
            req.state = "done"
            req.finish_time = sr.finish_time
            self.queue.remove(req)
            self.done.append(req)
        for sr in self.scheduler.requests:
            if sr.state == "rejected" and sr.payload in self.queue:
                sr.payload.state = "rejected"
                self.queue.remove(sr.payload)
        if not isinstance(self.executor, SimExecutor):
            self._execute_real(order)

    # ------------------------------------------------- cell-handle surface
    def poll(self) -> List[Request]:
        """Requests completed since the last ``poll`` (admission order)."""
        new = self.done[self._polled:]
        self._polled = len(self.done)
        return list(new)

    def drain(self) -> List[Request]:
        """Stop admission PERMANENTLY and complete all in-flight work: the
        queue runs dry through the scheduler, committed KV leases expire as
        their requests finish, and any ``submit`` after this raises. Returns
        the requests completed by the drain (the un-polled suffix)."""
        self.draining = True
        self.run_until_drained()
        return self.poll()

    def queue_depth(self) -> int:
        """Requests submitted or admitted but not yet finished at the cell's
        current head-of-pipeline time — the least-loaded router signal."""
        now = float(self.scheduler.stage_free[0])
        live = sum(1 for sr in self.scheduler.admitted
                   if sr.finish_time > now)
        return live + sum(1 for r in self.queue if r.state == "queued")

    def free_lease_bytes(self) -> float:
        """Tightest per-stage KV-lease headroom (``KVLeaseManager.headroom``)
        from the cell's current head time on — bytes a new request's lease
        could still claim on the most-contended stage."""
        now = float(self.scheduler.stage_free[0])
        return float(self.lease.headroom(after=now).min())

    def estimate_admission(self, seq_len: int, arrival: float = 0.0,
                           prefix_hashes: Optional[Sequence[int]] = None
                           ) -> Tuple[float, bool]:
        """(predicted finish time, lease-fits-now) for a hypothetical
        request — ``ChunkScheduler.preview`` against the live frontier with
        this cell's OWN chunk-cost vectors (per-cell calibrated profiles and
        kv_dtype lease pricing both fold in automatically). Pure.
        ``prefix_hashes`` folds the radix index into the quote: a cell
        already holding the prefix quotes an earlier ETA and a smaller
        lease (the fleet's prefix-affinity signal)."""
        bucket = bucket_of(self.ec.buckets, seq_len)
        return self.scheduler.preview(
            bucket, seq_len, release=arrival,
            prefix_hashes=tuple(prefix_hashes or ()))

    def prefix_stats(self) -> Dict[str, Any]:
        """Radix-index counters (``PrefixPageCache.stats``); {} when the
        prefix cache is off."""
        return self.scheduler.prefix_stats()

    def prefix_hit_pages(self, prefix_hashes: Sequence[int]) -> int:
        """Pages of ``prefix_hashes`` already resident in this cell's radix
        index — the router's prefix-affinity tiebreak signal. 0 when off."""
        if self.prefix_cache is None or not prefix_hashes:
            return 0
        return int(self.prefix_cache.hit_pages(tuple(prefix_hashes)))

    def records(self) -> List[Any]:
        """Per-request ``RequestRecord`` rows (sched.metrics) — the fleet
        summary / SLO attainment input."""
        return list(self.scheduler.metrics.records)

    def configure_obs(self, *, telemetry: Optional[bool] = None,
                      measured: Optional[bool] = None,
                      health: Any = None) -> None:
        """Arm executor-side observability WITHOUT poking the executor from
        outside (the protocol seam): device telemetry (``return_telemetry``),
        measured tick spans (``collect_measured``) and a health monitor.
        Flags an executor does not support are ignored (SimExecutor IS the
        analytic model — there is nothing to measure)."""
        ex = self.executor
        if telemetry is not None and hasattr(ex, "collect_telemetry"):
            ex.collect_telemetry = bool(telemetry)
        if measured is not None and hasattr(ex, "collect_measured"):
            ex.collect_measured = bool(measured)
        if health is not None:
            ex.health = health

    def measured_waves(self) -> List[Dict[str, Any]]:
        """Executor waves that carry a measured per-(stage, tick) span array
        (``configure_obs(measured=True)``) — the calibration input."""
        return [w for w in getattr(self.executor, "waves", [])
                if w.get("measured") is not None]

    def _execute_real(self, order) -> None:
        """Chunk-interleaved token batches: stack consecutive same-bucket
        admissions up to max_batch and run each wave through the executor."""
        i = 0
        while i < len(order):
            bucket = order[i].bucket
            wave = [order[i]]
            i += 1
            while (i < len(order) and order[i].bucket == bucket
                   and len(wave) < self.ec.max_batch):
                wave.append(order[i])
                i += 1
            chunks = list(self._chunk_plan(bucket).chunks)
            self.executor.run([sr.payload for sr in wave], chunks,
                              self.ec.num_stages, self.ec.tp)

    # -------------------------------------------------------- calibration
    def recalibrate(self, hw: cm.ProfileSpec) -> cm.HardwareProfile:
        """Swap the engine onto a CALIBRATED profile (a ``HardwareProfile``,
        a registered name, or a path written by
        ``obs.calibrate.save_profile``): replaces ``EngineConfig.hw``, drops
        the cached bucket plans, and rebases the scheduler's admission costs
        via ``ChunkScheduler.rebase_costs`` — already-admitted requests keep
        their schedule; only future candidates see measured rates. A
        ``SimExecutor`` also re-prices execution."""
        hw = cm.resolve_profile(hw)
        self.ec = dc_replace(self.ec, hw=hw)
        self._sm = cm.StageModel.build(self.ec.model, self.ec.num_stages,
                                       self.ec.tp)
        self._plans.clear()
        self._mplans.clear()
        self._pplans.clear()
        self.scheduler.rebase_costs(self._chunk_plan)
        if isinstance(self.executor, SimExecutor):
            self.executor.hw = hw
        return hw

    # ----------------------------------------------------------- metrics
    @property
    def clock(self) -> float:
        return self.scheduler.metrics.makespan

    def metrics(self) -> Dict[str, float]:
        return self.scheduler.summary()

    # ------------------------------------------------------ observability
    def merged_trace(self):
        """ONE Perfetto trace merging every surface of this run:

        - scheduler task intervals + request lifecycle marks (pid = stage,
          tid = request; the scheduler's virtual clock),
        - per-stage ``kv_lease_bytes`` counter tracks replayed from the
          lease manager's admission timeline (virtual clock),
        - per-stage ``wire_bytes`` counter tracks: sim runs price each
          spilled chunk (index >= p2) from the bucket plan's KV bytes;
          jax runs with ``executor.collect_telemetry`` price the device
          event counts with the analytic per-event wire bytes,
        - engine wave spans + per-(stage, tick) device spans and
          ``kv_resident_bytes`` tracks from JaxExecutor telemetry waves
          (wall clock since executor construction, pid = "engine"),
        - MEASURED per-(stage, tick) wall-clock spans (``wave["measured"]``
          from ``collect_measured``) on their own ``measured`` process row
          next to the analytic tracks,
        - health-sentinel alerts (``executor.health``) on a ``health``
          process row.

        Pure: builds a fresh recorder each call; safe to export repeatedly.
        """
        from repro.obs.trace import TraceRecorder
        rec = TraceRecorder(enabled=True)
        rec.tasks = list(self.trace.tasks)
        rec.marks = list(self.trace.marks)
        # lease residency per stage (virtual clock)
        for s, timeline in enumerate(self.lease._timeline):
            level = 0.0
            for t, delta in sorted(timeline):
                level += delta
                rec.counter("kv_lease_bytes", pid=s, time=t,
                            values={"bytes": level})
        # sim wire model: a chunk with index >= p2 was spilled at creation
        buckets = {sr.rid: sr.bucket for sr in self.scheduler.requests}
        wire_acc: Dict[int, float] = {}
        for ev in sorted(self.trace.tasks, key=lambda e: e.finish):
            plan = self._chunk_plan(buckets.get(ev.rid, max(self.ec.buckets)))
            if ev.chunk >= plan.p2:
                lvl = wire_acc.get(ev.stage, 0.0) + float(plan.kvb[ev.chunk])
                wire_acc[ev.stage] = lvl
                rec.counter("wire_bytes", pid=ev.stage, time=ev.finish,
                            values={"bytes": lvl})
        # engine waves (wall clock) + device telemetry
        waves = getattr(self.executor, "waves", None) or []
        if waves:
            rec.process_name("engine", "engine (wall clock)")
        for wi, w in enumerate(waves):
            rec.span(f"wave{wi} seq{w['seq']} b{len(w['rids'])}",
                     pid="engine", tid=0, start=w["start"],
                     finish=w["start"] + w["dur"], cat="wave",
                     args={"rids": w["rids"], "chunks": w["chunks"]})
            tel = w.get("telemetry")
            if tel is None:
                continue
            pe = w.get("per_event_wire", {})
            n_st, ticks = tel["own_chunks"].shape
            tick_dur = w["dur"] / max(ticks, 1)
            kv, occ = tel["kv_bytes"], tel["own_chunks"] + tel["hosted_chunks"]
            wire = (tel["spill_events"] * pe.get("spill", 0.0)
                    + tel["fetch_events"] * pe.get("fetch", 0.0)
                    + tel["qship_events"] * pe.get("qship", 0.0))
            for s in range(n_st):
                for t in range(ticks):
                    ts = w["start"] + t * tick_dur
                    phase = t - s
                    if 0 <= phase < len(w["chunks"]):
                        rec.span(f"tick{t} c{phase}", pid="engine",
                                 tid=s + 1, start=ts, finish=ts + tick_dur,
                                 cat="tick",
                                 args={"stage": s, "chunk": phase,
                                       "occupancy": float(occ[s, t])})
                    rec.counter("kv_resident_bytes", pid=s, time=ts,
                                values={f"w{wi}": float(kv[s, t])})
                    rec.counter("device_wire_bytes", pid=s, time=ts,
                                values={f"w{wi}": float(wire[s, t])})
        # measured wall-clock spans: one process row, one thread per stage;
        # per-stage span starts are the cumulative measured tick durations
        if any(w.get("measured") is not None for w in waves):
            rec.process_name("measured", "measured spans (wall clock)")
        for wi, w in enumerate(waves):
            ms = w.get("measured")
            if ms is None:
                continue
            for s in range(ms.shape[0]):
                cursor = w["start"]
                for t in range(ms.shape[1]):
                    d = float(ms[s, t])
                    phase = t - s
                    if 0 <= phase < len(w["chunks"]) and d > 0:
                        rec.span(f"tick{t} c{phase}", pid="measured", tid=s,
                                 start=cursor, finish=cursor + d,
                                 cat="measured",
                                 args={"stage": s, "chunk": phase,
                                       "wave": wi})
                    cursor += d
        health = getattr(self.executor, "health", None)
        if health is not None:
            health.to_trace(rec)
        return rec

    def export_obs(self, trace_out: Optional[str] = None,
                   metrics_out: Optional[str] = None,
                   extra: Optional[Dict[str, float]] = None,
                   health=None) -> Dict[str, str]:
        """Export the merged trace and/or the metrics summary (both atomic);
        returns {"trace": path, "metrics": path} for whichever was asked.
        ``health`` (default: the executor's attached monitor) adds the
        per-kind alert counters and burn-rate gauge to the metrics."""
        paths: Dict[str, str] = {}
        if health is None:
            health = getattr(self.executor, "health", None)
        if trace_out:
            paths["trace"] = self.merged_trace().export(trace_out)
        if metrics_out:
            from repro.obs.metrics import export_engine_metrics
            paths["metrics"] = export_engine_metrics(
                metrics_out, self.metrics(),
                records=self.scheduler.metrics.records, extra=extra,
                health=health)
        return paths
