"""Sharded, atomic, elastic checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json    tree structure, shapes, dtypes, sha256 per leaf
           <leaf-key>.npy   one file per pytree leaf (host-gathered)

- ATOMIC: written to ``step_<N>.tmp`` then ``os.replace``d — a crash mid-save
  never corrupts the latest checkpoint (restart resumes from the previous one).
- ELASTIC: restore takes target shardings, so a checkpoint written on one mesh
  restores onto any other (different device count / axis sizes) — the basis of
  the N -> N-1 stage failover in the serving engine.
- Integrity: sha256 per leaf, verified on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any], structure: Any) -> Any:
    if isinstance(structure, dict):
        if "__leaf__" in structure:
            return flat[structure["__leaf__"]]
        return {k: _unflatten(flat, v) for k, v in structure.items()}
    raise ValueError(f"bad manifest node: {structure}")


def _structure_of(tree: Any, prefix: str = "") -> Any:
    if isinstance(tree, dict):
        return {k: _structure_of(tree[k], f"{prefix}{k}{SEP}") for k in sorted(tree)}
    if isinstance(tree, (list, tuple)):
        return {str(i): _structure_of(v, f"{prefix}{i}{SEP}")
                for i, v in enumerate(tree)}
    return {"__leaf__": prefix[:-1]}


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Host-gather every leaf and write atomically. Returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest: Dict[str, Any] = {
        "step": step,
        "structure": _structure_of(tree),
        "leaves": {},
        "extra": extra or {},
    }
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
            # ml_dtypes (bfloat16, float8_*) are not numpy-native: store the
            # raw bits and reconstruct from the manifest's dtype string
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        fname = key.replace(SEP, "__") + ".npy"
        path = os.path.join(tmp, fname)
        np.save(path, arr)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": logical_dtype,
            "sha256": digest,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int] = None, *,
                       shardings: Any = None, verify: bool = True):
    """Load (tree, extra). ``shardings``: optional pytree of NamedSharding /
    None matching the saved tree — leaves are device_put to them (elastic
    re-shard onto the CURRENT mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shard_flat = _flatten(shardings) if shardings is not None else {}
    flat = {}
    for key, meta in manifest["leaves"].items():
        fpath = os.path.join(path, meta["file"])
        if verify:
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checksum mismatch for {key} in {path}")
        arr = np.load(fpath)
        want = meta["dtype"]
        if str(arr.dtype) != want:  # ml_dtypes round-trip via raw bits
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, want, want))
        sh = shard_flat.get(key)
        flat[key] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
    tree = _unflatten(flat, manifest["structure"])
    return tree, manifest.get("extra", {})
