"""JAX API-drift shims so the repo runs on both current and older jax.

- ``shard_map``: jax >= 0.6 exposes ``jax.shard_map(..., axis_names=...,
  check_vma=...)``; older releases have ``jax.experimental.shard_map`` with
  the complementary ``auto``/``check_rep`` spelling. One entry point maps
  between them (axis_names -> auto = mesh axes minus manual; check_vma ->
  check_rep).
"""
from __future__ import annotations

from typing import Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[frozenset] = None,
              check_vma: Optional[bool] = None):
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    if check_vma is not None:
        kw["check_rep"] = check_vma
    mapped = _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    def with_mesh_ctx(*args):
        # old jax resolves PartitionSpec-based with_sharding_constraint
        # inside the body against the ambient mesh context
        with mesh:
            return mapped(*args)
    return with_mesh_ctx
