"""JAX API-drift shims so the repo runs on both current and older jax.

- ``shard_map``: jax >= 0.6 exposes ``jax.shard_map(..., axis_names=...,
  check_vma=...)``; older releases have ``jax.experimental.shard_map`` with
  the complementary ``auto``/``check_rep`` spelling. One entry point maps
  between them (axis_names -> auto = mesh axes minus manual; check_vma ->
  check_rep).
- ``AxisType``: jax >= 0.5 types mesh axes explicitly
  (``jax.sharding.AxisType.{Auto,Explicit,Manual}``); older meshes are
  implicitly Auto. The shim exposes the real enum when present and a
  placeholder otherwise so call sites can always say ``AxisType.Auto``.
- ``make_mesh``: forwards ``axis_types`` only when the installed jax
  understands it.
- ``set_mesh``: jax >= 0.6 ``jax.set_mesh`` context manager; older jax uses
  the mesh object itself as the context (``with mesh:``).
"""
from __future__ import annotations

import enum
import inspect
from typing import Optional

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # noqa: F401  (re-export)
except ImportError:  # older jax: every axis is implicitly Auto
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def axis_types_kw(n: int) -> dict:
    """``{"axis_types": (Auto,)*n}`` when the installed jax supports it."""
    if _MAKE_MESH_TAKES_AXIS_TYPES:
        return {"axis_types": (AxisType.Auto,) * n}
    return {}


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
    """``jax.make_mesh`` that drops ``axis_types`` on jax builds predating it."""
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        kw["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def set_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh or ``with mesh:``)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # old jax: Mesh is its own context manager


def ensure_host_devices(n: int = 8) -> None:
    """Give a bare CPU host ``n`` fake host-platform devices (the chunked
    pipeline needs >= 2). No-op when the flag is already set or real
    accelerators exist — the flag only affects the host platform. Must run
    before the first jax backend use (device queries, array ops); importing
    jax is fine."""
    import os
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def resolve_tp_lowering(requested: str = "auto") -> str:
    """Resolve ``RunConfig.tp_lowering`` against the installed jaxlib.

    "manual" is always honored. "auto" means GSPMD partial-auto SPMD when
    the jaxlib can partition it inside shard_map, falling back to the
    fully-manual lowering (explicit psums in the stage programs, all mesh
    axes manual) on old jaxlib — which is what restores TP > 1 coverage
    there (the old ``max_auto_tp`` tp=1 fallback is gone). The
    ``REPRO_TP_LOWERING`` env var overrides the "auto" resolution (the CI
    matrix uses it to pin the manual path on the old-jaxlib leg).
    """
    if requested == "manual":
        return "manual"
    if requested not in ("auto", "", None):
        raise ValueError(f"unknown tp_lowering {requested!r}; "
                         "choose 'auto' or 'manual'")
    import os
    env = os.environ.get("REPRO_TP_LOWERING")
    if env in ("auto", "manual"):
        return env
    return "auto" if supports_partial_auto_spmd() else "manual"


def supports_partial_auto_spmd() -> bool:
    """True when shard_map over a SUBSET of mesh axes (manual stage axis,
    GSPMD-auto TP axis of size > 1) can be partitioned by the installed
    jaxlib. Old jaxlib rejects the lowering with "UNIMPLEMENTED: PartitionId
    instruction is not supported for SPMD partitioning", so pipeline runs
    there must keep every non-manual axis at size 1 (tp = 1). The
    ``jax.shard_map`` attribute doubles as the capability marker: it appeared
    alongside the partitioner fix.
    """
    return hasattr(jax, "shard_map")


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[frozenset] = None,
              check_vma: Optional[bool] = None):
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    if check_vma is not None:
        kw["check_rep"] = check_vma
    mapped = _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    def with_mesh_ctx(*args):
        # old jax resolves PartitionSpec-based with_sharding_constraint
        # inside the body against the ambient mesh context
        with mesh:
            return mapped(*args)
    return with_mesh_ctx
