"""Multi-cell serving fabric: a fleet router over replicated pipeline cells.

One shared arrival stream fans out over N independent serving cells (each a
``runtime.CellHandle`` — canonically a ``ContinuousEngine``), placed by a
router policy:

- ``jsf``  — join-shortest-finish: admit where ``estimate_admission``
  predicts the earliest finish (per-cell LBCP chunk costs, calibrated
  profiles and KV-lease headroom all fold into the quote),
- ``least-loaded`` — smallest ``queue_depth``,
- ``rr``   — round-robin (the baseline the bench gates against).

Cells are heterogeneous (each its own EngineConfig: buckets, kv_dtype,
calibrated profile, pool backend) and dynamic: ``FleetFabric.drain_cell``
closes admission and completes in-flight work; ``resize`` adds/removes
cells mid-stream. The fabric only ever touches cells through the
``CellHandle`` protocol (source-scan enforced by ``tests/test_fleet.py``).
"""
from repro.fleet.placement import CellSignals, ROUTER_POLICIES, score_cells
from repro.fleet.router import FleetRouter, PlacementDecision
from repro.fleet.fabric import FleetFabric
