"""Fleet fabric: owns the cells, drives placement, aggregates observability.

The fabric is the mutation side of the fleet split: the router DECIDES
(pure), the fabric ACTS — submit to the chosen cell, pump it so the
scheduler frontier advances (placement signals are only as fresh as the
last ``run_until_drained``), collect completions, retire drained cells.
All cell access goes through the ``CellHandle`` protocol; the fabric never
reaches into a cell's scheduler/lease/executor internals.

Elasticity:
- ``drain_cell(name)`` closes that cell's admission, completes its
  in-flight work and RETIRES it — the handle moves to ``self.retired`` so
  its request records and trace stay in the fleet roll-up, but the router
  never sees it again.
- ``add_cell(name, cell)`` grows the fleet mid-stream (``launch.cells``
  enumerates per-cell meshes for real executors; sim cells are just more
  engines).
- ``resize(names, factory)`` reconciles toward a target cell set: missing
  names are built by the factory, surplus cells are drained.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence)

from repro.fleet.router import FleetRouter, PlacementDecision
from repro.sched.metrics import fleet_summary


class FleetFabric:

    def __init__(self, cells: Mapping[str, Any],
                 router: Optional[FleetRouter] = None):
        self.cells: Dict[str, Any] = dict(cells)
        self.router = router or FleetRouter()
        self.retired: Dict[str, Any] = {}
        self.completed: List[Any] = []
        self.placements: Dict[int, str] = {}    # rid -> cell name

    # ------------------------------------------------------------ admission
    def submit(self, req: Any, pump: bool = True) -> PlacementDecision:
        """Route one request to a cell and (by default) pump that cell so
        the NEXT placement scores against its post-admission frontier.

        A ``rejected`` decision (every live cell's KV-lease headroom
        exhausted) submits NOTHING — the caller reads ``dec.retry_after``
        and resubmits; the rejection is counted into ``fleet_summary``."""
        dec = self.router.place(
            self.cells, req.rid, req.seq_len, arrival=req.arrival,
            prefix_hashes=getattr(req, "prefix_hashes", None))
        if dec.rejected:
            return dec
        cell = self.cells[dec.cell]
        cell.submit(req)
        self.placements[req.rid] = dec.cell
        if pump:
            cell.run_until_drained()
        return dec

    def pump(self) -> List[Any]:
        """Run every live cell dry and collect newly completed requests."""
        out: List[Any] = []
        for cell in self.cells.values():
            cell.run_until_drained()
            out.extend(cell.poll())
        self.completed.extend(out)
        return out

    # ----------------------------------------------------------- elasticity
    def drain_cell(self, name: str) -> List[Any]:
        """Close ``name``'s admission, finish its in-flight requests, retire
        it from routing. Returns the requests the drain completed."""
        cell = self.cells.pop(name)
        done = cell.drain()
        self.completed.extend(done)
        self.retired[name] = cell
        return done

    def add_cell(self, name: str, cell: Any) -> None:
        if name in self.cells or name in self.retired:
            raise ValueError(f"cell name {name!r} already used")
        self.cells[name] = cell

    def resize(self, names: Sequence[str],
               factory: Callable[[str], Any]) -> None:
        """Reconcile the live cell set toward ``names``: build missing cells
        with ``factory(name)``, drain cells not in the target set."""
        target = list(names)
        for name in [n for n in self.cells if n not in target]:
            self.drain_cell(name)
        for name in target:
            if name not in self.cells and name not in self.retired:
                self.add_cell(name, factory(name))

    def drain_all(self) -> List[Any]:
        out: List[Any] = []
        for name in list(self.cells):
            out.extend(self.drain_cell(name))
        return out

    # -------------------------------------------------------------- metrics
    def _all_cells(self) -> Dict[str, Any]:
        return {**self.cells, **self.retired}

    def metrics(self) -> Dict[str, Any]:
        """Fleet-level SLO/TTFT roll-up over every cell ever part of the
        fleet (live + retired) — ``sched.metrics.fleet_summary`` — plus the
        router's reject-with-retry-after count."""
        return fleet_summary({name: cell.records()
                              for name, cell in self._all_cells().items()},
                             router_rejections=self.router.rejections)

    def configure_obs(self, *, telemetry: Optional[bool] = None,
                      measured: Optional[bool] = None,
                      health: Any = None) -> None:
        for cell in self.cells.values():
            cell.configure_obs(telemetry=telemetry, measured=measured,
                               health=health)

    def recalibrate(self, name: str, hw: Any) -> Any:
        """Swap ONE cell onto a calibrated profile (per-cell calibration is
        the point — heterogeneous fleets quote heterogeneous ETAs)."""
        return self._all_cells()[name].recalibrate(hw)

    # ------------------------------------------------------------ tracing
    def merged_trace(self):
        """ONE Perfetto timeline for the whole fleet: each cell's merged
        trace absorbed under its own ``{name}/`` process namespace
        (``TraceRecorder.absorb``), so ``cell0/stage 3`` and
        ``cell1/engine`` render as separate process rows."""
        from repro.obs.trace import TraceRecorder
        rec = TraceRecorder(enabled=True)
        for name, cell in self._all_cells().items():
            rec.absorb(cell.merged_trace(), pid_prefix=f"{name}/")
        return rec

    def export_obs(self, trace_out: Optional[str] = None,
                   metrics_out: Optional[str] = None) -> Dict[str, str]:
        paths: Dict[str, str] = {}
        if trace_out:
            paths["trace"] = self.merged_trace().export(trace_out)
        if metrics_out:
            from repro.obs._io import atomic_write_text
            import json
            paths["metrics"] = atomic_write_text(
                metrics_out, json.dumps(self.metrics(), default=float,
                                        indent=2))
        return paths
