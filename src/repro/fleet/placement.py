"""Placement signals and scoring for the fleet router.

Every policy reduces to: read one ``CellSignals`` snapshot per candidate
cell (through the ``CellHandle`` protocol only), score the candidates,
pick the minimum. Scores are (primary, tiebreak...) tuples so policies
stay deterministic under ties — ties always break toward the lower cell
index, which is what makes ``rr`` vs ``jsf`` comparisons reproducible.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class CellSignals:
    """One router-visible snapshot of a cell, taken at placement time.

    ``eta`` is the cell's own quote for the candidate request
    (``CellHandle.estimate_admission``): predicted finish time on ITS cost
    vectors, with a lease-wait penalty already folded in when the KV lease
    does not fit now. ``lease_fits`` says whether the quote required
    deferring behind an existing lease. ``free_lease_bytes`` is the
    tightest per-stage KV headroom; ``queue_depth`` the live request count.
    """
    name: str
    index: int
    eta: float
    lease_fits: bool
    free_lease_bytes: float
    queue_depth: int
    draining: bool = False


def snapshot(name: str, index: int, cell: Any, seq_len: int,
             arrival: float = 0.0) -> CellSignals:
    """Read a cell's placement signals through the CellHandle protocol."""
    eta, fits = cell.estimate_admission(seq_len, arrival=arrival)
    return CellSignals(
        name=name, index=index, eta=float(eta), lease_fits=bool(fits),
        free_lease_bytes=float(cell.free_lease_bytes()),
        queue_depth=int(cell.queue_depth()),
        draining=bool(cell.draining))


# ------------------------------------------------------------------ scoring

def _score_jsf(s: CellSignals) -> Tuple:
    # earliest predicted finish; prefer a cell whose lease fits NOW over an
    # equal-ETA cell that had to defer; then headroom, then index
    return (s.eta, 0 if s.lease_fits else 1,
            -s.free_lease_bytes, s.index)


def _score_least_loaded(s: CellSignals) -> Tuple:
    return (s.queue_depth, -s.free_lease_bytes, s.index)


ROUTER_POLICIES: Tuple[str, ...] = ("jsf", "rr", "least-loaded")

_SCORERS = {"jsf": _score_jsf, "least-loaded": _score_least_loaded}


def score_cells(policy: str, signals: Sequence[CellSignals]
                ) -> List[Tuple[Tuple, CellSignals]]:
    """(score, signals) per non-draining candidate, best (lowest) first.

    ``rr`` has no score — the router owns its rotation counter — so asking
    for it here is a programming error, as is an unknown policy.
    """
    if policy not in _SCORERS:
        raise ValueError(
            f"unknown scoring policy {policy!r}; expected one of "
            f"{sorted(_SCORERS)} (rr is handled by the router's rotation)")
    fn = _SCORERS[policy]
    live = [s for s in signals if not s.draining]
    return sorted(((fn(s), s) for s in live), key=lambda p: p[0])
