"""Placement signals and scoring for the fleet router.

Every policy reduces to: read one ``CellSignals`` snapshot per candidate
cell (through the ``CellHandle`` protocol only), score the candidates,
pick the minimum. Scores are (primary, tiebreak...) tuples so policies
stay deterministic under ties — ties always break toward the lower cell
index, which is what makes ``rr`` vs ``jsf`` comparisons reproducible.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class CellSignals:
    """One router-visible snapshot of a cell, taken at placement time.

    ``eta`` is the cell's own quote for the candidate request
    (``CellHandle.estimate_admission``): predicted finish time on ITS cost
    vectors, with a lease-wait penalty already folded in when the KV lease
    does not fit now. ``lease_fits`` says whether the quote required
    deferring behind an existing lease. ``free_lease_bytes`` is the
    tightest per-stage KV headroom; ``queue_depth`` the live request count.
    ``prefix_hit_pages`` is how many of the request's prefix pages this
    cell's radix index already holds (``CellHandle.prefix_hit_pages``) —
    the prefix-affinity signal; 0 when the cache is off or no hashes were
    passed.
    """
    name: str
    index: int
    eta: float
    lease_fits: bool
    free_lease_bytes: float
    queue_depth: int
    draining: bool = False
    prefix_hit_pages: int = 0


def snapshot(name: str, index: int, cell: Any, seq_len: int,
             arrival: float = 0.0,
             prefix_hashes: Optional[Sequence[int]] = None) -> CellSignals:
    """Read a cell's placement signals through the CellHandle protocol.
    ``prefix_hashes`` (the request's chunk-hash chain) folds the radix
    index into both the ETA quote and the affinity tiebreak; cells that
    predate the prefix signals are read as hit-free."""
    if prefix_hashes:
        eta, fits = cell.estimate_admission(seq_len, arrival=arrival,
                                            prefix_hashes=prefix_hashes)
        hit = int(cell.prefix_hit_pages(prefix_hashes)) \
            if hasattr(cell, "prefix_hit_pages") else 0
    else:
        eta, fits = cell.estimate_admission(seq_len, arrival=arrival)
        hit = 0
    return CellSignals(
        name=name, index=index, eta=float(eta), lease_fits=bool(fits),
        free_lease_bytes=float(cell.free_lease_bytes()),
        queue_depth=int(cell.queue_depth()),
        draining=bool(cell.draining), prefix_hit_pages=hit)


# ------------------------------------------------------------------ scoring

def _score_jsf(s: CellSignals) -> Tuple:
    # earliest predicted finish; prefer a cell whose lease fits NOW over an
    # equal-ETA cell that had to defer; then the cell already holding the
    # request's prefix (its radix hit also shrank the ETA quote — this
    # tiebreak settles equal-ETA cells); then headroom, then index
    return (s.eta, 0 if s.lease_fits else 1,
            -s.prefix_hit_pages, -s.free_lease_bytes, s.index)


def _score_least_loaded(s: CellSignals) -> Tuple:
    return (s.queue_depth, -s.free_lease_bytes, s.index)


ROUTER_POLICIES: Tuple[str, ...] = ("jsf", "rr", "least-loaded")

_SCORERS = {"jsf": _score_jsf, "least-loaded": _score_least_loaded}


def score_cells(policy: str, signals: Sequence[CellSignals]
                ) -> List[Tuple[Tuple, CellSignals]]:
    """(score, signals) per non-draining candidate, best (lowest) first.

    ``rr`` has no score — the router owns its rotation counter — so asking
    for it here is a programming error, as is an unknown policy.
    """
    if policy not in _SCORERS:
        raise ValueError(
            f"unknown scoring policy {policy!r}; expected one of "
            f"{sorted(_SCORERS)} (rr is handled by the router's rotation)")
    fn = _SCORERS[policy]
    live = [s for s in signals if not s.draining]
    return sorted(((fn(s), s) for s in live), key=lambda p: p[0])
