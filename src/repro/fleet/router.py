"""Fleet router: pick the serving cell for each arriving request.

The router is PURE POLICY — it never mutates a cell. It reads one
``CellSignals`` snapshot per candidate (through the ``CellHandle``
protocol) and returns a ``PlacementDecision``; the fabric does the actual
``submit`` and pumps the chosen cell so the next placement sees fresh
frontiers. Draining cells are never candidates under any policy.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.fleet.placement import (CellSignals, ROUTER_POLICIES, score_cells,
                                   snapshot)


@dataclass(frozen=True)
class PlacementDecision:
    rid: int
    cell: str
    policy: str
    eta: float                      # chosen cell's quoted finish (jsf) / nan
    signals: Tuple[CellSignals, ...]   # every candidate consulted
    # admission control: True when EVERY live cell's KV-lease headroom is
    # exhausted — the fabric must NOT submit; retry_after is the earliest
    # quoted instant a retry could land (min finite ETA across live cells)
    rejected: bool = False
    retry_after: float = math.inf


class FleetRouter:
    """Stateless scoring + one rotation counter (for ``rr``).

    ``place`` raises ``RuntimeError`` when every cell is draining — the
    fleet has stopped admitting; callers surface that as a rejection.
    """

    def __init__(self, policy: str = "jsf"):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; expected "
                             f"one of {list(ROUTER_POLICIES)}")
        self.policy = policy
        self.decisions: List[PlacementDecision] = []
        self.rejections = 0
        self._rr = 0

    def place(self, cells: Mapping[str, Any], rid: int, seq_len: int,
              arrival: float = 0.0,
              prefix_hashes: Optional[Sequence[int]] = None
              ) -> PlacementDecision:
        """Choose the cell for one request. ``cells`` maps name -> CellHandle
        in a stable order (insertion order drives rr rotation and
        tie-breaks). ``prefix_hashes`` arms the prefix-affinity signals.

        Admission control: when EVERY live cell's KV-lease headroom is
        exhausted the request is REJECTED (``rejected=True``) with an
        explicit ``retry_after`` — the earliest finite ETA any live cell
        quoted (i.e. the earliest instant a committed lease could release
        capacity) — instead of being queued behind a lease that may never
        clear."""
        sigs = tuple(snapshot(name, i, cell, seq_len, arrival,
                              prefix_hashes=prefix_hashes)
                     for i, (name, cell) in enumerate(cells.items()))
        live = [s for s in sigs if not s.draining]
        if not live:
            raise RuntimeError(
                "all fleet cells are draining: admission is closed")
        if all(s.free_lease_bytes <= 0.0 for s in live):
            etas = [s.eta for s in live if math.isfinite(s.eta)]
            dec = PlacementDecision(
                rid=rid, cell="", policy=self.policy, eta=math.inf,
                signals=sigs, rejected=True,
                retry_after=min(etas) if etas else math.inf)
            self.rejections += 1
            self.decisions.append(dec)
            return dec
        if self.policy == "rr":
            chosen = live[self._rr % len(live)]
            self._rr += 1
        else:
            chosen = score_cells(self.policy, sigs)[0][1]
        dec = PlacementDecision(rid=rid, cell=chosen.name, policy=self.policy,
                                eta=chosen.eta, signals=sigs)
        self.decisions.append(dec)
        return dec
