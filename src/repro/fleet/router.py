"""Fleet router: pick the serving cell for each arriving request.

The router is PURE POLICY — it never mutates a cell. It reads one
``CellSignals`` snapshot per candidate (through the ``CellHandle``
protocol) and returns a ``PlacementDecision``; the fabric does the actual
``submit`` and pumps the chosen cell so the next placement sees fresh
frontiers. Draining cells are never candidates under any policy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.fleet.placement import (CellSignals, ROUTER_POLICIES, score_cells,
                                   snapshot)


@dataclass(frozen=True)
class PlacementDecision:
    rid: int
    cell: str
    policy: str
    eta: float                      # chosen cell's quoted finish (jsf) / nan
    signals: Tuple[CellSignals, ...]   # every candidate consulted


class FleetRouter:
    """Stateless scoring + one rotation counter (for ``rr``).

    ``place`` raises ``RuntimeError`` when every cell is draining — the
    fleet has stopped admitting; callers surface that as a rejection.
    """

    def __init__(self, policy: str = "jsf"):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; expected "
                             f"one of {list(ROUTER_POLICIES)}")
        self.policy = policy
        self.decisions: List[PlacementDecision] = []
        self._rr = 0

    def place(self, cells: Mapping[str, Any], rid: int, seq_len: int,
              arrival: float = 0.0) -> PlacementDecision:
        """Choose the cell for one request. ``cells`` maps name -> CellHandle
        in a stable order (insertion order drives rr rotation and
        tie-breaks)."""
        sigs = tuple(snapshot(name, i, cell, seq_len, arrival)
                     for i, (name, cell) in enumerate(cells.items()))
        live = [s for s in sigs if not s.draining]
        if not live:
            raise RuntimeError(
                "all fleet cells are draining: admission is closed")
        if self.policy == "rr":
            chosen = live[self._rr % len(live)]
            self._rr += 1
        else:
            chosen = score_cells(self.policy, sigs)[0][1]
        dec = PlacementDecision(rid=rid, cell=chosen.name, policy=self.policy,
                                eta=chosen.eta, signals=sigs)
        self.decisions.append(dec)
        return dec
