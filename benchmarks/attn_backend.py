"""Attention-backend comparison: jnp reference vs pallas (interpret off-TPU).

Times the ``core.attention`` backends on the composite the pipeline hot loop
actually runs per (layer, chunk): the stored-prefix pool scan + the causal
self block + finish — with the pool scan in all three traversal orders:

- ``jnp``            per-slot jnp reference scan,
- ``pallas_scan``    per-slot kernel launches (one ``chunk_attention`` +
                     traced combine per occupied slot — the pre-batching
                     pallas path),
- ``pool_batched``   the fused slot-grid kernel (``ops.pool_attention``):
                     ONE launch per pool scan, O(1) in pool depth,
- ``pool_paged``     the ragged paged kernel (``ops.pool_attention_paged``):
                     ONE launch AND zero gather — pages read in place.

``launches_*`` count RUNTIME kernel launches of the pool part
(``ops.count_launches``): O(slots) -> O(1) is the point; the wall-time win
from amortized launch overhead needs real TPU (off-TPU the pallas numbers
are INTERPRET-mode — a correctness harness, expected slower than jnp on
CPU). Alongside wall time we report the analytic TPU-v5e roofline time for
the same flops/bytes, which is backend-independent, and the DETERMINISTIC
HBM cost of the gather copy the paged kernel deletes:
``hbm_gather_bytes`` (what the gathered slot-grid path writes+reads per
pool scan) vs ``hbm_gather_bytes_paged`` (pinned 0), plus the roofline
speedup ``paged_speedup`` that traffic delta buys — the compare.py gate
pins all three exactly.

Writes artifacts/bench/attn_backend.json. Usage:
  PYTHONPATH=src python -m benchmarks.attn_backend [--iters 3] [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import OUT_DIR, table
from repro.core import attention as A
from repro.kernels import ops
from repro.roofline.analysis import HW_V5E

# (b, c, kvh, g, d, n_pool_chunks): pipeline-shaped cases; --quick trims
CASES = [
    (1, 128, 2, 4, 64, 3),
    (1, 256, 4, 4, 128, 3),
    (2, 128, 8, 4, 128, 6),
]


def _wire_bytes(b, c, kvh, g, d, npool):
    """§3.4 per-(layer, tick) remote-traffic pricing of this case's
    geometry, fp32 wire (the bench tensors' dtype) — the same formulas the
    transport CollectiveLedger is pinned to at runtime within 1%
    (core.transport.analytic_wire_bytes / tests/test_transport.py):

      fetch  = n_remote chunk-layer payloads (2 * C * kvh * hd each),
      qship  = one Q ship + one (m, l) fp32 + acc return, n_remote-free.

    Deterministic byte counts -> exact directional gates in compare.py
    (remote traffic must never regress upward unnoticed)."""
    h = kvh * g
    fetch = npool * (2 * b * c * kvh * d) * 4.0
    qship = (b * c * h * d) * 4.0 + 2 * (b * h * c) * 4.0 \
        + (b * c * h * d) * 4.0
    return fetch, qship


def _pool_fns(kpool, vpool, scale):
    """The four pool-scan traversal orders under test, as (name, fn) with
    fn: (qg, state) -> state over the SAME stacked pool KV (the paged
    backend views the stack as identity-handle pages — zero copy)."""
    valid = jnp.ones(kpool.shape[0], bool)
    be_jnp = A.get_backend("jnp")
    be_pal = A.get_backend("pallas")
    be_paged = A.get_backend("paged")
    per_slot = A.PallasBackend()
    per_slot.batched_pool = False  # pool_block honors the flag
    return [
        ("jnp", lambda q, st: be_jnp.pool_block(
            q, kpool, vpool, None, None, valid, scale, st)),
        ("pallas_scan", lambda q, st: per_slot.pool_block(
            q, kpool, vpool, None, None, valid, scale, st)),
        ("pool_batched", lambda q, st: be_pal.pool_block(
            q, kpool, vpool, None, None, valid, scale, st)),
        ("pool_paged", lambda q, st: be_paged.pool_block(
            q, kpool, vpool, None, None, valid, scale, st)),
    ]


def _composite(pool_fn, self_be, qg, scale):
    b, c, kvh, g, d = qg.shape
    st = A.attn_init(b, c, kvh, g, d)
    st = pool_fn(qg, st)
    st = self_be.self_block(qg, qg[:, :, :, 0], qg[:, :, :, 0], scale, st)
    return A.attn_finish(st, jnp.float32)


def _time(fn, *args, iters: int) -> float:
    out = fn(*args)              # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(iters: int = 3, quick: bool = False) -> dict:
    cases = CASES[:1] if quick else CASES
    rows = []
    for (b, c, kvh, g, d, npool) in cases:
        ks = jax.random.split(jax.random.key(0), 3)
        qg = jax.random.normal(ks[0], (b, c, kvh, g, d), jnp.float32)
        kpool = jax.random.normal(ks[1], (npool, b, c, kvh, d), jnp.float32)
        vpool = jax.random.normal(ks[2], (npool, b, c, kvh, d), jnp.float32)
        scale = 1.0 / float(np.sqrt(d))
        h = kvh * g
        t_kv = (npool + 1) * c
        flops = 4.0 * b * c * t_kv * h * d
        bytes_ = 2.0 * (b * c * h * d * 2 + 2 * b * t_kv * kvh * d)  # bf16
        tpu_s = max(flops / HW_V5E["peak_flops"], bytes_ / HW_V5E["hbm_bw"])

        outs, times, launches = {}, {}, {}
        for name, pool_fn in _pool_fns(kpool, vpool, scale):
            self_be = A.get_backend("jnp" if name == "jnp" else "pallas")
            fn = jax.jit(lambda q, pf=pool_fn, sb=self_be:
                         _composite(pf, sb, q, scale))
            times[name] = _time(fn, qg, iters=iters)
            outs[name] = np.asarray(fn(qg))
            # pool-part launch count (the O(slots) -> O(1) claim), counted
            # at runtime on a pool-only closure
            pfn = jax.jit(lambda q, pf=pool_fn: pf(
                q, A.attn_init(b, c, kvh, g, d))[1])
            with ops.count_launches() as lc:
                pfn(qg).block_until_ready()
            launches[name] = lc["count"]
        parity = float(np.max(np.abs(outs["jnp"] - outs["pool_batched"])))
        parity_scan = float(np.max(np.abs(outs["pallas_scan"]
                                          - outs["pool_batched"])))
        parity_paged = float(np.max(np.abs(outs["pool_paged"]
                                           - outs["pool_batched"])))
        wire_fetch, wire_qship = _wire_bytes(b, c, kvh, g, d, npool)
        # HBM cost of the dense-slot-stack gather the paged kernel deletes:
        # the gathered path WRITES the [S, B, C, KVH, D] k/v stack then the
        # kernel reads it back; the paged kernel DMAs pages in place. Pool-
        # scan roofline with vs without that traffic = the deterministic
        # paged >= batched gate (wall clock off-TPU is interpret noise).
        gather_bytes = 2.0 * npool * b * c * kvh * d * 4.0  # k + v, fp32
        pool_flops = 4.0 * b * c * (npool * c) * h * d
        pool_bytes = (b * c * h * d * 4.0        # q read
                      + gather_bytes             # page reads (both paths)
                      + 2 * b * c * h * d * 4.0)  # state out
        roof = lambda extra: max(pool_flops / HW_V5E["peak_flops"],
                                 (pool_bytes + extra) / HW_V5E["hbm_bw"])
        paged_speedup = roof(2.0 * gather_bytes) / roof(0.0)
        rows.append({
            "shape": f"b{b} c{c} kv{kvh} g{g} d{d} pool{npool}",
            "jnp_ms": round(times["jnp"] * 1e3, 2),
            "pallas_scan_ms": round(times["pallas_scan"] * 1e3, 2),
            "pool_batched_ms": round(times["pool_batched"] * 1e3, 2),
            "pool_paged_ms": round(times["pool_paged"] * 1e3, 2),
            "parity_abs": f"{parity:.1e}",
            "launches_scan": launches["pallas_scan"],
            "launches_batched": launches["pool_batched"],
            "launches_paged": launches["pool_paged"],
            "hbm_gather_bytes": int(2 * gather_bytes),
            "hbm_gather_bytes_paged": 0,
            "paged_speedup": round(paged_speedup, 4),
            "wire_bytes_fetch": int(wire_fetch),
            "wire_bytes_qship": int(wire_qship),
            "tpu_roofline_us": round(tpu_s * 1e6, 1),
        })
        assert parity < 1e-4, f"backend divergence: {parity}"
        assert parity_scan < 1e-4, f"scan/batched divergence: {parity_scan}"
        assert parity_paged < 1e-4, f"paged/batched divergence: {parity_paged}"
        assert launches["pallas_scan"] == npool, launches
        assert launches["pool_batched"] == 1, launches  # O(1) in pool depth
        assert launches["pool_paged"] == 1, launches    # O(1) AND zero gather
        assert launches["jnp"] == 0, launches
        assert paged_speedup >= 1.0, paged_speedup

    result = {
        "device": str(jax.devices()[0].platform),
        "quick": quick,
        "note": ("pallas timings are interpret-mode off-TPU (correctness "
                 "harness, not a speed claim); launches_* count runtime "
                 "kernel launches of the pool scan (O(slots) vs O(1)); "
                 "tpu_roofline_us is the analytic v5e bound for the "
                 "composite"),
        "iters": iters,
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "attn_backend.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(table(rows, ["shape", "jnp_ms", "pallas_scan_ms", "pool_batched_ms",
                       "pool_paged_ms", "parity_abs", "launches_scan",
                       "launches_batched", "launches_paged",
                       "hbm_gather_bytes", "hbm_gather_bytes_paged",
                       "paged_speedup", "wire_bytes_fetch",
                       "wire_bytes_qship", "tpu_roofline_us"]))
    print(f"-> {path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(iters=a.iters, quick=a.quick)
