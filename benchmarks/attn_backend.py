"""Attention-backend comparison: jnp reference vs pallas (interpret off-TPU).

Times the two ``core.attention`` backends on the composite the pipeline hot
loop actually runs per (layer, chunk): pool chunk_blocks (the stored-prefix
scan) + the causal self block + finish. Off-TPU the pallas numbers are
INTERPRET-mode (a correctness harness, expected slower than jnp on CPU —
wall-clock wins need the Mosaic lowering on real TPU hardware); alongside
wall time we report the analytic TPU-v5e roofline time for the same
flops/bytes, which is backend-independent and is what the §Perf iterations
reason with.

Writes artifacts/bench/attn_backend.json. Usage:
  PYTHONPATH=src python -m benchmarks.attn_backend [--iters 3] [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import OUT_DIR, table
from repro.core import attention as A
from repro.roofline.analysis import HW_V5E

# (b, c, kvh, g, d, n_pool_chunks): pipeline-shaped cases; --quick trims
CASES = [
    (1, 128, 2, 4, 64, 3),
    (1, 256, 4, 4, 128, 3),
    (2, 128, 8, 4, 128, 6),
]


def _composite(backend: A.AttentionBackend, qg, kpool, vpool, scale):
    b, c, kvh, g, d = qg.shape
    st = A.attn_init(b, c, kvh, g, d)

    def body(carry, kv):
        k, v = kv
        return backend.chunk_block(qg, k, v, jnp.bool_(True), scale, carry), None

    st, _ = jax.lax.scan(body, st, (kpool, vpool))
    st = backend.self_block(qg, qg[:, :, :, 0], qg[:, :, :, 0], scale, st)
    return A.attn_finish(st, jnp.float32)


def _time(fn, *args, iters: int) -> float:
    out = fn(*args)              # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(iters: int = 3, quick: bool = False) -> dict:
    cases = CASES[:1] if quick else CASES
    rows = []
    for (b, c, kvh, g, d, npool) in cases:
        ks = jax.random.split(jax.random.key(0), 3)
        qg = jax.random.normal(ks[0], (b, c, kvh, g, d), jnp.float32)
        kpool = jax.random.normal(ks[1], (npool, b, c, kvh, d), jnp.float32)
        vpool = jax.random.normal(ks[2], (npool, b, c, kvh, d), jnp.float32)
        scale = 1.0 / float(np.sqrt(d))
        h = kvh * g
        t_kv = (npool + 1) * c
        flops = 4.0 * b * c * t_kv * h * d
        bytes_ = 2.0 * (b * c * h * d * 2 + 2 * b * t_kv * kvh * d)  # bf16
        tpu_s = max(flops / HW_V5E["peak_flops"], bytes_ / HW_V5E["hbm_bw"])

        outs, times = {}, {}
        for name in ("jnp", "pallas"):
            be = A.get_backend(name)
            fn = jax.jit(lambda q, kp, vp, be=be: _composite(be, q, kp, vp, scale))
            times[name] = _time(fn, qg, kpool, vpool, iters=iters)
            outs[name] = np.asarray(fn(qg, kpool, vpool))
        parity = float(np.max(np.abs(outs["jnp"] - outs["pallas"])))
        rows.append({
            "shape": f"b{b} c{c} kv{kvh} g{g} d{d} pool{npool}",
            "jnp_ms": round(times["jnp"] * 1e3, 2),
            "pallas_interp_ms": round(times["pallas"] * 1e3, 2),
            "parity_abs": f"{parity:.1e}",
            "tpu_roofline_us": round(tpu_s * 1e6, 1),
        })
        assert parity < 1e-4, f"backend divergence: {parity}"

    result = {
        "device": str(jax.devices()[0].platform),
        "note": ("pallas timings are interpret-mode off-TPU (correctness "
                 "harness, not a speed claim); tpu_roofline_us is the "
                 "analytic v5e bound for the composite"),
        "iters": iters,
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "attn_backend.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(table(rows, ["shape", "jnp_ms", "pallas_interp_ms", "parity_abs",
                       "tpu_roofline_us"]))
    print(f"-> {path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(iters=a.iters, quick=a.quick)
