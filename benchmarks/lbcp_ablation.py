"""LBCP ablation (paper §4.2): uniform chunks vs DP-only vs DP+SA under the
full MBKR execution model, plus the stagger-collapse study (event-driven vs
lockstep execution) that motivates running them JOINTLY."""
from __future__ import annotations

from benchmarks.common import emit, table
from repro.configs.base import get_config
from repro.core import costmodel as cm, lbcp
from repro.sim import SimConfig, simulate


def run(arch: str = "llama3-70b", seq: int = 131072, m: int = 16,
        batch: int = 8):
    cfg = get_config(arch)
    sm = cm.StageModel.build(cfg, 16, 1)

    variants = {}
    # uniform
    variants["uniform"] = lbcp.uniform_partition(seq, m)
    # DP only (stage 1 of Alg. 1)
    full = lbcp.plan_partition(cfg, seq, m, 16, cm.WSC_PAPER, sa_iters=0,
                               sa_rounds=1)
    variants["dp"] = full.chunks
    # DP + SA (full Alg. 1)
    full2 = lbcp.plan_partition(cfg, seq, m, 16, cm.WSC_PAPER, sa_iters=400,
                                sa_rounds=8)
    variants["dp+sa"] = full2.chunks

    rows = []
    for name, chunks in variants.items():
        res = cm.evaluate_prefill(chunks, sm, 16, cm.WSC_PAPER,
                                  mbkr_plan=full2.mbkr_plan)
        lat, thr = cm.evaluate_e2e(batch, res.latency, chunks, sm, 16,
                                   cm.WSC_PAPER, mbkr_plan=full2.mbkr_plan)
        rows.append({
            "variant": name, "t_prefill_s": round(res.latency, 4),
            "e2e_s": round(lat, 4), "throughput": round(thr, 4),
            "first_chunk": chunks[0], "last_chunk": chunks[-1],
        })

    # stagger-collapse study
    for execution, part in (("lockstep", "uniform"), ("eventdriven", "uniform"),
                            ("eventdriven", "lbcp")):
        r = simulate(SimConfig(scheduler="mocap", model=cfg, seq_len=seq,
                               batch=batch, num_chunks=m, partition=part,
                               execution=execution, sa_iters=60))
        kvc = cm.kv_chunk_bytes(sm, seq // m)
        rows.append({
            "variant": f"{execution}/{part}",
            "t_prefill_s": "", "e2e_s": round(r.e2e_latency, 4),
            "throughput": round(r.throughput, 4),
            "first_chunk": f"peak={r.peak_mem/kvc:.1f}ck",
            "last_chunk": "",
        })
    return rows


def main():
    rows = run()
    print(table(rows, ["variant", "t_prefill_s", "e2e_s", "throughput",
                       "first_chunk", "last_chunk"]))
    emit("lbcp_ablation", rows)
    return rows


if __name__ == "__main__":
    main()
