"""Paper Fig. 6(b): maximum feasible sequence length, MOCAP vs Terapipe,
across models and chunk counts. Paper: up to 1.31x, larger gain at fewer
chunks. Also cross-checks the closed-form slot-plan prediction M/peak(M)."""
from __future__ import annotations

from benchmarks.common import PAPER_MODELS, emit, table
from repro.configs.base import get_config
from repro.core import mbkr
from repro.sim import SimConfig, max_seq_len

CHUNKS = (16, 24, 32, 64)


def run(batch: int = 3):
    rows = []
    for arch in PAPER_MODELS:
        cfg = get_config(arch)
        for m in CHUNKS:
            mt = max_seq_len(SimConfig(scheduler="terapipe", model=cfg,
                                       batch=batch, num_chunks=m))
            mm = max_seq_len(SimConfig(scheduler="mocap", model=cfg,
                                       batch=batch, num_chunks=m))
            plan = mbkr.plan(m, 16)
            rows.append({
                "model": arch, "num_chunks": m,
                "terapipe_max_seq": mt, "mocap_max_seq": mm,
                "ratio": round(mm / mt, 3) if mt else "",
                "plan_prediction": round(m / plan.peak, 3),
            })
    return rows


def main():
    rows = run()
    print(table(rows, ["model", "num_chunks", "terapipe_max_seq",
                       "mocap_max_seq", "ratio", "plan_prediction"]))
    best = max(r["ratio"] for r in rows if r["ratio"])
    print(f"max ratio {best:.2f}x (paper: up to 1.31x); gain shrinks with "
          f"more chunks (paper's chunk-count tradeoff)")
    emit("fig6b", rows)
    return rows


if __name__ == "__main__":
    main()
