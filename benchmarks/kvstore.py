"""KV page store benchmark: max feasible sequence length vs kv_dtype.

Two levers multiply (DESIGN.md §6): MBKR slot orchestration shrinks the pool
from M chunk-slots to ``plan(M, N).num_slots``, and the page codec shrinks
every stored byte. At an EQUAL per-stage byte budget, the table reports the
max feasible sequence length per codec (per-page scale overhead included),
the combined gain over the Terapipe/bf16 baseline, and the cold-tier
headroom when --offload staging is allowed.

Acceptance floor: kv_dtype=int8 >= 1.5x the bf16 max seq len at the M=N=16
dryrun config. A device-validated leg round-trips one quantized pool chunk
through scatter/gather + both attention backends to pin the codec error the
capacity numbers rely on.

Writes artifacts/bench/kvstore.json. Usage:
  PYTHONPATH=src python -m benchmarks.kvstore [--quick]
"""
from __future__ import annotations

import argparse
import json
import math
import os

import numpy as np

from benchmarks.common import OUT_DIR, table
from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.core import mbkr
from repro.kvstore import pages as PG
from repro.kvstore import quant as Q
from repro.kvstore import tiers as TR

M = N = 16                       # the dryrun config of the acceptance floor
DTYPES = ("bfloat16", "int8", "fp8")
PAGE_TOKENS = 64
H2D_BW = 16e9


def capacity_table(arch: str = "llama3-70b", hw=cm.WSC_PAPER):
    """Max feasible seq len per codec at the per-stage KV byte budget left
    after weights (the same capacity math the lease manager provisions)."""
    cfg = get_config(arch)
    sm = cm.StageModel.build(cfg, N, 1)
    kv_tok = cm.kv_chunk_bytes(sm, 1)          # one stage's bytes/token, bf16
    weights = cfg.param_count() * 2 / N
    budget = max(hw.hbm_cap - weights, hw.hbm_cap * 0.2)
    base_tp = TR.max_seq_len_for_budget(
        budget, kv_token_bytes=kv_tok, num_chunks=M, num_stages=N,
        codec=Q.get_codec("bfloat16"), page_tokens=PAGE_TOKENS,
        head_dim=cfg.resolved_head_dim, mbkr=False)   # Terapipe/bf16 floor
    rows = []
    for dt in DTYPES:
        codec = Q.get_codec(dt)
        s = TR.max_seq_len_for_budget(
            budget, kv_token_bytes=kv_tok, num_chunks=M, num_stages=N,
            codec=codec, page_tokens=PAGE_TOKENS,
            head_dim=cfg.resolved_head_dim)
        bf16 = rows[0]["max_seq_len"] if rows else s
        rows.append({
            "arch": arch, "kv_dtype": dt,
            "budget_GB": round(budget / 1e9, 1),
            "max_seq_len": s,
            "vs_bf16": round(s / bf16, 3) if bf16 else "",
            "vs_terapipe_bf16": round(s / base_tp, 3) if base_tp else "",
        })
    return rows


def tier_headroom(arch: str = "llama3-70b", hw=cm.WSC_PAPER):
    """Cold-tier study: fraction of own pages that can live host-side with
    the analytic prefetch still landing every page before its pool-scan
    tick, per codec (quantized pages stream back faster)."""
    cfg = get_config(arch)
    sm = cm.StageModel.build(cfg, N, 1)
    mplan = mbkr.plan(M, N)
    c = 131072 // M
    dur, _, _, _, _ = cm.chunk_cost_arrays(sm, [c] * M, hw, mbkr_plan=mplan)
    host_slots = (np.unique(np.concatenate(
        [mplan.host_slot_a[mplan.p2:], mplan.host_slot_b[mplan.p2:]]))
        if mplan.p2 < M else None)
    rows = []
    for dt in DTYPES:
        codec = Q.get_codec(dt)
        geom = PG.page_geometry(c, mplan.num_slots, PAGE_TOKENS)
        tbl = PG.build_slot_pages(geom)
        dims = dict(lps=sm.attn_layers, b=1, kvh=cfg.num_kv_heads,
                    hd=cfg.resolved_head_dim)
        cb = TR.chunk_page_bytes(geom, codec, **dims)
        # shrink the hot budget until the plan goes infeasible
        best = 0
        for cold_chunks in range(0, mplan.p2):
            hot = cb * (mplan.p2 - cold_chunks)
            plan = TR.plan_tiers(geom, codec, tbl, mplan.own_slot, mplan.p2,
                                 M, TR.TierSpec(hot_bytes=hot, cold_bw=H2D_BW),
                                 **dims, tick_s=dur, host_slots=host_slots)
            if plan.feasible:
                # count the cold chunks actually placed (host-shared slots
                # are ineligible, so this can be < the requested overflow)
                best = max(best, len({op.chunk for op in plan.prefetch}))
        rows.append({
            "arch": arch, "kv_dtype": dt, "seq_len": c * M,
            "chunk_MB": round(cb / 1e6, 1),
            "cold_chunks_feasible": best,
            "cold_frac": round(best / max(mplan.p2, 1), 3),
        })
    return rows


def device_validation():
    """Round-trip one chunk through the paged pool + both backends on the
    actual device (interpret-mode kernels off-TPU): the codec error the
    capacity table's dtypes rely on, measured not assumed."""
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    b, c, kvh, g, d = 1, 64, 4, 2, 64
    geom = PG.page_geometry(c, 3, PAGE_TOKENS)
    tbl = PG.build_slot_pages(geom)
    ks = jax.random.split(jax.random.key(0), 3)
    qg = jax.random.normal(ks[0], (b, c, kvh, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, b, c, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, b, c, kvh, d), jnp.float32)
    scale = 1.0 / math.sqrt(d)
    out = {}
    for dt in DTYPES:
        codec = Q.get_codec(dt)
        pool = PG.alloc_pool(geom, codec, 1, b, kvh, d)
        pool = PG.scatter_chunk(pool, jnp.asarray(tbl[0]), k, v, codec)
        sl = lambda a: None if a is None else a[:, 0]
        pool_l = (sl(pool.k), sl(pool.v), sl(pool.k_scale), sl(pool.v_scale))
        res = {}
        for name in ("jnp", "pallas"):
            be = A.get_backend(name)
            st = A.pool_scan(be, qg, pool_l, tbl,
                             np.asarray([0, -1, -1, -1], np.int32),
                             jnp.int32(1), scale,
                             A.attn_init(b, c, kvh, g, d))
            res[name] = np.asarray(A.attn_finish(st, jnp.float32))
        ref_st = A.get_backend("jnp").chunk_block(
            qg, k[0], v[0], jnp.bool_(True), scale,
            A.attn_init(b, c, kvh, g, d))
        ref = np.asarray(A.attn_finish(ref_st, jnp.float32))
        rms = float(np.sqrt(np.mean(ref ** 2)))
        out[dt] = {
            "attn_err_p99_over_rms": round(
                float(np.percentile(np.abs(res["jnp"] - ref), 99)) / rms, 5),
            "backend_parity_abs": float(np.abs(res["jnp"] - res["pallas"]).max()),
        }
        assert out[dt]["backend_parity_abs"] < 1e-4, (dt, out[dt])
    return out


def pipeline_leg(quick: bool = False) -> dict:
    """Real-pipeline leg: jit the chunked pipeline with a TP-SHARDED paged
    pool (GSPMD kv-head sharding on new jaxlib; the manual TP lowering with
    local kv heads on old jaxlib — ``compat.resolve_tp_lowering``) and
    measure the pool's actual device bytes + prefill wall time per
    kv_dtype. Appends to artifacts/bench/kvstore.json."""
    import time

    from repro import compat
    compat.ensure_host_devices(8)
    import jax
    from repro.configs.base import RunConfig, get_smoke_config, replace
    from repro.core import pipeline as pp
    from repro.launch.mesh import make_test_topology
    from repro.models.api import build_model

    cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
    stages, tp = 4, 2  # old jaxlib: build_plan resolves the manual TP lowering
    topo = make_test_topology(stages, tp)
    seq, m = 256, 8
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, seq), 0,
                                cfg.vocab_size)
    rows = []
    for dt in ("auto",) + (() if quick else ("int8",)):
        run_cfg = RunConfig(num_chunks=m, num_stages=stages, kv_dtype=dt,
                            kv_page_tokens=8)
        plan = pp.build_plan(cfg, stages, seq, run_cfg)
        staged = pp.stage_params(cfg, params, plan)
        pool = pp.alloc_kv_pool(cfg, plan, 2)
        nbytes = sum(int(a.nbytes) for a in
                     (pool.k, pool.v, pool.k_scale, pool.v_scale)
                     if a is not None)
        with compat.set_mesh(topo.mesh):
            fn = jax.jit(lambda st, tk: pp.prefill_pipeline(
                cfg, st, tk, plan, topo))
            out = fn(staged, tokens)
            out.block_until_ready()
            t0 = time.perf_counter()
            fn(staged, tokens).block_until_ready()
            wall = time.perf_counter() - t0
        rows.append({"kv_dtype": plan.kv_dtype, "tp": tp,
                     "pool_bytes": nbytes, "wall_s": round(wall, 3)})
    print(table(rows, ["kv_dtype", "tp", "pool_bytes", "wall_s"]))
    path = os.path.join(OUT_DIR, "kvstore.json")
    if os.path.exists(path):
        blob = json.load(open(path))
        blob["pipeline_leg"] = rows
        with open(path, "w") as f:
            json.dump(blob, f, indent=1)
    return {"rows": rows}


def run(quick: bool = False) -> dict:
    archs = ("llama3-70b",) if quick else ("llama3-70b", "qwen3-235b")
    cap_rows, tier_rows = [], []
    for a in archs:
        cap_rows += capacity_table(a)
        tier_rows += tier_headroom(a)
    print(table(cap_rows, ["arch", "kv_dtype", "budget_GB", "max_seq_len",
                           "vs_bf16", "vs_terapipe_bf16"]))
    print(table(tier_rows, ["arch", "kv_dtype", "seq_len", "chunk_MB",
                            "cold_chunks_feasible", "cold_frac"]))
    val = device_validation()
    int8_gain = min(r["vs_bf16"] for r in cap_rows
                    if r["kv_dtype"] == "int8")
    print(f"int8 max-seq gain over bf16 at equal budget: {int8_gain:.2f}x "
          f"(acceptance floor 1.5x); codec attention error p99/rms: "
          + ", ".join(f"{k}={v['attn_err_p99_over_rms']}"
                      for k, v in val.items()))
    assert int8_gain >= 1.5, int8_gain
    result = {"config": {"M": M, "N": N, "page_tokens": PAGE_TOKENS},
              "quick": quick,
              "capacity": cap_rows, "tiers": tier_rows, "validation": val}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "kvstore.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"-> {path}")
    return result


def main(quick: bool = False):
    return run(quick=quick)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
