"""Aggregate the dry-run artifacts into the §Roofline table: three terms,
dominant bound, useful ratio, roofline fraction per (arch x shape x mode x
mesh) — plus the one-line what-would-move-it-down diagnosis."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, table

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _diagnose(r: dict, mode: str) -> str:
    dom = r["dominant"]
    if dom == "collective":
        kinds = r.get("coll_by_kind", {})
        top = max(kinds, key=kinds.get) if kinds else "?"
        return f"cut {top} (seq-parallel TP / bf16 psum / fetch-vs-qship)"
    if dom == "memory":
        if r["useful_ratio"] < 0.2:
            return "bubble+pool waste: raise M, triangular attention"
        return "fuse attention (Pallas flash), shard KV pool over TP"
    return "raise useful_ratio: fewer padded layers / smaller bubble"


def load(mesh: str = "pod"):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        rec = json.load(open(path))
        if rec.get("skipped"):
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"], "mode": rec["mode"],
                "status": "SKIP", "compute_ms": "", "memory_ms": "",
                "collective_ms": "", "dominant": "", "useful_%": "",
                "roofline_%": "", "hbm_GB": "", "note": rec.get("reason", "")[:40],
            })
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mode": rec["mode"], "status": "FAIL",
                         "note": rec.get("error", "")[:60]})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mode": rec["mode"],
            "status": "OK",
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"],
            "useful_%": round(r["useful_ratio"] * 100, 1),
            "roofline_%": round(r["roofline_fraction"] * 100, 2),
            "hbm_GB": round(rec["memory"]["peak_bytes_per_device"] / 1e9, 2),
            "note": _diagnose(r, rec["mode"]),
        })
    return rows


def main():
    for mesh in ("pod", "multipod"):
        rows = load(mesh)
        if not rows:
            continue
        print(f"===== mesh: {mesh} ({'256' if mesh == 'pod' else '512'} chips) =====")
        print(table(rows, ["arch", "shape", "mode", "status", "compute_ms",
                           "memory_ms", "collective_ms", "dominant",
                           "useful_%", "roofline_%", "hbm_GB"]))
        emit(f"roofline_{mesh}", rows)
    return load("pod")


if __name__ == "__main__":
    main()
