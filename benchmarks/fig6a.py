"""Paper Fig. 6(a): E2E latency + throughput, GPipe vs Terapipe vs MOCAP,
4 models x 4 sequence lengths on the 4x4 WSC. Reports normalized values and
the paper's headline aggregates (-76.4% latency, 3.24x throughput vs GPipe).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_MODELS, SEQ_LENS, emit, table
from repro.configs.base import get_config
from repro.sim import SimConfig, simulate


def run(batch: int = 8, sa_iters: int = 60):
    rows = []
    lat_red, thr_gain = [], []
    for arch in PAPER_MODELS:
        cfg = get_config(arch)
        for s in SEQ_LENS:
            res = {}
            for sched, part in (("gpipe", "uniform"), ("terapipe", "uniform"),
                                ("mocap", "lbcp")):
                res[sched] = simulate(SimConfig(
                    scheduler=sched, model=cfg, seq_len=s, batch=batch,
                    partition=part, sa_iters=sa_iters))
            base = res["gpipe"]
            for sched in ("gpipe", "terapipe", "mocap"):
                r = res[sched]
                rows.append({
                    "model": arch, "seq_len": s, "scheduler": sched,
                    "feasible": r.feasible,
                    "e2e_s": round(r.e2e_latency, 4),
                    "norm_latency": round(r.e2e_latency / base.e2e_latency, 4)
                    if base.feasible and r.feasible else "",
                    "throughput_rps": round(r.throughput, 4),
                    "norm_throughput": round(r.throughput / base.throughput, 4)
                    if base.feasible and r.feasible else "",
                })
            if res["gpipe"].feasible and res["mocap"].feasible:
                lat_red.append(1 - res["mocap"].e2e_latency / res["gpipe"].e2e_latency)
                thr_gain.append(res["mocap"].throughput / res["gpipe"].throughput)
    summary = {
        "avg_latency_reduction_vs_gpipe": float(np.mean(lat_red)),
        "avg_throughput_gain_vs_gpipe": float(np.mean(thr_gain)),
        "paper_claims": "-76.4% latency, 3.24x throughput",
    }
    return rows, summary


def main():
    rows, summary = run()
    print(table(rows, ["model", "seq_len", "scheduler", "e2e_s",
                       "norm_latency", "throughput_rps", "norm_throughput"]))
    print(f"MOCAP vs GPipe average: latency "
          f"-{summary['avg_latency_reduction_vs_gpipe']*100:.1f}% "
          f"(paper: -76.4%), throughput "
          f"{summary['avg_throughput_gain_vs_gpipe']:.2f}x (paper: 3.24x)")
    emit("fig6a", rows)
    return rows, summary


if __name__ == "__main__":
    main()
