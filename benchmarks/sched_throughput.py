"""Continuous chunk-level scheduling vs the batch-synchronous engine.

Closed-loop sweep (sim executor, WSC_PAPER profile): 3 archs x 3 sequence
buckets, 16 stages x 16 chunks x 8 requests. The batch-synchronous engine
pays the pipeline fill/drain bubble per request; the continuous scheduler
(repro.sched) pays it once per busy period, so req/s improves by roughly
(N-1+M)/M at this config (~1.7-1.9x; the acceptance floor is 1.5x).

  PYTHONPATH=src python -m benchmarks.sched_throughput [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import OUT_DIR, emit, table
from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                  PrefillEngine, Request, SimExecutor)

ARCHS = ("llama3-70b", "mistral-123b", "qwen3-235b")
BUCKETS = (32768, 65536, 131072)
NUM_STAGES = 16
NUM_CHUNKS = 16
NUM_REQUESTS = 8


def run_pair(arch: str, bucket: int, *, sa_iters: int = 24,
             policy: str = "fcfs"):
    cfg = get_config(arch)
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=NUM_STAGES,
                      tp=1, num_chunks=NUM_CHUNKS, max_batch=NUM_REQUESTS,
                      buckets=(bucket,), partition="lbcp", sa_iters=sa_iters)

    batch = PrefillEngine(ec, SimExecutor(cfg, ec.hw))
    for i in range(NUM_REQUESTS):
        batch.submit(Request(rid=i, arrival=0.0, seq_len=bucket))
    batch.run_until_drained()
    mb = batch.metrics()

    cont = ContinuousEngine(ec, SimExecutor(cfg, ec.hw), policy=policy)
    for i in range(NUM_REQUESTS):
        cont.submit(Request(rid=i, arrival=0.0, seq_len=bucket))
    cont.run_until_drained()
    mc = cont.metrics()
    return mb, mc


def main(quick: bool = False) -> None:
    rows = []
    for arch in ARCHS:
        for bucket in BUCKETS:
            mb, mc = run_pair(arch, bucket, sa_iters=8 if quick else 24)
            rows.append({
                "arch": arch,
                "seq": bucket,
                "batch_rps": mb["throughput"],
                "cont_rps": mc["throughput"],
                "speedup": mc["throughput"] / max(mb["throughput"], 1e-12),
                "cont_p99_ttft": mc["p99_ttft"],
                "bubble_frac": mc["bubble_frac"],
                "lease_hwm_frac": mc["lease_hwm_frac"],
                "lease_refusals": mc["lease_refusals"],
            })
    print(table(rows, ["arch", "seq", "batch_rps", "cont_rps", "speedup",
                       "cont_p99_ttft", "bubble_frac", "lease_hwm_frac",
                       "lease_refusals"]))
    path = emit("sched_throughput", rows)
    print(f"csv -> {path}")
    worst = min(r["speedup"] for r in rows)
    # JSON twin of the CSV so the bench-regression gate (benchmarks.compare)
    # can diff it against the committed BENCH_sched.json baseline
    jpath = os.path.join(OUT_DIR, "sched_throughput.json")
    with open(jpath, "w") as f:
        json.dump({"quick": quick, "min_speedup": round(worst, 3),
                   "rows": rows}, f, indent=1)
    print(f"-> {jpath}")
    print(f"min speedup across sweep: {worst:.2f}x "
          f"({'PASS' if worst >= 1.5 else 'BELOW'} the 1.5x floor)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
