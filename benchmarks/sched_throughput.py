"""Continuous chunk-level scheduling vs the batch-synchronous engine.

Closed-loop sweep (sim executor, WSC_PAPER profile): 3 archs x 3 sequence
buckets, 16 stages x 16 chunks x 8 requests. The batch-synchronous engine
pays the pipeline fill/drain bubble per request; the continuous scheduler
(repro.sched) pays it once per busy period, so req/s improves by roughly
(N-1+M)/M at this config (~1.7-1.9x; the acceptance floor is 1.5x).

  PYTHONPATH=src python -m benchmarks.sched_throughput [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import replace as dc_replace

from benchmarks.common import OUT_DIR, emit, table
from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                  PrefillEngine, Request, SimExecutor)

ARCHS = ("llama3-70b", "mistral-123b", "qwen3-235b")
BUCKETS = (32768, 65536, 131072)
NUM_STAGES = 16
NUM_CHUNKS = 16
NUM_REQUESTS = 8


def run_pair(arch: str, bucket: int, *, sa_iters: int = 24,
             policy: str = "fcfs"):
    cfg = get_config(arch)
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=NUM_STAGES,
                      tp=1, num_chunks=NUM_CHUNKS, max_batch=NUM_REQUESTS,
                      buckets=(bucket,), partition="lbcp", sa_iters=sa_iters)

    batch = PrefillEngine(ec, SimExecutor(cfg, ec.hw))
    for i in range(NUM_REQUESTS):
        batch.submit(Request(rid=i, arrival=0.0, seq_len=bucket))
    batch.run_until_drained()
    mb = batch.metrics()

    cont = ContinuousEngine(dc_replace(ec, policy=policy),
                            SimExecutor(cfg, ec.hw))
    for i in range(NUM_REQUESTS):
        cont.submit(Request(rid=i, arrival=0.0, seq_len=bucket))
    cont.run_until_drained()
    mc = cont.metrics()
    return mb, mc


def telem_overhead(arch: str = "llama3-70b", bucket: int = 32768, *,
                   sa_iters: int = 8, reps: int = 5) -> float:
    """Wall-clock ratio of an obs-instrumented continuous run (trace
    recording + merged-timeline build) over the bare run — the "telemetry
    is (near-)free when you ask for it, FREE when you don't" claim.

    Naively wall-timing trace-on vs trace-off runs and differencing them
    drowns the ~2% signal in run-to-run scheduler noise, so the obs cost
    is timed DIRECTLY and divided by the bare run's floor:

        overhead = 1 + (t_record + t_merge) / t_run

    - ``t_run``: min wall-clock of the bare engine loop over ``reps`` runs,
    - ``t_record``: min time to replay the run's exact recorder calls
      (every ``task``/``mark`` the scheduler emitted) into a fresh
      ``TraceRecorder`` — the in-loop recording cost,
    - ``t_merge``: min time of ``merged_trace()`` — the one-shot
      post-run timeline build.

    No noisy-minus-noisy subtraction anywhere, so the column is stable to
    a fraction of its own small value. Gated <= 1.05 by
    benchmarks/compare.py."""
    from repro.obs.trace import TraceRecorder
    cfg = get_config(arch)
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=NUM_STAGES,
                      tp=1, num_chunks=NUM_CHUNKS, max_batch=NUM_REQUESTS,
                      buckets=(bucket,), partition="lbcp", sa_iters=sa_iters)

    def run(obs: bool):
        eng = ContinuousEngine(dc_replace(ec, trace=obs),
                               SimExecutor(cfg, ec.hw))
        for i in range(NUM_REQUESTS):
            eng.submit(Request(rid=i, arrival=0.0, seq_len=bucket))
        t0 = time.perf_counter()
        eng.run_until_drained()
        return time.perf_counter() - t0, eng

    run(False)  # warm caches (imports, SA planner code paths) off-clock
    t_run = min(run(False)[0] for _ in range(reps))
    _, eng = run(True)

    def replay() -> float:
        rec = TraceRecorder(enabled=True)
        t0 = time.perf_counter()
        for e in eng.trace.tasks:
            rec.task(e.rid, e.chunk, e.stage, e.start, e.finish)
        for e in eng.trace.marks:
            rec.mark(e.rid, e.kind, e.time)
        return time.perf_counter() - t0

    t_record = min(replay() for _ in range(reps))

    def merge() -> float:
        t0 = time.perf_counter()
        eng.merged_trace()
        return time.perf_counter() - t0

    t_merge = min(merge() for _ in range(reps))
    return 1.0 + (t_record + t_merge) / max(t_run, 1e-9)


def fleet_pair(arch: str, bucket: int, rate: float, *, n_req: int = 24,
               slo_s: float = 0.6, sa_iters: int = 8, seed: int = 0):
    """Lease/cost-aware routing (jsf) vs round-robin over a heterogeneous
    2-cell fleet at EQUAL offered load — the ISSUE 9 acceptance row.

    Two sim cells: a FAST cell on the paper profile and a DEGRADED cell at
    ~0.55x gemm/attn efficiency (a straggling or thermally-capped block).
    Both routers see the IDENTICAL seeded Poisson stream; everything
    downstream is the analytic cost model on a virtual clock, so the p99
    advantage is deterministic and gets an exact >=-0 gate
    (``router_beats_rr``) in benchmarks/compare.py."""
    from repro.fleet import FleetFabric, FleetRouter
    from repro.sched import poisson_arrivals
    cfg = get_config(arch)
    slow_hw = dc_replace(cm.WSC_PAPER, name="wsc-degraded",
                         gemm_eff=cm.WSC_PAPER.gemm_eff * 0.55,
                         attn_eff=cm.WSC_PAPER.attn_eff * 0.55)

    def build_cells():
        cells = {}
        for name, hw in (("fast", cm.WSC_PAPER), ("degraded", slow_hw)):
            ec = EngineConfig(model=cfg, hw=hw, num_stages=NUM_STAGES, tp=1,
                              num_chunks=NUM_CHUNKS, max_batch=NUM_REQUESTS,
                              buckets=(bucket,), partition="lbcp",
                              sa_iters=sa_iters, slo=slo_s)
            cells[name] = ContinuousEngine(ec, SimExecutor(cfg, hw))
        return cells

    arrivals = poisson_arrivals(rate, n_req, seed=seed)
    out = {}
    for policy in ("jsf", "rr"):
        fab = FleetFabric(build_cells(), FleetRouter(policy))
        for i, t in enumerate(arrivals):
            fab.submit(Request(rid=i, arrival=float(t), seq_len=bucket))
        fab.pump()
        out[policy] = fab.metrics()
    return out


def prefix_pair(arch: str, bucket: int, *, n_req: int = 16,
                n_prefixes: int = 4, prefix_chunks: int = 6,
                zipf_a: float = 1.1, sa_iters: int = 8, inflight: int = 2,
                seed: int = 0):
    """Shared-prefix workload: the radix prefix index ON vs OFF at EQUAL
    lease budget — the ISSUE 10 acceptance rows.

    A seeded system-prompt + few-shot mix: each request draws one of
    ``n_prefixes`` shared prefix chains with Zipf(``zipf_a``) popularity
    (chain element = synthetic chunk-content hash) covering its first
    ``prefix_chunks`` chunks, then a per-request novel suffix. Both engines
    see the IDENTICAL closed-loop stream; everything downstream is the
    analytic cost model on a virtual clock, so the hit rate, the
    peak-inflight admission win and the p99-TTFT advantage are all
    deterministic and get exact gates in benchmarks/compare.py."""
    import numpy as np
    cfg = get_config(arch)
    base = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=NUM_STAGES,
                        tp=1, num_chunks=NUM_CHUNKS, max_batch=NUM_REQUESTS,
                        buckets=(bucket,), partition="lbcp",
                        sa_iters=sa_iters, inflight=inflight)
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_prefixes + 1) ** zipf_a
    pids = rng.choice(n_prefixes, size=n_req, p=w / w.sum())
    chains = [tuple([(int(z) + 1) * 10_000 + j for j in range(prefix_chunks)]
                    + [(i + 1) * 1_000_000 + j
                       for j in range(NUM_CHUNKS - prefix_chunks)])
              for i, z in enumerate(pids)]
    out = {}
    for mode in ("off", "on"):
        eng = ContinuousEngine(dc_replace(base, prefix_cache=mode),
                               SimExecutor(cfg, base.hw))
        for i, ch in enumerate(chains):
            eng.submit(Request(rid=i, arrival=0.0, seq_len=bucket,
                               prefix_hashes=ch))
        eng.run_until_drained()
        out[mode] = eng.metrics()
    return out


def run_prefix_rows(quick: bool = False):
    rows = []
    sa = 8 if quick else 24
    for arch, bucket in (("llama3-70b", 32768), ("qwen3-235b", 65536)):
        m = prefix_pair(arch, bucket, sa_iters=sa)
        on, off = m["on"], m["off"]
        rows.append({
            "arch": arch,
            "seq": bucket,
            "off_p99_ttft": off["p99_ttft"],
            "on_p99_ttft": on["p99_ttft"],
            "p99_advantage": off["p99_ttft"] / max(on["p99_ttft"], 1e-12),
            "prefix_beats_off": int(on["p99_ttft"] < off["p99_ttft"]),
            "hit_rate": on["prefix_hit_rate"],
            "off_peak_inflight": off["peak_inflight"],
            "on_peak_inflight": on["peak_inflight"],
            "admits_more": int(on["peak_inflight"] > off["peak_inflight"]),
            "saved_gb": on["prefix_saved_bytes"] / 1e9,
        })
    return rows


def run_fleet_rows(quick: bool = False):
    rows = []
    sa = 8 if quick else 24
    for arch, bucket, rate in (("llama3-70b", 32768, 4.0),
                               ("llama3-70b", 32768, 6.0)):
        m = fleet_pair(arch, bucket, rate, sa_iters=sa)
        jsf, rr = m["jsf"], m["rr"]
        rows.append({
            "arch": arch,
            "seq": bucket,
            "rate": rate,
            "jsf_p99_ttft": jsf["p99_ttft"],
            "rr_p99_ttft": rr["p99_ttft"],
            "p99_advantage": rr["p99_ttft"] / max(jsf["p99_ttft"], 1e-12),
            "router_beats_rr": int(jsf["p99_ttft"] < rr["p99_ttft"]),
            "jsf_slo_attainment": jsf["slo_attainment"],
            "rr_slo_attainment": rr["slo_attainment"],
            "jsf_completed": jsf["completed"],
        })
    return rows


def main(quick: bool = False) -> None:
    overhead = round(telem_overhead(sa_iters=8 if quick else 24), 3)
    rows = []
    for arch in ARCHS:
        for bucket in BUCKETS:
            mb, mc = run_pair(arch, bucket, sa_iters=8 if quick else 24)
            rows.append({
                "arch": arch,
                "seq": bucket,
                "batch_rps": mb["throughput"],
                "cont_rps": mc["throughput"],
                "speedup": mc["throughput"] / max(mb["throughput"], 1e-12),
                "cont_p99_ttft": mc["p99_ttft"],
                "bubble_frac": mc["bubble_frac"],
                "lease_hwm_frac": mc["lease_hwm_frac"],
                "lease_refusals": mc["lease_refusals"],
                "telem_overhead": overhead,
            })
    print(table(rows, ["arch", "seq", "batch_rps", "cont_rps", "speedup",
                       "cont_p99_ttft", "bubble_frac", "lease_hwm_frac",
                       "lease_refusals", "telem_overhead"]))
    path = emit("sched_throughput", rows)
    print(f"csv -> {path}")
    fleet_rows = run_fleet_rows(quick)
    print(table(fleet_rows, ["arch", "seq", "rate", "jsf_p99_ttft",
                             "rr_p99_ttft", "p99_advantage",
                             "router_beats_rr", "jsf_slo_attainment",
                             "rr_slo_attainment"]))
    prefix_rows = run_prefix_rows(quick)
    print(table(prefix_rows, ["arch", "seq", "off_p99_ttft", "on_p99_ttft",
                              "p99_advantage", "prefix_beats_off",
                              "hit_rate", "off_peak_inflight",
                              "on_peak_inflight", "admits_more",
                              "saved_gb"]))
    worst = min(r["speedup"] for r in rows)
    # JSON twin of the CSV so the bench-regression gate (benchmarks.compare)
    # can diff it against the committed BENCH_sched.json baseline
    jpath = os.path.join(OUT_DIR, "sched_throughput.json")
    with open(jpath, "w") as f:
        json.dump({"quick": quick, "min_speedup": round(worst, 3),
                   "rows": rows, "fleet": fleet_rows,
                   "prefix": prefix_rows}, f, indent=1)
    print(f"-> {jpath}")
    print(f"min speedup across sweep: {worst:.2f}x "
          f"({'PASS' if worst >= 1.5 else 'BELOW'} the 1.5x floor)")
    print(f"obs overhead (trace on / off): {overhead:.3f}x "
          f"({'PASS' if overhead <= 1.05 else 'ABOVE'} the 1.05x ceiling)")
    adv = min(r["p99_advantage"] for r in fleet_rows)
    print(f"fleet router p99-TTFT advantage over round-robin: {adv:.2f}x "
          f"({'PASS' if adv > 1.0 else 'BELOW'} the >1x floor)")
    padv = min(r["p99_advantage"] for r in prefix_rows)
    pok = all(r["prefix_beats_off"] and r["admits_more"]
              for r in prefix_rows)
    print(f"prefix cache p99-TTFT advantage over off: {padv:.2f}x, "
          f"admits-more+beats-off: {'PASS' if pok and padv > 1.0 else 'FAIL'}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
