"""Shared benchmark utilities: CSV emission, model sets, pretty tables."""
from __future__ import annotations

import csv
import io
import os
from typing import Any, Dict, Iterable, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

PAPER_MODELS = ("llama3-70b", "mistral-123b", "qwen3-235b", "llama3-405b")
SEQ_LENS = (32768, 65536, 131072, 262144)


def emit(name: str, rows: List[Dict[str, Any]]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return path


def table(rows: List[Dict[str, Any]], cols: Iterable[str]) -> str:
    cols = list(cols)
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    out = io.StringIO()
    out.write(" | ".join(c.ljust(widths[c]) for c in cols) + "\n")
    out.write("-+-".join("-" * widths[c] for c in cols) + "\n")
    for r in rows:
        out.write(" | ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols) + "\n")
    return out.getvalue()


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 0.01:
            return f"{v:.3g}"
        return f"{v:.3f}"
    return str(v)
