"""Closed-loop calibration benchmark: predicted vs MEASURED chunk latency
MAPE before/after fitting the HardwareProfile effective rates
(repro.obs.calibrate), plus the health-sentinel overhead ratio.

Deterministic sim-backed leg — runs off-TPU. The "measured" spans are
synthesized from a GROUND-TRUTH profile the fit never sees (the nominal
WSC_PAPER with its effective rates perturbed: gemm_eff x0.8, attn_eff x1.1,
hbm_bw x0.9, link_bw x0.95) plus seeded ~1% multiplicative noise — i.e. a
machine whose real rates differ from the datasheet, observed through a
slightly jittery clock. Calibration must recover most of that gap:

- ``mape_nominal``      datasheet prediction vs the measured spans (~10-20%
                        at this perturbation),
- ``mape_calibrated``   post-fit prediction vs the same spans (~ the noise
                        floor, <1%),
- ``mape_ratio``        calibrated / nominal — gated well below 1.0 by
                        benchmarks/compare.py,
- ``calibrated_improves``  1 iff strictly better (the acceptance criterion).

``health_overhead`` is the wall-clock ratio of a continuous run PLUS the
host-side health sentinels (SLO burn + ledger drift + exports) over the
bare run, timed directly like sched_throughput.telem_overhead (no
noisy-minus-noisy subtraction); gated <= 1.05x.

The row set and every fit input are identical under --quick and full mode
(--quick only shrinks the SA budget inside the overhead leg's engine), so
the committed BENCH_calibration.json baseline stays valid for both.

Artifacts: artifacts/bench/calibration.json (compare-gated) and
artifacts/bench/calibrated_profile.json — a real calibrated-profile JSON
(obs.calibrate.save_profile) that ``--calibrated-profile`` flags accept.

  PYTHONPATH=src python -m benchmarks.calibration [--quick]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from dataclasses import replace as dc_replace

import numpy as np

from benchmarks.common import OUT_DIR, emit, table
from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.core import mbkr
from repro.obs import calibrate as cal

ARCHS = ("llama3-70b", "qwen3-235b")
SEQS = (32768, 65536)
NUM_STAGES = 16
NUM_CHUNKS = 16

# the machine the "measurements" come from: datasheet rates are off by
# -20% gemm, +10% attention, -10% HBM, -5% interconnect
TRUE_HW = dc_replace(cm.WSC_PAPER, name="wsc-ground-truth",
                     gemm_eff=cm.WSC_PAPER.gemm_eff * 0.8,
                     attn_eff=cm.WSC_PAPER.attn_eff * 1.1,
                     hbm_bw=cm.WSC_PAPER.hbm_bw * 0.9,
                     link_bw=cm.WSC_PAPER.link_bw * 0.95)
NOISE_FRAC = 0.01


def synth_measured(sm: cm.StageModel, chunks, mplan,
                   seed: int) -> np.ndarray:
    """A ``[N, T]`` measured-span array as MeasuredProfile lays it out:
    chunk ``ph``'s true cost lands at every valid (stage, tick = stage+ph),
    times seeded multiplicative clock noise; fill/drain cells stay 0."""
    feats = cm.chunk_cost_features(sm, chunks, cm.WSC_PAPER,
                                   mbkr_plan=mplan)
    cost_true = feats @ cm.profile_theta(TRUE_HW, sm.tp)
    n, m = NUM_STAGES, len(chunks)
    rng = np.random.default_rng(seed)
    tick_s = np.zeros((n, m + n - 1))
    for s in range(n):
        for ph in range(m):
            tick_s[s, s + ph] = cost_true[ph] * (
                1.0 + NOISE_FRAC * rng.standard_normal())
    return tick_s


def fit_row(arch: str, seq: int, seed: int):
    cfg = get_config(arch)
    sm = cm.StageModel.build(cfg, NUM_STAGES, 1)
    chunks = [seq // NUM_CHUNKS] * NUM_CHUNKS
    mplan = mbkr.plan(NUM_CHUNKS, NUM_STAGES) if not cfg.attn_free else None
    measured = synth_measured(sm, chunks, mplan, seed)
    fit = cal.fit_profile(sm, chunks, measured, cm.WSC_PAPER,
                          mbkr_plan=mplan)
    row = {
        "arch": arch,
        "seq": seq,
        "mape_nominal": round(fit.mape_nominal, 6),
        "mape_calibrated": round(fit.mape_calibrated, 6),
        "mape_ratio": round(fit.mape_calibrated
                            / max(fit.mape_nominal, 1e-12), 6),
        "calibrated_improves": int(fit.mape_calibrated < fit.mape_nominal),
    }
    return row, fit


def health_overhead(arch: str = "llama3-70b", bucket: int = 32768, *,
                    sa_iters: int = 8, reps: int = 5) -> float:
    """Wall-clock ratio of a continuous run + the host-side health
    sentinels over the bare run. Like sched_throughput.telem_overhead, the
    sentinel cost is timed DIRECTLY (replaying the exact per-run checks:
    TTFT histogram -> check_slo, per-request ledger-vs-model drift, the
    summary + metrics export) and divided by the bare run's floor —
    no noisy-minus-noisy subtraction. Gated <= 1.05x by compare.py."""
    from repro.obs.health import HealthMonitor
    from repro.obs.metrics import Histogram, MetricsRegistry
    from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                      Request, SimExecutor)
    cfg = get_config(arch)
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=NUM_STAGES,
                      tp=1, num_chunks=NUM_CHUNKS, max_batch=8,
                      buckets=(bucket,), partition="lbcp",
                      sa_iters=sa_iters)

    def run():
        eng = ContinuousEngine(ec, SimExecutor(cfg, ec.hw))
        for i in range(8):
            eng.submit(Request(rid=i, arrival=0.0, seq_len=bucket))
        t0 = time.perf_counter()
        eng.run_until_drained()
        return time.perf_counter() - t0, eng

    run()  # warm caches off-clock
    t_run = min(run()[0] for _ in range(reps))
    _, eng = run()
    records = eng.scheduler.metrics.records
    ledger = {"ring": 1.0e9, "fetch": 2.5e8, "qship": 1.2e8, "tp": 4.0e8}

    def sentinels() -> float:
        mon = HealthMonitor()
        t0 = time.perf_counter()
        h = Histogram("ttft")
        for r in records:
            if math.isfinite(r.finish):
                h.observe(r.finish - r.arrival)
        mon.check_slo(h, slo_s=5.0)
        for _ in records:       # one ledger-drift check per completed wave
            mon.check_ledger(ledger, ledger)
        mon.summary()
        mon.to_metrics(MetricsRegistry())
        return time.perf_counter() - t0

    t_health = min(sentinels() for _ in range(reps))
    return 1.0 + t_health / max(t_run, 1e-9)


def run(quick: bool = False) -> None:
    overhead = round(health_overhead(sa_iters=8 if quick else 24), 3)
    rows, last_fit = [], None
    for i, arch in enumerate(ARCHS):
        for j, seq in enumerate(SEQS):
            row, fit = fit_row(arch, seq, seed=1000 + 10 * i + j)
            row["health_overhead"] = overhead
            rows.append(row)
            last_fit = fit
    print(table(rows, ["arch", "seq", "mape_nominal", "mape_calibrated",
                       "mape_ratio", "calibrated_improves",
                       "health_overhead"]))
    path = emit("calibration", rows)
    print(f"csv -> {path}")

    os.makedirs(OUT_DIR, exist_ok=True)
    ppath = cal.save_profile(
        os.path.join(OUT_DIR, "calibrated_profile.json"),
        last_fit.profile, fit=last_fit,
        meta={"arch": ARCHS[-1], "seq": SEQS[-1],
              "source": "benchmarks.calibration"})
    print(f"calibrated profile -> {ppath}")

    jpath = os.path.join(OUT_DIR, "calibration.json")
    with open(jpath, "w") as f:
        json.dump({"quick": quick, "rows": rows}, f, indent=1)
    print(f"-> {jpath}")
    worst = max(r["mape_ratio"] for r in rows)
    ok = all(r["calibrated_improves"] for r in rows)
    print(f"worst calibrated/nominal MAPE ratio: {worst:.4f} "
          f"({'PASS' if ok and worst < 1.0 else 'FAIL'}: calibration must "
          "strictly improve every row)")
    print(f"health-sentinel overhead: {overhead:.3f}x "
          f"({'PASS' if overhead <= 1.05 else 'ABOVE'} the 1.05x ceiling)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
