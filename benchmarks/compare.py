"""Bench-regression gate: diff a fresh --quick benchmark run against the
committed baselines (``BENCH_kvstore.json`` / ``BENCH_attn_backend.json`` /
``BENCH_sched.json`` at repo root) with per-metric tolerances, and exit
non-zero on regression — so a perf/capacity/parity loss fails the
``bench-artifacts`` CI job instead of silently riding an upload.

Direction matters per metric: capacity and speedup metrics regress when
they DROP (``low``); error, launch-count and wall-time metrics regress when
they RISE (``high``). A degradation passes while it stays within
``max(rel * |baseline|, abs_floor)``. Deterministic metrics (launch counts,
analytic capacity, seeded-SA speedups) get tight or exact tolerances;
wall-clock metrics get a deliberately loose 10x guard — CI runners are
noisy, and the gate is there to catch pathological blowups, not jitter.

Rows are matched by their key fields; a baseline row MISSING from the fresh
run is a regression too (lost coverage). Extra fresh rows (new cases) pass.

Usage (after ``python -m benchmarks.run --quick --only kvstore,attn_backend,sched``):
  PYTHONPATH=src python -m benchmarks.compare [--names kvstore,attn_backend,sched]

Refreshing baselines after an INTENTIONAL change:
  PYTHONPATH=src python -m benchmarks.compare --update
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Any, Callable, Dict, List, Tuple

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FRESH_DIR = os.path.join(ROOT, "artifacts", "bench")

# metric -> (direction, rel_tol, abs_floor); direction "low" = lower is
# worse (floors), "high" = higher is worse (ceilings)
MetricSpec = Tuple[str, float, float]
# (label, rows-getter, key fields, metric specs)
TableSpec = Tuple[str, Callable[[dict], List[dict]], Tuple[str, ...],
                  Dict[str, MetricSpec]]

_TIME_GUARD = ("high", 9.0, 5.0)  # 10x / +5 units: noise guard only


def _validation_rows(blob: dict) -> List[dict]:
    return [{"kv_dtype": k, **v} for k, v in blob.get("validation", {}).items()]


SPECS: Dict[str, Dict[str, Any]] = {
    "attn_backend": {
        "baseline": "BENCH_attn_backend.json",
        "fresh": "attn_backend.json",
        "tables": [
            ("rows", lambda b: b["rows"], ("shape",), {
                "parity_abs": ("high", 9.0, 1e-5),
                "launches_scan": ("high", 0.0, 0.0),
                "launches_batched": ("high", 0.0, 0.0),  # O(1) stays O(1)
                "launches_paged": ("high", 0.0, 0.0),
                # the paged kernel's whole point: the dense-slot-stack
                # gather copy stays DELETED (exact 0) and the roofline
                # speedup that deletion buys never drops — all
                # deterministic, so exact gates
                "hbm_gather_bytes": ("high", 0.0, 0.0),
                "hbm_gather_bytes_paged": ("high", 0.0, 0.0),
                "paged_speedup": ("low", 0.0, 0.0),
                # §3.4 remote-traffic pricing of the case: deterministic, so
                # any upward drift is a real comms regression, not noise
                "wire_bytes_fetch": ("high", 0.0, 0.0),
                "wire_bytes_qship": ("high", 0.0, 0.0),
                "jnp_ms": _TIME_GUARD,
                "pallas_scan_ms": _TIME_GUARD,
                "pool_batched_ms": _TIME_GUARD,
                "pool_paged_ms": _TIME_GUARD,
            }),
        ],
    },
    "kvstore": {
        "baseline": "BENCH_kvstore.json",
        "fresh": "kvstore.json",
        "tables": [
            ("capacity", lambda b: b["capacity"], ("arch", "kv_dtype"), {
                "max_seq_len": ("low", 0.01, 0.0),
                "vs_bf16": ("low", 0.01, 0.0),
                "vs_terapipe_bf16": ("low", 0.01, 0.0),
            }),
            ("tiers", lambda b: b["tiers"], ("arch", "kv_dtype"), {
                "cold_chunks_feasible": ("low", 0.0, 0.0),
                "cold_frac": ("low", 0.01, 0.0),
            }),
            ("validation", _validation_rows, ("kv_dtype",), {
                "attn_err_p99_over_rms": ("high", 0.10, 1e-4),
                "backend_parity_abs": ("high", 0.0, 1e-4),
            }),
        ],
    },
    "sched": {
        "baseline": "BENCH_sched.json",
        "fresh": "sched_throughput.json",
        "tables": [
            ("rows", lambda b: b["rows"], ("arch", "seq"), {
                "speedup": ("low", 0.05, 0.0),
                "batch_rps": ("low", 0.05, 0.0),
                "cont_rps": ("low", 0.05, 0.0),
                "cont_p99_ttft": ("high", 0.05, 1e-4),
                "bubble_frac": ("high", 0.05, 0.01),
                "lease_refusals": ("high", 0.0, 0.0),
                # obs-on / obs-off wall-clock ratio (~1.0): tracing the run
                # + building the merged timeline must stay within 5% of the
                # bare engine — the repro.obs "near-free" contract
                "telem_overhead": ("high", 0.0, 0.05),
            }),
            # fleet router vs round-robin over a heterogeneous 2-cell pair:
            # everything is seeded Poisson + analytic cost model, so the
            # acceptance bit (jsf strictly beats rr on p99 TTFT at equal
            # offered load) gates EXACTLY, and the deterministic p99s get
            # tight relative guards
            ("fleet", lambda b: b.get("fleet", []), ("arch", "seq", "rate"), {
                "router_beats_rr": ("low", 0.0, 0.0),
                "p99_advantage": ("low", 0.05, 0.0),
                "jsf_p99_ttft": ("high", 0.05, 1e-4),
                "jsf_slo_attainment": ("low", 0.0, 0.0),
                "jsf_completed": ("low", 0.0, 0.0),
            }),
            # shared-prefix workload, radix index on vs off at equal lease
            # budget: seeded Zipf stream + analytic cost model on a virtual
            # clock, so the acceptance bits (prefix-on strictly beats off on
            # p99 TTFT AND admits strictly more concurrent requests) and the
            # hit rate gate EXACTLY; the p99s get tight relative guards
            ("prefix", lambda b: b.get("prefix", []), ("arch", "seq"), {
                "prefix_beats_off": ("low", 0.0, 0.0),
                "admits_more": ("low", 0.0, 0.0),
                "hit_rate": ("low", 0.0, 0.0),
                "p99_advantage": ("low", 0.05, 0.0),
                "on_p99_ttft": ("high", 0.05, 1e-4),
                "on_peak_inflight": ("low", 0.0, 0.0),
            }),
        ],
    },
    "calibration": {
        "baseline": "BENCH_calibration.json",
        "fresh": "calibration.json",
        "tables": [
            ("rows", lambda b: b["rows"], ("arch", "seq"), {
                # the closed-loop contract: post-fit MAPE stays strictly
                # below nominal (ratio < 1, improves == 1 exactly) and the
                # fit keeps recovering the synthetic rate perturbation down
                # to the seeded noise floor — all deterministic inputs, so
                # tight gates
                "mape_ratio": ("high", 0.05, 0.01),
                "mape_calibrated": ("high", 0.25, 0.005),
                "calibrated_improves": ("low", 0.0, 0.0),
                # host-side sentinel cost vs the bare engine loop: same
                # 1.05x contract as telem_overhead
                "health_overhead": ("high", 0.0, 0.05),
            }),
        ],
    },
}


def _num(v) -> float:
    return float(v)  # handles "4.8e-07" strings too


def _check_metric(name: str, base, fresh, spec: MetricSpec):
    """-> (delta_txt, regressed)."""
    direction, rel, floor = spec
    b, f = _num(base), _num(fresh)
    worse = (b - f) if direction == "low" else (f - b)
    allowed = max(rel * abs(b), floor)
    return worse, worse > allowed + 1e-12


def compare_one(name: str, baseline_dir: str = ROOT,
                fresh_dir: str = FRESH_DIR) -> Tuple[List[dict], bool]:
    spec = SPECS[name]
    bpath = os.path.join(baseline_dir, spec["baseline"])
    fpath = os.path.join(fresh_dir, spec["fresh"])
    if not os.path.exists(bpath):
        return [{"table": name, "key": "-", "metric": "(baseline missing)",
                 "baseline": bpath, "fresh": "", "delta": "",
                 "verdict": "FAIL"}], True
    if not os.path.exists(fpath):
        return [{"table": name, "key": "-", "metric": "(fresh run missing)",
                 "baseline": "", "fresh": fpath, "delta": "",
                 "verdict": "FAIL"}], True
    base_blob = json.load(open(bpath))
    fresh_blob = json.load(open(fpath))
    deltas, regressed = [], False
    for label, getter, key_fields, metrics in spec["tables"]:
        fresh_rows = {tuple(str(r[k]) for k in key_fields): r
                      for r in getter(fresh_blob)}
        for brow in getter(base_blob):
            key = tuple(str(brow[k]) for k in key_fields)
            frow = fresh_rows.get(key)
            if frow is None:
                regressed = True
                deltas.append({"table": f"{name}.{label}",
                               "key": "/".join(key), "metric": "(row)",
                               "baseline": "present", "fresh": "MISSING",
                               "delta": "", "verdict": "FAIL"})
                continue
            for metric, mspec in metrics.items():
                if metric not in brow:
                    continue  # baseline predates the metric: nothing to gate
                if metric not in frow:
                    regressed = True
                    deltas.append({"table": f"{name}.{label}",
                                   "key": "/".join(key), "metric": metric,
                                   "baseline": brow[metric],
                                   "fresh": "MISSING", "delta": "",
                                   "verdict": "FAIL"})
                    continue
                worse, bad = _check_metric(metric, brow[metric],
                                           frow[metric], mspec)
                regressed |= bad
                deltas.append({"table": f"{name}.{label}",
                               "key": "/".join(key), "metric": metric,
                               "baseline": brow[metric],
                               "fresh": frow[metric],
                               "delta": f"{-worse:+.4g}"
                                        if mspec[0] == "low"
                                        else f"{worse:+.4g}",
                               "verdict": "FAIL" if bad else "ok"})
    return deltas, regressed


def update_baselines(names, fresh_dir: str = FRESH_DIR,
                     baseline_dir: str = ROOT) -> int:
    """Refuses artifacts not stamped ``"quick": true`` — CI regenerates and
    diffs with ``--quick``, so a baseline refreshed from a full-mode run
    (different row sets / SA budgets) would brick the gate for every
    subsequent PR."""
    rc = 0
    for name in names:
        spec = SPECS[name]
        src = os.path.join(fresh_dir, spec["fresh"])
        dst = os.path.join(baseline_dir, spec["baseline"])
        if json.load(open(src)).get("quick") is not True:
            print(f"REFUSED {dst}: {src} is not a --quick artifact "
                  "(regenerate with `python -m benchmarks.run --quick "
                  f"--only {name}` — the CI gate compares --quick runs)")
            rc = 1
            continue
        shutil.copyfile(src, dst)
        print(f"baseline {dst} <- {src}")
    return rc


def main(argv=None) -> int:
    from benchmarks.common import table
    ap = argparse.ArgumentParser()
    ap.add_argument("--names", default="kvstore,attn_backend,sched",
                    help="comma-separated subset of "
                         f"{sorted(SPECS)}")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh artifacts over the committed "
                         "baselines instead of comparing")
    args = ap.parse_args(argv)
    names = [n for n in args.names.split(",") if n]
    unknown = set(names) - set(SPECS)
    if unknown:
        print(f"unknown benchmark names: {sorted(unknown)}")
        return 2
    if args.update:
        return update_baselines(names)
    all_deltas, rc = [], 0
    for name in names:
        deltas, regressed = compare_one(name)
        all_deltas += deltas
        rc |= int(regressed)
    print(table(all_deltas, ["table", "key", "metric", "baseline", "fresh",
                             "delta", "verdict"]))
    n_fail = sum(d["verdict"] == "FAIL" for d in all_deltas)
    if rc:
        print(f"REGRESSION: {n_fail} metric(s) beyond tolerance vs the "
              "committed BENCH_*.json baselines (refresh intentionally with "
              "`python -m benchmarks.compare --update`)")
    else:
        print(f"bench gate PASS: {len(all_deltas)} metrics within tolerance")
    return rc


if __name__ == "__main__":
    sys.exit(main())
