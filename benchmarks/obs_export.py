"""Export a sample merged observability bundle as a CI artifact.

Runs the continuous engine (sim executor, WSC_PAPER profile) with tracing
on and exports everything ``repro.obs`` produces for one serve run:

- ``obs_trace.json``   — the merged Perfetto timeline (scheduler task spans
  + kv_lease_bytes / wire_bytes counter tracks + the health-sentinel alert
  row),
- ``obs_metrics.json`` — the serving metrics as JSON lines (including the
  ``repro_health_*`` alert counters + burn-rate gauge),
- ``obs_metrics.prom`` — the same registry as a Prometheus textfile,
- ``obs_calibrated_profile.json`` — a calibrated-profile sample
  (obs.calibrate.save_profile) that round-trips through
  ``costmodel.resolve_profile`` — what serve/dryrun
  ``--calibrated-profile`` consumes,

so every PR carries a timeline a reviewer can drop into
https://ui.perfetto.dev without rerunning anything. The job FAILS (raises)
if the trace is missing any of the surfaces the merge is supposed to
contain — that is the "one file has everything" contract of DESIGN.md
§Observability (now §8-§9).

  PYTHONPATH=src python -m benchmarks.obs_export [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import OUT_DIR
from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.obs import HealthMonitor, MetricsRegistry
from repro.runtime.engine import (ContinuousEngine, EngineConfig, Request,
                                  SimExecutor)

ARCH = "llama3-70b"


def run(quick: bool = False) -> None:
    cfg = get_config(ARCH)
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                      num_chunks=16, max_batch=4, buckets=(8192, 32768),
                      partition="lbcp", sa_iters=8 if quick else 24,
                      policy="edf", slo=5.0, trace=True)
    executor = SimExecutor(cfg, ec.hw)
    monitor = HealthMonitor()
    eng = ContinuousEngine(ec, executor)
    eng.configure_obs(health=monitor)   # merged_trace/export_obs pick it up
    rng = np.random.default_rng(0)
    n_req = 6 if quick else 12
    for i in range(n_req):
        eng.submit(Request(rid=i, arrival=float(rng.exponential(0.2) * i),
                           seq_len=int(rng.choice(ec.buckets))))
    eng.run_until_drained()

    # drive the host-side sentinels so the bundle shows a NON-empty alert
    # surface: an impossible SLO trips slo_burn, a drifted ledger trips
    # ledger_drift (both deterministic for the seeded arrivals)
    ttft = MetricsRegistry().histogram("ttft")
    for r in eng.records():
        if np.isfinite(r.finish):
            ttft.observe(r.finish - r.arrival)
    monitor.check_slo(ttft, slo_s=1e-6)
    monitor.check_ledger({"ring": 1.10e9}, {"ring": 1.00e9})

    os.makedirs(OUT_DIR, exist_ok=True)
    paths = eng.export_obs(
        trace_out=os.path.join(OUT_DIR, "obs_trace.json"),
        metrics_out=os.path.join(OUT_DIR, "obs_metrics.json"))
    prom = eng.export_obs(
        metrics_out=os.path.join(OUT_DIR, "obs_metrics.prom"))
    paths["prom"] = prom["metrics"]

    evs = json.load(open(paths["trace"]))["traceEvents"]
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    missing = []
    if not any(e["ph"] == "X" and e.get("cat") == "chunk" for e in evs):
        missing.append("scheduler task spans")
    if "kv_lease_bytes" not in counters:
        missing.append("kv_lease_bytes counter track")
    if "wire_bytes" not in counters:
        missing.append("wire_bytes counter track")
    if not any(e["ph"] == "M" for e in evs):
        missing.append("process_name metadata")
    if not any(e["ph"] == "X" and e.get("cat") == "alert" for e in evs):
        missing.append("health-sentinel alert row")
    metric_names = {json.loads(line)["name"]
                    for line in open(paths["metrics"]) if line.strip()}
    if "repro_health_alerts_total" not in metric_names:
        missing.append("repro_health_* metrics")
    if missing:
        raise RuntimeError(f"merged bundle is missing: {missing}")

    # calibrated-profile sample: a synthetic fit against spans generated
    # under a perturbed ground truth (the calibration benchmark's setup),
    # persisted and round-tripped through resolve_profile — the exact
    # artifact serve/dryrun --calibrated-profile accept
    from benchmarks.calibration import NUM_CHUNKS, NUM_STAGES, synth_measured
    from repro.core import mbkr
    from repro.obs import calibrate as cal
    sm = cm.StageModel.build(cfg, NUM_STAGES, 1)
    chunks = [ec.buckets[0] // NUM_CHUNKS] * NUM_CHUNKS
    mplan = mbkr.plan(NUM_CHUNKS, NUM_STAGES)
    fit = cal.fit_profile(sm, chunks, synth_measured(sm, chunks, mplan, 7),
                          cm.WSC_PAPER, mbkr_plan=mplan)
    ppath = cal.save_profile(
        os.path.join(OUT_DIR, "obs_calibrated_profile.json"), fit.profile,
        fit=fit, meta={"arch": ARCH, "source": "benchmarks.obs_export"})
    if cm.resolve_profile(ppath) != fit.profile:
        raise RuntimeError("calibrated-profile JSON did not round-trip "
                           "bit-identically through resolve_profile")
    paths["calibrated_profile"] = ppath

    m = eng.metrics()
    print(f"[obs] {m['completed']} requests | {len(evs)} trace events | "
          f"{len(monitor.alerts)} health alerts | counters {sorted(counters)}")
    for kind, path in paths.items():
        print(f"{kind} -> {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
