"""Export a sample merged observability bundle as a CI artifact.

Runs the continuous engine (sim executor, WSC_PAPER profile) with tracing
on and exports everything ``repro.obs`` produces for one serve run:

- ``obs_trace.json``   — the merged Perfetto timeline (scheduler task spans
  + kv_lease_bytes / wire_bytes counter tracks),
- ``obs_metrics.json`` — the serving metrics as JSON lines,
- ``obs_metrics.prom`` — the same registry as a Prometheus textfile,

so every PR carries a timeline a reviewer can drop into
https://ui.perfetto.dev without rerunning anything. The job FAILS (raises)
if the trace is missing any of the surfaces the merge is supposed to
contain — that is the "one file has everything" contract of DESIGN.md
§Observability.

  PYTHONPATH=src python -m benchmarks.obs_export [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import OUT_DIR
from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.runtime.engine import (ContinuousEngine, EngineConfig, Request,
                                  SimExecutor)

ARCH = "llama3-70b"


def run(quick: bool = False) -> None:
    cfg = get_config(ARCH)
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                      num_chunks=16, max_batch=4, buckets=(8192, 32768),
                      partition="lbcp", sa_iters=8 if quick else 24)
    eng = ContinuousEngine(ec, SimExecutor(cfg, ec.hw), policy="edf",
                           slo=5.0, trace=True)
    rng = np.random.default_rng(0)
    n_req = 6 if quick else 12
    for i in range(n_req):
        eng.submit(Request(rid=i, arrival=float(rng.exponential(0.2) * i),
                           seq_len=int(rng.choice(ec.buckets))))
    eng.run_until_drained()

    os.makedirs(OUT_DIR, exist_ok=True)
    paths = eng.export_obs(
        trace_out=os.path.join(OUT_DIR, "obs_trace.json"),
        metrics_out=os.path.join(OUT_DIR, "obs_metrics.json"))
    prom = eng.export_obs(
        metrics_out=os.path.join(OUT_DIR, "obs_metrics.prom"))
    paths["prom"] = prom["metrics"]

    evs = json.load(open(paths["trace"]))["traceEvents"]
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    missing = []
    if not any(e["ph"] == "X" and e.get("cat") == "chunk" for e in evs):
        missing.append("scheduler task spans")
    if "kv_lease_bytes" not in counters:
        missing.append("kv_lease_bytes counter track")
    if "wire_bytes" not in counters:
        missing.append("wire_bytes counter track")
    if not any(e["ph"] == "M" for e in evs):
        missing.append("process_name metadata")
    if missing:
        raise RuntimeError(f"merged trace is missing: {missing}")
    m = eng.metrics()
    print(f"[obs] {m['completed']} requests | {len(evs)} trace events | "
          f"counters {sorted(counters)}")
    for kind, path in paths.items():
        print(f"{kind} -> {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
