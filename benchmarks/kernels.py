"""Kernel micro-benchmarks: wall time of the interpret-mode Pallas kernels is
meaningless on CPU, so this reports (a) correctness deltas vs the oracle and
(b) the ANALYTIC TPU-v5e time model per kernel call (bytes/flops through the
roofline constants) — the numbers the §Perf iterations reason with."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, table
from repro.kernels import ops, ref
from repro.roofline.analysis import HW_V5E


def _tpu_time(flops, bytes_):
    return max(flops / HW_V5E["peak_flops"], bytes_ / HW_V5E["hbm_bw"])


def run():
    rows = []
    key = jax.random.key(0)

    # chunk attention: MOCAP hot spot at production shape
    for (b, c, h, kvh, d, p) in [(1, 2048, 32, 8, 128, 0),
                                 (1, 2048, 32, 8, 128, 30720)]:
        t = p + c
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, c, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, t, kvh, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, t, kvh, d), jnp.float32)
        small = (b, 128, h, kvh, d, min(p, 256))
        qs = q[:, :128]
        ksm = k[:, :small[5] + 128]
        vsm = v[:, :small[5] + 128]
        err = float(jnp.max(jnp.abs(
            ops.chunk_attention(qs, ksm, vsm, causal_offset=small[5])
            - ref.chunk_attention_ref(qs, ksm, vsm, causal_offset=small[5]))))
        flops = 4.0 * b * c * (p + c / 2) * h * d
        bytes_ = (q.size + 2 * b * t * kvh * d + q.size) * 2  # bf16 on TPU
        rows.append({
            "kernel": "chunk_attn", "shape": f"b{b} c{c} p{p} h{h}/{kvh} d{d}",
            "max_err_small": f"{err:.1e}",
            "tpu_flops": f"{flops:.3g}", "tpu_bytes": f"{bytes_:.3g}",
            "tpu_time_us": round(_tpu_time(flops, bytes_) * 1e6, 1),
            "bound": "compute" if flops / HW_V5E["peak_flops"] >
                     bytes_ / HW_V5E["hbm_bw"] else "memory",
        })

    # ssd
    b, t, h, p_, g, n, ck = 1, 2048, 24, 64, 1, 128, 256
    flops = 2 * b * t * (h * p_ * n * 3)       # diag + state + out, approx
    bytes_ = b * t * (h * p_ + 2 * g * n + h) * 2 * 2
    rows.append({
        "kernel": "ssd", "shape": f"b{b} t{t} h{h} p{p_} n{n} chunk{ck}",
        "max_err_small": "see tests", "tpu_flops": f"{flops:.3g}",
        "tpu_bytes": f"{bytes_:.3g}",
        "tpu_time_us": round(_tpu_time(flops, bytes_) * 1e6, 1),
        "bound": "compute" if flops / HW_V5E["peak_flops"] >
                 bytes_ / HW_V5E["hbm_bw"] else "memory",
    })

    # decode attention: memory-bound by definition
    b, h, kvh, d, s = 128, 32, 8, 128, 32768
    flops = 4.0 * b * s * h * d
    bytes_ = 2 * b * s * kvh * d * 2
    rows.append({
        "kernel": "decode_attn", "shape": f"b{b} s{s} h{h}/{kvh} d{d}",
        "max_err_small": "see tests", "tpu_flops": f"{flops:.3g}",
        "tpu_bytes": f"{bytes_:.3g}",
        "tpu_time_us": round(_tpu_time(flops, bytes_) * 1e6, 1),
        "bound": "memory",
    })
    return rows


def main():
    rows = run()
    print(table(rows, ["kernel", "shape", "max_err_small", "tpu_flops",
                       "tpu_bytes", "tpu_time_us", "bound"]))
    emit("kernels", rows)
    return rows


if __name__ == "__main__":
    main()
