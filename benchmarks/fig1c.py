"""Paper Fig. 1(c): WSC vs GPU-system E2E prefill latency under equivalent
compute/memory — the CONVENTIONAL tensor-parallel mapping, where each layer
issues 2 activation all-reduces whose size grows with sequence length. That
is the communication wall the paper motivates with (46.8% reduction on WSC);
the MOCAP pipeline then removes most of that traffic on either substrate
(also reported below).
"""
from __future__ import annotations

from benchmarks.common import emit, table
from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.sim import SimConfig, simulate


def tp_prefill_latency(cfg, s_len: int, hw: cm.HardwareProfile) -> dict:
    """Analytic full-TP prefill: all dies tensor-parallel, no pipeline.
    Per layer: Megatron's 2 ring all-reduces of the [S, d] activation."""
    n = hw.num_dies
    flops = 2.0 * cfg.active_param_count() * s_len \
        + 4.0 * s_len * (s_len / 2) * cfg.num_heads * cfg.resolved_head_dim \
        * cm.attn_layers(cfg)
    t_compute = flops / (n * hw.flops * hw.gemm_eff)
    ar_bytes = s_len * cfg.d_model * 2
    wire = 2 * ar_bytes * (n - 1) / n          # ring all-reduce per device
    n_ar = 2 * cfg.num_layers
    t_comm = n_ar * wire / (hw.link_bw * hw.link_eff)
    return {"compute_s": t_compute, "comm_s": t_comm,
            "total_s": t_compute + t_comm}


def run():
    rows = []
    cfg = get_config("llama3-70b")
    for s in (65536, 131072, 262144):
        gpu = tp_prefill_latency(cfg, s, cm.GPU_HGX)
        wsc = tp_prefill_latency(cfg, s, cm.WSC_PAPER)
        red = 1 - wsc["total_s"] / gpu["total_s"]
        mocap = simulate(SimConfig(scheduler="mocap", model=cfg,
                                   hw=cm.WSC_PAPER, seq_len=s, batch=1,
                                   partition="lbcp", sa_iters=40))
        rows.append({
            "seq_len": s,
            "gpu_tp_total_s": round(gpu["total_s"], 3),
            "gpu_comm_frac": round(gpu["comm_s"] / gpu["total_s"], 3),
            "wsc_tp_total_s": round(wsc["total_s"], 3),
            "wsc_reduction": round(red, 4),
            "wsc_mocap_s": round(mocap.e2e_latency, 3),
        })
    return rows


def main():
    rows = run()
    print(table(rows, ["seq_len", "gpu_tp_total_s", "gpu_comm_frac",
                       "wsc_tp_total_s", "wsc_reduction", "wsc_mocap_s"]))
    avg = sum(r["wsc_reduction"] for r in rows) / len(rows)
    print(f"avg WSC latency reduction {avg*100:.1f}% under the conventional "
          f"TP mapping (paper Fig 1(c): 46.8%); MOCAP then removes the "
          f"remaining comm wall on either substrate")
    emit("fig1c", rows)
    return rows


if __name__ == "__main__":
    main()
