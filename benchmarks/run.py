"""Benchmark driver: one module per paper table/figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Jobs whose backend prerequisites are unavailable are SKIPPED, not crashed:
jobs that lower the real chunked pipeline with a GSPMD-auto TP axis need
partial-auto SPMD inside shard_map, which old jaxlib rejects at lowering
time ("UNIMPLEMENTED: PartitionId") — ``compat.supports_partial_auto_spmd``
is the gate.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller SA budgets / fewer probes")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig6a,fig6b,fig1c,"
                         "lbcp_ablation,kernels,attn_backend,roofline,sched,"
                         "kvstore,kvstore_pipeline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import attn_backend, fig1c, fig6a, fig6b, kernels
    from benchmarks import kvstore as kvstore_bench
    from benchmarks import lbcp_ablation, roofline_report, sched_throughput
    from repro import compat

    # (name, description, fn, needs_partial_auto_spmd)
    jobs = [
        ("sched", "Continuous chunk-level scheduling vs batch-synchronous",
         lambda: sched_throughput.main(quick=args.quick), False),
        ("attn_backend", "jnp vs pallas attention-backend comparison",
         lambda: attn_backend.run(quick=args.quick), False),
        ("kvstore", "KV page store: max seq len vs kv_dtype + tier headroom",
         lambda: kvstore_bench.run(quick=args.quick), False),
        ("kvstore_pipeline", "Real-pipeline paged-pool bytes + wall time "
         "(TP-sharded pool)",
         lambda: kvstore_bench.pipeline_leg(quick=args.quick), True),
        ("fig6a", "Fig 6(a): E2E latency/throughput vs GPipe & Terapipe",
         fig6a.main, False),
        ("fig6b", "Fig 6(b): max sequence length vs Terapipe x #chunks",
         fig6b.main, False),
        ("fig1c", "Fig 1(c): WSC vs GPU-system communication advantage",
         fig1c.main, False),
        ("lbcp_ablation", "LBCP ablation + stagger-collapse study",
         lbcp_ablation.main, False),
        ("kernels", "Pallas kernel correctness + analytic TPU timing",
         kernels.main, False),
        ("roofline", "Roofline report from the dry-run artifacts",
         roofline_report.main, False),
    ]
    rc = 0
    ran = skipped = 0
    for name, desc, fn, needs_spmd in jobs:
        if only and name not in only:
            continue
        print(f"\n================ {name}: {desc} ================",
              flush=True)
        if needs_spmd and not compat.supports_partial_auto_spmd():
            skipped += 1
            print(f"[{name} SKIP: installed jaxlib cannot partition "
                  "partial-auto shard_map (PartitionId); rerun on jax >= "
                  "the jax.shard_map release]")
            continue
        ran += 1
        t0 = time.time()
        try:
            fn()
            print(f"[{name} done in {time.time()-t0:.1f}s]")
        except Exception as e:  # noqa: BLE001
            rc = 1
            import traceback
            traceback.print_exc()
            print(f"[{name} FAILED: {e}]")
    if ran == 0:
        # every selected job was gated away (or --only matched nothing):
        # an empty artifact set must FAIL the caller, not ride a green exit
        # to the upload step
        print(f"\nERROR: 0 of {skipped} selected job(s) ran "
              f"({'all SKIPPED' if skipped else '--only matched no jobs'})",
              flush=True)
        return 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
