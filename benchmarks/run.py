"""Benchmark driver: one module per paper table/figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller SA budgets / fewer probes")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig6a,fig6b,fig1c,"
                         "lbcp_ablation,kernels,attn_backend,roofline,sched")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import attn_backend, fig1c, fig6a, fig6b, kernels
    from benchmarks import lbcp_ablation, roofline_report, sched_throughput

    jobs = [
        ("sched", "Continuous chunk-level scheduling vs batch-synchronous",
         lambda: sched_throughput.main(quick=args.quick)),
        ("attn_backend", "jnp vs pallas attention-backend comparison",
         lambda: attn_backend.run(quick=args.quick)),
        ("fig6a", "Fig 6(a): E2E latency/throughput vs GPipe & Terapipe",
         fig6a.main),
        ("fig6b", "Fig 6(b): max sequence length vs Terapipe x #chunks",
         fig6b.main),
        ("fig1c", "Fig 1(c): WSC vs GPU-system communication advantage",
         fig1c.main),
        ("lbcp_ablation", "LBCP ablation + stagger-collapse study",
         lbcp_ablation.main),
        ("kernels", "Pallas kernel correctness + analytic TPU timing",
         kernels.main),
        ("roofline", "Roofline report from the dry-run artifacts",
         roofline_report.main),
    ]
    rc = 0
    for name, desc, fn in jobs:
        if only and name not in only:
            continue
        print(f"\n================ {name}: {desc} ================",
              flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"[{name} done in {time.time()-t0:.1f}s]")
        except Exception as e:  # noqa: BLE001
            rc = 1
            import traceback
            traceback.print_exc()
            print(f"[{name} FAILED: {e}]")
    return rc


if __name__ == "__main__":
    sys.exit(main())
