"""Benchmark driver: one module per paper table/figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Jobs whose backend prerequisites are unavailable are SKIPPED, not crashed,
and the SKIP line names the gating predicate (e.g.
``compat.supports_partial_auto_spmd``) so a matrix-leg log says exactly WHY
a job was gated. Job modules are imported lazily, exactly once, and only
for the jobs actually selected — ``--only kvstore`` no longer pays the
import cost (jax tracing setup included) of every other benchmark module.
"""
from __future__ import annotations

import argparse
import sys
import time
from importlib import import_module

# job name -> (module under benchmarks/, entrypoint attr, description,
#              gating predicate dotted name or None)
JOBS = {
    "sched": ("sched_throughput", "main",
              "Continuous chunk-level scheduling vs batch-synchronous", None),
    "attn_backend": ("attn_backend", "run",
                     "jnp vs pallas attention-backend comparison", None),
    "kvstore": ("kvstore", "run",
                "KV page store: max seq len vs kv_dtype + tier headroom",
                None),
    # was gated on compat.supports_partial_auto_spmd; the manual TP
    # lowering (DESIGN.md §3.6) made tp=2 lower on old jaxlib too
    "kvstore_pipeline": ("kvstore", "pipeline_leg",
                         "Real-pipeline paged-pool bytes + wall time "
                         "(TP-sharded pool)", None),
    "fig6a": ("fig6a", "main",
              "Fig 6(a): E2E latency/throughput vs GPipe & Terapipe", None),
    "fig6b": ("fig6b", "main",
              "Fig 6(b): max sequence length vs Terapipe x #chunks", None),
    "fig1c": ("fig1c", "main",
              "Fig 1(c): WSC vs GPU-system communication advantage", None),
    "lbcp_ablation": ("lbcp_ablation", "main",
                      "LBCP ablation + stagger-collapse study", None),
    "kernels": ("kernels", "main",
                "Pallas kernel correctness + analytic TPU timing", None),
    "roofline": ("roofline_report", "main",
                 "Roofline report from the dry-run artifacts", None),
    "obs": ("obs_export", "run",
            "Merged Perfetto trace + metrics exporter sample artifacts",
            None),
    "calibration": ("calibration", "run",
                    "Cost-model calibration MAPE + health-sentinel overhead "
                    "(sim-backed, deterministic)", None),
}

_QUICK_AWARE = {"sched", "attn_backend", "kvstore", "kvstore_pipeline",
                "obs", "calibration"}


def _gate(predicate: str) -> bool:
    """Evaluate a dotted gating predicate from ``repro.compat``."""
    from repro import compat
    assert predicate.startswith("compat."), predicate
    return bool(getattr(compat, predicate.split(".", 1)[1])())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller SA budgets / fewer probes")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: " + ",".join(JOBS))
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(JOBS)
        if unknown:
            # a typo'd job name must not ride the "0 ran" path with the
            # all-SKIPPED message — name the bad names and the valid set
            print(f"ERROR: unknown job name(s) {sorted(unknown)}; "
                  f"valid: {','.join(JOBS)}")
            return 2

    rc = 0
    ran = skipped = 0
    modules = {}  # one import pass: each selected module imported ONCE
    for name, (mod_name, attr, desc, predicate) in JOBS.items():
        if only and name not in only:
            continue
        print(f"\n================ {name}: {desc} ================",
              flush=True)
        if predicate is not None and not _gate(predicate):
            skipped += 1
            print(f"[{name} SKIP: gated on {predicate}() == False — "
                  "installed jaxlib cannot partition partial-auto shard_map "
                  "(PartitionId); rerun on jax >= the jax.shard_map release]")
            continue
        if mod_name not in modules:
            modules[mod_name] = import_module(f"benchmarks.{mod_name}")
        fn = getattr(modules[mod_name], attr)
        ran += 1
        t0 = time.time()
        try:
            if name in _QUICK_AWARE:
                fn(quick=args.quick)
            else:
                fn()
            print(f"[{name} done in {time.time()-t0:.1f}s]")
        except Exception as e:  # noqa: BLE001
            rc = 1
            import traceback
            traceback.print_exc()
            print(f"[{name} FAILED: {e}]")
    if ran == 0:
        # every selected job was gated away (or --only matched nothing):
        # an empty artifact set must FAIL the caller, not ride a green exit
        # to the upload step
        print(f"\nERROR: 0 of {skipped} selected job(s) ran "
              f"({'all SKIPPED' if skipped else '--only matched no jobs'})",
              flush=True)
        return 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
