"""Transport-layer tests (DESIGN.md §3.6): the pipeline path is grep-clean
of raw collectives, fetch-vs-qship logits agree, the runtime CollectiveLedger
matches the §3.4 analytic traffic model within 1%, batched fetch equals
streamed fetch at 1e-6 with O(1) attention launches per tick, and the manual
TP lowering (forced, so it is exercised on BOTH jaxlib legs) matches the
full-forward oracle."""
import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(snippet, extra_env=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "PASS" in r.stdout, r.stdout
    return r.stdout


# ------------------------------------------------- protocol surface / hygiene

def test_no_raw_collectives_in_pipeline_path():
    """Acceptance: zero raw ppermute/psum call sites outside
    core/transport.py in the pipeline path."""
    core = os.path.join(ROOT, "src", "repro", "core")
    pat = re.compile(r"jax\.lax\.(ppermute|psum|psum_scatter|all_gather)\b")
    for name in ("remote.py", "pipeline.py", "gpipe.py", "stagestep.py"):
        src = open(os.path.join(core, name)).read()
        hits = pat.findall(src)
        assert not hits, f"raw collectives in core/{name}: {hits}"


def test_transport_registry():
    from repro.core import transport as tx
    tr = tx.get_transport("jax")
    assert tr.name == "jax"
    assert "jax" in tx.available_transports()
    with pytest.raises(KeyError):
        tx.get_transport("nope")


def test_resolve_tp_lowering(monkeypatch):
    from repro import compat
    assert compat.resolve_tp_lowering("manual") == "manual"
    monkeypatch.setenv("REPRO_TP_LOWERING", "manual")
    assert compat.resolve_tp_lowering("auto") == "manual"
    monkeypatch.setenv("REPRO_TP_LOWERING", "auto")
    assert compat.resolve_tp_lowering("auto") == "auto"
    monkeypatch.delenv("REPRO_TP_LOWERING")
    expected = "auto" if compat.supports_partial_auto_spmd() else "manual"
    assert compat.resolve_tp_lowering("auto") == expected
    with pytest.raises(ValueError):
        compat.resolve_tp_lowering("gspmd")


def test_analytic_model_shapes():
    """Closed-form totals react to the knobs the §3.4 model prices."""
    from repro.configs.base import RunConfig, get_smoke_config, replace
    from repro.core import pipeline as pp
    from repro.core import transport as tx
    cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
    run_f = RunConfig(num_chunks=8, num_stages=8, remote_attn="fetch")
    run_q = RunConfig(num_chunks=8, num_stages=8, remote_attn="qship")
    wf = tx.analytic_wire_bytes(pp.build_plan(cfg, 8, 128, run_f), cfg, 2)
    wq = tx.analytic_wire_bytes(pp.build_plan(cfg, 8, 128, run_q), cfg, 2)
    assert wf["fetch"] > 0 and wf["qship_q"] == 0
    assert wq["qship_q"] > 0 and wq["fetch"] == 0
    assert wf["ring"] == wq["ring"] > 0
    assert wf["spill"] == wq["spill"] > 0
    # int8 codec compresses the spill/fetch wire, not the activation ring
    run_i8 = RunConfig(num_chunks=8, num_stages=8, remote_attn="fetch",
                       kv_dtype="int8", kv_page_tokens=8)
    wi = tx.analytic_wire_bytes(pp.build_plan(cfg, 8, 128, run_i8), cfg, 2)
    assert wi["fetch"] < wf["fetch"] and wi["spill"] < wf["spill"]
    assert wi["ring"] == wf["ring"]
    # terapipe: no MBKR traffic at all
    wt = tx.analytic_wire_bytes(
        pp.build_plan(cfg, 8, 128, run_f, mode="terapipe"), cfg, 2)
    assert wt["spill"] == wt["fetch"] == wt["qship_q"] == 0
    # ragged-occupancy variant (paged pool path): all-resident == the dense
    # closed form EXACTLY; partially-resident chunks shed wire on the paged
    # categories (spill/fetch) and nothing else
    plan_i8 = pp.build_plan(cfg, 8, 128, run_i8)
    ppc = plan_i8.pages_per_chunk
    assert ppc > 1  # the ragged model needs sub-chunk granularity to price
    wfull = tx.analytic_wire_bytes(plan_i8, cfg, 2,
                                   resident_pages=[ppc] * plan_i8.num_chunks)
    assert wfull == wi
    wrag = tx.analytic_wire_bytes(plan_i8, cfg, 2,
                                  resident_pages=[1] * plan_i8.num_chunks)
    assert 0 < wrag["fetch"] < wi["fetch"]
    assert 0 < wrag["spill"] < wi["spill"]
    assert wrag["ring"] == wi["ring"] and wrag["collect"] == wi["collect"]
    # per-chunk pricing: only the spilled chunks' residency matters, and a
    # single full chunk among them sits strictly between the extremes
    mixed = [1] * (plan_i8.num_chunks - 1) + [ppc]
    wmix = tx.analytic_wire_bytes(plan_i8, cfg, 2, resident_pages=mixed)
    assert wrag["spill"] < wmix["spill"] < wi["spill"]
    only_early = [ppc] * plan_i8.p2 + [1] * (plan_i8.num_chunks - plan_i8.p2)
    wearly = tx.analytic_wire_bytes(plan_i8, cfg, 2, resident_pages=only_early)
    assert wearly["spill"] == wrag["spill"]  # chunks < p2 never spill


# ---------------------------------------- runtime ledger vs the §3.4 model

SNIPPET_LEDGER = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.core import transport as tx
from repro.models.api import build_model
from repro.models.topology import Topology

# deep geometry (8 stages, p2 = 6 < M-1) so remote chunks are CONSUMED
cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"), axis_types=(AxisType.Auto,)*2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

outs = {}
for remote in ("fetch", "qship"):
    run = RunConfig(num_chunks=m, num_stages=n, remote_attn=remote)
    plan = pp.build_plan(cfg, n, s, run)
    staged = pp.stage_params(cfg, params, plan)
    with compat.set_mesh(mesh):
        out, led = jax.jit(lambda st, tk: pp.prefill_pipeline(
            cfg, st, tk, plan, topo, return_ledger=True))(staged, toks)
    led = tx.ledger_to_dict(led)
    model_bytes = tx.analytic_wire_bytes(plan, cfg, b)
    for key, expect in model_bytes.items():
        got = led[key]
        if expect == 0.0:
            assert got == 0.0, (remote, key, got)
        else:
            rel = abs(got - expect) / expect
            assert rel < 0.01, (remote, key, got, expect, rel)
    assert led["tp"] == 0.0  # tp=1: no manual TP collectives
    outs[remote] = np.asarray(out)
    print(remote, {k: round(v) for k, v in led.items()})

# fetch-vs-qship logits parity (same math, different combine route)
rel = np.max(np.abs(outs["fetch"] - outs["qship"])
             / (np.abs(outs["fetch"]) + 1e-3))
assert rel < 1e-3, rel
print("PASS", rel)
"""


def test_ledger_matches_analytic_and_fetch_qship_parity():
    _run(SNIPPET_LEDGER)


SNIPPET_LEDGER_INT8 = r"""
import jax, jax.numpy as jnp
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.core import transport as tx
from repro.models.api import build_model
from repro.models.topology import Topology

cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"), axis_types=(AxisType.Auto,)*2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
run = RunConfig(num_chunks=m, num_stages=n, remote_attn="fetch",
                kv_dtype="int8", kv_page_tokens=8)
plan = pp.build_plan(cfg, n, s, run)
staged = pp.stage_params(cfg, params, plan)
with compat.set_mesh(mesh):
    out, led = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo, return_ledger=True))(staged, toks)
led = tx.ledger_to_dict(led)
model_bytes = tx.analytic_wire_bytes(plan, cfg, b)
for key in ("fetch", "spill", "ring"):
    expect = model_bytes[key]
    rel = abs(led[key] - expect) / expect
    assert rel < 0.01, (key, led[key], expect)
print("PASS quantized ledger", {k: round(v) for k, v in led.items()})
"""


def test_ledger_quantized_wire():
    """The ledger counts the ENCODED wire (int8 payload + fp32 scales), and
    the analytic model agrees — quantized-aware accounting."""
    _run(SNIPPET_LEDGER_INT8)


# -------------------------------------------------------- batched fetch

SNIPPET_BATCHED_FETCH = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.kernels import ops
from repro.models.api import build_model
from repro.models.topology import Topology

# 8 stages -> p2 = 6: TWO remote chunk-layers land per (layer, tick)
cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"), axis_types=(AxisType.Auto,)*2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

outs, launches = {}, {}
for fb in ("off", "on"):
    run = RunConfig(num_chunks=m, num_stages=n, remote_attn="fetch",
                    attn_backend="pallas", fetch_batch=fb)
    plan = pp.build_plan(cfg, n, s, run)
    n_remote = m - plan.p2
    assert n_remote >= 2, n_remote
    staged = pp.stage_params(cfg, params, plan)
    with compat.set_mesh(mesh):
        fn = jax.jit(lambda st, tk: pp.prefill_pipeline(
            cfg, st, tk, plan, topo))
        with ops.count_launches() as lc:
            out = fn(staged, toks)
            out.block_until_ready()
        launches[fb] = lc["count"]
        outs[fb] = np.asarray(fn(staged, toks))

# batched == streamed numerics at 1e-6 (same kernel, combine moved into the
# slot grid — the pool-batched reconciliation bound)
diff = float(np.max(np.abs(outs["on"] - outs["off"])))
assert diff < 1e-6, diff

# O(1) attention launches per tick for the fetch part: per (tick, layer)
# the streamed path launches one chunk_attention per landed chunk, the
# batched path ONE pool_attention regardless of n_remote (count_launches
# counts per traced program, SPMD-wide)
ticks, lps = m + n - 1, plan.layers_per_stage
# streamed: self + own-pool + n_remote fetch; batched: self + own-pool + 1
assert launches["off"] == ticks * lps * (2 + n_remote), launches
assert launches["on"] == ticks * lps * 3, launches
print("PASS", diff, launches)
"""


def test_batched_fetch_parity_and_launch_count():
    """Acceptance: batched fetch == streamed fetch at 1e-6, and
    ``count_launches`` pins the batched path at O(1) attention launches per
    (layer, tick) when >= 2 chunks land."""
    _run(SNIPPET_BATCHED_FETCH)


# -------------------------------------------------- manual TP lowering

SNIPPET_MANUAL_TP = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.core import transport as tx
from repro.models.api import build_model
from repro.models.topology import Topology
from jax.sharding import NamedSharding

cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, tp, m, s, b = 4, 2, 8, 128, 2
mesh = compat.make_mesh((n, tp), ("data", "model"), axis_types=(AxisType.Auto,)*2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
ref = np.asarray(model.forward(params, toks)[:, -1, :].astype(jnp.float32))

run = RunConfig(num_chunks=m, num_stages=n, tp_lowering="manual")
plan = pp.build_plan(cfg, n, s, run)
assert plan.tp_lowering == "manual"
staged = pp.stage_params(cfg, params, plan)
specs = pp.stage_param_specs(cfg, plan, topo)
staged = {k: (jax.tree.map(lambda a, sp: jax.device_put(
                  a, NamedSharding(mesh, sp)), staged[k], specs[k],
              is_leaf=lambda x: hasattr(x, "shape"))
              if k in specs else staged[k]) for k in staged}
with compat.set_mesh(mesh):
    out, led = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo, return_ledger=True))(staged, toks)
led = tx.ledger_to_dict(led)
out = np.asarray(out.astype(jnp.float32))
rel = np.max(np.abs(out - ref) / (np.abs(ref) + 1e-3))
assert rel < 2e-3, rel
# the manual lowering's explicit TP psums are on the ledger
assert led["tp"] > 0, led
# stage-pair wire categories stay at the logical totals (kv/q/state are
# genuinely sharded across tp chips; the ledger psum restores the total)
model_bytes = tx.analytic_wire_bytes(plan, cfg, b)
for key in ("spill", "qship_q", "qship_state"):
    expect = model_bytes[key]
    if expect == 0.0:
        assert led[key] == 0.0, (key, led[key])
    else:
        rel_b = abs(led[key] - expect) / expect
        assert rel_b < 0.01, (key, led[key], expect)
assert led["spill"] > 0  # shallow mocap still spills chunk M-1
# the replicated activation ring is genuinely sent by every tp chip
assert abs(led["ring"] - tp * model_bytes["ring"]) / model_bytes["ring"] < 0.01
print("PASS manual", rel, {k: round(v) for k, v in led.items()})
"""


def test_manual_tp_lowering_forced():
    """Force ``tp_lowering="manual"`` at tp=2 (so the manual path is
    exercised even on jaxlibs where "auto" resolves to GSPMD) and pin the
    oracle numerics plus the ledger's manual-TP accounting."""
    _run(SNIPPET_MANUAL_TP)


# -------------------------------------------------- paged pool backend

SNIPPET_PAGED_LEDGER = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.core import transport as tx
from repro.kernels import ops
from repro.models.api import build_model
from repro.models.topology import Topology

# deep geometry (p2 = 6 < M-1) so the paged kernel runs on BOTH pool paths:
# the own-pool scan and the batched-fetch landing buffer
cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"), axis_types=(AxisType.Auto,)*2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

outs, launches = {}, {}
for pool in ("pallas", "paged"):
    run = RunConfig(num_chunks=m, num_stages=n, remote_attn="fetch",
                    attn_backend="pallas", pool_backend=pool,
                    kv_dtype="int8", kv_page_tokens=8)
    plan = pp.build_plan(cfg, n, s, run)
    assert plan.pool_backend == pool
    staged = pp.stage_params(cfg, params, plan)
    with compat.set_mesh(mesh):
        fn = jax.jit(lambda st, tk: pp.prefill_pipeline(
            cfg, st, tk, plan, topo, return_ledger=True))
        with ops.count_launches() as lc:
            out, led = fn(staged, toks)
            out.block_until_ready()
        launches[pool] = dict(lc)
    outs[pool] = np.asarray(out)
    if pool == "paged":
        # wire traffic is IDENTICAL under the paged kernel: it changes the
        # consumer-side HBM layout, not what crosses the interconnect — the
        # ledger still pins against the ragged model at full occupancy,
        # which equals the dense closed form
        led = tx.ledger_to_dict(led)
        model_bytes = tx.analytic_wire_bytes(
            plan, cfg, b, resident_pages=[plan.pages_per_chunk] * m)
        for key in ("fetch", "spill", "ring"):
            expect = model_bytes[key]
            rel = abs(led[key] - expect) / expect
            assert rel < 0.01, (key, led[key], expect)

# paged == gathered numerics (identical int8 pages, fp32-rounding bound)
diff = float(np.max(np.abs(outs["paged"] - outs["pallas"])))
assert diff < 1e-6, diff

# launch accounting: the paged run routes EVERY pool-sourced partial (own
# pool + batched fetch) through pool_attention_paged and never launches the
# gathered kernel; totals stay O(1) per (layer, tick)
ticks, lps = m + n - 1, pp.build_plan(
    cfg, n, s, RunConfig(num_chunks=m, num_stages=n)).layers_per_stage
for pool in ("pallas", "paged"):
    assert launches[pool]["count"] == ticks * lps * 3, launches
    assert launches[pool]["chunk_attention"] == ticks * lps, launches
assert launches["pallas"]["pool_attention"] == ticks * lps * 2, launches
assert "pool_attention_paged" not in launches["pallas"], launches
assert launches["paged"]["pool_attention_paged"] == ticks * lps * 2, launches
assert "pool_attention" not in launches["paged"], launches
print("PASS paged ledger", diff, launches["paged"])
"""


def test_paged_pool_ledger_parity_and_launches():
    """End-to-end paged pool backend: logits match the gathered pallas pool
    at 1e-6 on identical int8 pages, the CollectiveLedger pins against the
    ragged analytic model at full occupancy, and every pool launch carries
    the ``pool_attention_paged`` tag with zero gathered-kernel launches."""
    _run(SNIPPET_PAGED_LEDGER)
