"""Shared launcher for the pipeline_check.py subprocess worker — the one
place that knows its argv contract (arch mode remote [spill] [deep]
[backend] [kv_dtype] [page_tokens]) and the fake-device environment it
needs."""
import os
import subprocess
import sys

_HELPERS = os.path.dirname(__file__)
_ROOT = os.path.join(_HELPERS, "..", "..")
_WORKER = os.path.join(_HELPERS, "pipeline_check.py")


def run_pipeline_check(arch, mode, remote, spill="bfloat16", deep=False,
                       backend="jnp", kv_dtype="auto", page_tokens=0,
                       expect="PASS"):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    cmd = [sys.executable, _WORKER, arch, mode, remote, spill,
           "deep" if deep else "", backend, kv_dtype, str(page_tokens)]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, f"{arch}/{mode}/{remote}:\n{r.stdout}\n{r.stderr}"
    assert expect in r.stdout
