"""Subprocess worker: chunked-pipeline vs full-forward equivalence on N fake
devices. Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Usage: python tests/helpers/pipeline_check.py <arch> <mode> <remote_attn> [spill_dtype]
Prints "PASS <max_err>" or raises.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology


def main(arch: str, mode: str, remote_attn: str, spill_dtype: str = "bfloat16",
         deep: str = ""):
    cfg = replace(get_smoke_config(arch), dtype="float32")
    if cfg.moe is not None:
        # chunked dispatch uses PER-CHUNK capacity; lift it so no tokens drop
        # and the pipeline is exactly comparable to the full-sequence oracle.
        from repro.configs.base import MoEConfig
        import dataclasses
        cfg = replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    # "deep": 8 stages x tp 1 -> p2 = 6 < M-1, so REMOTE chunk 6 is actually
    # consumed by chunk 7's attention (exercises fetch/qship VALUES and the
    # int8 wire quantization, not just their masking)
    n_stages, tp = (8, 1) if deep else (4, 2)
    mesh = jax.make_mesh((n_stages, tp), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    topo = Topology(mesh=mesh)
    m_chunks, c = 8, 16
    s = m_chunks * c
    b = 2
    if mode == "gpipe":
        b, m_chunks = 8, 4  # microbatch pipeline splits the BATCH

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

    kw = {}
    n_front = 0
    if cfg.family == "encdec" or cfg.frontend.kind != "none":
        n_front = c * 2 + 5  # deliberately NOT chunk-aligned (splice test)
        kw["embeds"] = jax.random.normal(
            jax.random.key(2), (b, n_front, cfg.d_model), jnp.float32) * 0.02
        if cfg.frontend.kind == "vision_stub":
            tokens = tokens[:, : s - n_front]  # embeds splice in front

    # oracle: full forward, last-token logits
    ref = model.forward(params, tokens, **kw)
    ref_last = ref[:, -1, :].astype(jnp.float32)

    run = RunConfig(num_chunks=m_chunks, num_stages=n_stages,
                    mbkr=(mode == "mocap"), remote_attn=remote_attn,
                    kv_spill_dtype=spill_dtype)
    plan = pp.build_plan(cfg, n_stages, s if cfg.frontend.kind != "vision_stub"
                         else s, run, mode=mode)
    staged = pp.stage_params(cfg, params, plan)
    specs = pp.stage_param_specs(cfg, plan, topo)

    def to_sharded(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    staged = {k: jax.tree.map(to_sharded, staged[k], specs[k],
                              is_leaf=lambda x: hasattr(x, "shape"))
              if k in specs else staged[k] for k in staged}

    with jax.set_mesh(mesh):
        fn = jax.jit(lambda st, tk, **k: pp.prefill_pipeline(
            cfg, st, tk, plan, topo, **k))
        out = fn(staged, tokens, **kw)
    out = np.asarray(out.astype(jnp.float32))
    ref_last = np.asarray(ref_last)
    err = np.max(np.abs(out - ref_last) / (np.abs(ref_last) + 1e-3))
    tol = 0.05 if spill_dtype == "int8" else 2e-3
    assert err < tol, f"{arch}/{mode}/{remote_attn}: max rel err {err}"
    assert np.isfinite(out).all()
    print(f"PASS {arch} {mode} {remote_attn} {spill_dtype} err={err:.2e}")


if __name__ == "__main__":
    main(*sys.argv[1:])
