"""Subprocess worker: chunked-pipeline vs full-forward equivalence on N fake
devices. Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.

Usage:
  python tests/helpers/pipeline_check.py <arch> <mode> <remote_attn> \
      [spill_dtype] [deep] [backend] [kv_dtype] [page_tokens]

``backend`` (jnp | pallas | both) picks the attention backend (for the ssm
family it also picks the SSD inner loop); ``both`` additionally asserts
jnp-vs-pallas parity directly. ``kv_dtype`` (auto | int8 | fp8) selects the
KV page codec and ``page_tokens`` the page size (0 = one page per chunk).
Prints "PASS <max_err>" or raises.

jax-version note: on old jaxlib (no partial-auto SPMD — see
``repro.compat.supports_partial_auto_spmd``) the shallow 4-stage x tp=2 mesh
cannot lower with GSPMD-auto TP (PartitionId) — ``build_plan`` resolves
``tp_lowering="auto"`` to the MANUAL lowering there (explicit transport
psums, all mesh axes manual; DESIGN.md §3.6), so TP=2 coverage runs on BOTH
jaxlib legs. ``REPRO_TP_LOWERING`` pins the choice (the CI matrix asserts
the manual path is exercised on the old-jaxlib leg).
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology


def main(arch: str, mode: str, remote_attn: str, spill_dtype: str = "bfloat16",
         deep: str = "", backend: str = "jnp", kv_dtype: str = "auto",
         page_tokens: str = "0"):
    cfg = replace(get_smoke_config(arch), dtype="float32")
    if cfg.moe is not None:
        # chunked dispatch uses PER-CHUNK capacity; lift it so no tokens drop
        # and the pipeline is exactly comparable to the full-sequence oracle.
        import dataclasses
        cfg = replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    # "deep": 8 stages x tp 1 -> p2 = 6 < M-1, so REMOTE chunk 6 is actually
    # consumed by chunk 7's attention (exercises fetch/qship VALUES and the
    # int8 wire quantization, not just their masking)
    n_stages, tp = (8, 1) if deep else (4, 2)
    mesh = compat.make_mesh((n_stages, tp), ("data", "model"),
                            axis_types=(AxisType.Auto,) * 2)
    topo = Topology(mesh=mesh)
    m_chunks, c = 8, 16
    s = m_chunks * c
    b = 2
    if mode == "gpipe":
        b, m_chunks = 8, 4  # microbatch pipeline splits the BATCH

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

    kw = {}
    n_front = 0
    if cfg.family == "encdec" or cfg.frontend.kind != "none":
        n_front = c * 2 + 5  # deliberately NOT chunk-aligned (splice test)
        kw["embeds"] = jax.random.normal(
            jax.random.key(2), (b, n_front, cfg.d_model), jnp.float32) * 0.02
        if cfg.frontend.kind == "vision_stub":
            tokens = tokens[:, : s - n_front]  # embeds splice in front

    # oracle: full forward, last-token logits
    ref = model.forward(params, tokens, **kw)
    ref_last = np.asarray(ref[:, -1, :].astype(jnp.float32))

    def run_pipeline(attn_backend: str) -> np.ndarray:
        run = RunConfig(num_chunks=m_chunks, num_stages=n_stages,
                        mbkr=(mode == "mocap"), remote_attn=remote_attn,
                        kv_spill_dtype=spill_dtype, attn_backend=attn_backend,
                        ssm_backend=attn_backend,  # same knob for ssm archs
                        kv_dtype=kv_dtype, kv_page_tokens=int(page_tokens))
        plan = pp.build_plan(cfg, n_stages, s, run, mode=mode)
        staged = pp.stage_params(cfg, params, plan)
        specs = pp.stage_param_specs(cfg, plan, topo)

        def to_sharded(x, spec):
            return jax.device_put(x, NamedSharding(mesh, spec))

        st = {k: jax.tree.map(to_sharded, staged[k], specs[k],
                              is_leaf=lambda x: hasattr(x, "shape"))
              if k in specs else staged[k] for k in staged}
        with compat.set_mesh(mesh):
            fn = jax.jit(lambda st, tk, **k: pp.prefill_pipeline(
                cfg, st, tk, plan, topo, **k))
            out = fn(st, tokens, **kw)
        return np.asarray(out.astype(jnp.float32))

    backends = ("jnp", "pallas") if backend == "both" else (backend,)
    outs = {bk: run_pipeline(bk) for bk in backends}
    for bk, out in outs.items():
        rel = np.abs(out - ref_last) / (np.abs(ref_last) + 1e-3)
        if spill_dtype == "int8" or kv_dtype in ("int8", "fp8"):
            # int8/fp8 KV quantization is REAL lossy compression, so bound
            # the tail, not the single worst (near-zero-logit) element.
            # Spill-only int8 (2 of 8 chunks quantized) sits at p99 ~0.02;
            # kv_dtype=int8 quantizes EVERY stored chunk on this tiny
            # random-weight smoke model and lands at p99 ~0.065 (fp8-e4m3:
            # 3 mantissa bits, ~0.14). The per-ATTENTION-OUTPUT error is
            # bounded at the old 0.05 tolerance in test_kvstore.py.
            p99_tol, max_tol = {
                "int8": (0.12, 0.35), "fp8": (0.35, 1.2),
            }.get(kv_dtype, (0.05, 0.3))
            err = float(np.percentile(rel, 99))
            assert err < p99_tol and rel.max() < max_tol, \
                f"{arch}/{mode}/{remote_attn}/{bk}: p99 {err} max {rel.max()}"
            assert (out.argmax(-1) == ref_last.argmax(-1)).all()
        else:
            err = float(rel.max())
            assert err < 2e-3, \
                f"{arch}/{mode}/{remote_attn}/{bk}: max rel err {err}"
        assert np.isfinite(out).all()
        print(f"PASS {arch} {mode} {remote_attn} {spill_dtype} "
              f"kv={kv_dtype} backend={bk} err={err:.2e}")
    if backend == "both":
        perr = np.max(np.abs(outs["jnp"] - outs["pallas"])
                      / (np.abs(outs["jnp"]) + 1e-3))
        # both backends read the SAME quantized pages; their divergence
        # stays at numerics level even under int8/fp8 storage
        assert perr < 2e-3, f"jnp vs pallas diverge: {perr}"
        print(f"PASS backend-parity jnp~pallas err={perr:.2e}")


if __name__ == "__main__":
    main(*sys.argv[1:])
