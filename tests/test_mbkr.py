"""MBKR slot-plan invariants — exhaustive small cases + hypothesis properties.

The plan is the paper's §4.1 mechanism turned into static tables; these tests
prove (a) no slot is ever clobbered while live, (b) attention always finds
every prefix chunk, (c) the pool is strictly smaller than the Terapipe
baseline whenever the cross-half stagger gives headroom.
"""
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip the
#   module cleanly instead of erroring out the whole collection
from hypothesis import given, settings, strategies as st

from repro.core import mbkr


@pytest.mark.parametrize("m,n", [(16, 16), (32, 16), (8, 4), (24, 8),
                                 (12, 4), (64, 16), (6, 2), (20, 10)])
def test_plan_verifies(m, n):
    p = mbkr.plan(m, n)
    mbkr.verify_plan(p, periods=4)


@pytest.mark.parametrize("m,n", [(16, 16), (32, 16), (24, 8)])
def test_plan_saves_memory(m, n):
    p = mbkr.plan(m, n)
    assert p.num_slots < m


def test_plan_no_mbkr_is_terapipe():
    p = mbkr.plan(16, 16, mbkr=False)
    assert p.num_slots == 16 and p.p2 == 16


def test_pairing_involution():
    for n in (2, 4, 8, 16):
        for s in range(n):
            assert mbkr.pair_of(mbkr.pair_of(s, n), n) == s


def test_interleaved_placement_adjacency():
    """Paper: stage i placed adjacent to stage i+N/2."""
    rows = mbkr.interleaved_placement(16)
    for i in range(8):
        assert abs(rows[i] - rows[i + 8]) == 1
    assert sorted(rows) == list(range(16))


def test_peak_slots_closed_form_m_eq_n():
    """M == N: peak = M - N/4 (the 1/(1 - N/(4M)) max-seq gain, DESIGN.md)."""
    for n in (4, 8, 16, 32):
        m = n
        p2, peak = mbkr.best_p2(m, n)
        assert peak == m - n // 4, (n, peak)


@settings(max_examples=60, deadline=None)
@given(m=st.integers(2, 40), n=st.sampled_from([2, 4, 8, 16]))
def test_plan_property_verify(m, n):
    p = mbkr.plan(m, n)
    mbkr.verify_plan(p, periods=3)
    assert p.num_slots <= m            # never worse than Terapipe
    assert 0 < p.p2 <= m
    # every own chunk has a distinct slot; hosted tables within pool bounds
    own = p.own_slot[:p.p2]
    assert len(set(own.tolist())) == p.p2
    assert (p.host_slot_a[p.p2:] <= p.num_slots).all()
    assert (p.host_slot_b[p.p2:] <= p.num_slots).all()


@settings(max_examples=30, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), cap=st.integers(4, 40))
def test_max_chunks_monotone(n, cap):
    """MBKR admits at least as many chunks as the baseline at any capacity."""
    base = mbkr.max_chunks_for_capacity(n, cap, mbkr=False)
    ours = mbkr.max_chunks_for_capacity(n, cap, mbkr=True)
    assert ours >= base
    # and the claimed chunk count actually fits
    p = mbkr.plan(ours, n)
    assert p.peak <= cap or p.num_slots <= cap


def test_gain_decreases_with_chunk_count():
    """Paper Fig. 6(b): fewer chunks -> more reallocation headroom."""
    n = 16
    gains = []
    for m in (16, 24, 32, 64):
        _, peak = mbkr.best_p2(m, n)
        gains.append(m / peak)
    assert all(a >= b for a, b in zip(gains, gains[1:])), gains
    assert gains[0] == pytest.approx(16 / 12)
