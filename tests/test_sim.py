"""Event/lockstep simulator tests: closed-form agreement, paper orderings,
OOM detection, and the uniform-chunks stagger-collapse finding."""
import pytest

from repro.configs.base import get_config
from repro.core import costmodel as cm, mbkr
from repro.sim import SimConfig, max_seq_len, simulate

CFG = get_config("llama3-70b")


def test_lockstep_peak_matches_plan():
    """Lockstep + uniform chunks: per-stage peak == slot-plan peak."""
    s_len, m = 1 << 20, 16
    sm = cm.StageModel.build(CFG, 16, 1)
    kvc = cm.kv_chunk_bytes(sm, s_len // m)
    r = simulate(SimConfig(scheduler="mocap", model=CFG, seq_len=s_len,
                           batch=4, num_chunks=m))
    plan = mbkr.plan(m, 16)
    assert r.peak_mem / kvc == pytest.approx(plan.peak, abs=0.01)


def test_terapipe_peak_is_m_chunks():
    s_len, m = 1 << 20, 16
    sm = cm.StageModel.build(CFG, 16, 1)
    kvc = cm.kv_chunk_bytes(sm, s_len // m)
    r = simulate(SimConfig(scheduler="terapipe", model=CFG, seq_len=s_len,
                           batch=4, num_chunks=m))
    # peak m-1: the last chunk's alloc ties with the request free
    assert r.peak_mem / kvc >= m - 1.01


def test_scheduler_latency_ordering():
    """Paper Fig. 6(a): mocap < terapipe < gpipe on E2E latency."""
    res = {}
    for sched, part in (("gpipe", "uniform"), ("terapipe", "uniform"),
                        ("mocap", "lbcp")):
        res[sched] = simulate(SimConfig(
            scheduler=sched, model=CFG, seq_len=65536, batch=8,
            partition=part, sa_iters=40))
    assert res["mocap"].e2e_latency < res["terapipe"].e2e_latency
    assert res["terapipe"].e2e_latency < res["gpipe"].e2e_latency
    assert res["mocap"].throughput > res["gpipe"].throughput * 2


def test_max_seq_gain_matches_plan_trend():
    """Fig. 6(b): the MOCAP/Terapipe max-seq ratio decreases with chunks."""
    ratios = []
    for m in (16, 32):
        mt = max_seq_len(SimConfig(scheduler="terapipe", model=CFG, batch=3,
                                   num_chunks=m))
        mm = max_seq_len(SimConfig(scheduler="mocap", model=CFG, batch=3,
                                   num_chunks=m))
        ratios.append(mm / mt)
    assert ratios[0] > ratios[1] > 1.0
    assert ratios[0] > 1.2   # ~1.25 measured; paper reports up to 1.31


def test_gpipe_ooms_first():
    """GPipe (retained KV, N microbatches resident) hits OOM far earlier."""
    mg = max_seq_len(SimConfig(scheduler="gpipe", model=CFG, batch=16))
    mt = max_seq_len(SimConfig(scheduler="terapipe", model=CFG, batch=4))
    assert mt > mg * 3


def test_oom_detection():
    r = simulate(SimConfig(scheduler="terapipe", model=CFG, seq_len=64 << 20,
                           batch=2))
    assert not r.feasible and "OOM" in r.detail


def test_eventdriven_stagger_collapse():
    """KEY FINDING (beyond paper): free-running stages + UNIFORM chunks lose
    the cross-half stagger (offset = max dur + comm), so MBKR's saving
    vanishes; LBCP balancing restores it."""
    s_len, m = 1 << 20, 16
    sm = cm.StageModel.build(CFG, 16, 1)
    kvc = cm.kv_chunk_bytes(sm, s_len // m)
    uni = simulate(SimConfig(scheduler="mocap", model=CFG, seq_len=s_len,
                             batch=4, num_chunks=m, execution="eventdriven"))
    bal = simulate(SimConfig(scheduler="mocap", model=CFG, seq_len=s_len,
                             batch=4, num_chunks=m, execution="eventdriven",
                             partition="lbcp", sa_iters=40))
    assert uni.peak_mem / kvc > 14.5          # collapsed: ~M chunks
    assert bal.peak_mem < uni.peak_mem * 0.97  # LBCP restores headroom


def test_mocap_reallocation_traffic_accounted():
    r = simulate(SimConfig(scheduler="mocap", model=CFG, seq_len=1 << 20,
                           batch=2, num_chunks=16))
    assert r.link_bytes > 0
    r2 = simulate(SimConfig(scheduler="mocap", model=CFG, seq_len=1 << 20,
                            batch=2, num_chunks=16, compress=0.5))
    assert r2.link_bytes == pytest.approx(r.link_bytes * 0.5, rel=1e-6)


def test_moe_and_gqa_shape_the_gain():
    """Paper §5.2: MoE lowers per-token compute (attention share grows);
    bigger GQA ratio shrinks KV and weakens the memory bottleneck."""
    qwen = get_config("qwen3-235b")    # MoE
    llama405 = get_config("llama3-405b")  # large GQA ratio
    m70 = max_seq_len(SimConfig(scheduler="terapipe", model=CFG, batch=3))
    m405 = max_seq_len(SimConfig(scheduler="terapipe", model=llama405, batch=3))
    # per-token KV smaller relative to capacity => llama405 goes further in
    # absolute tokens? No: more layers per stage. Just assert feasibility.
    assert m70 > 0 and m405 > 0
    r = simulate(SimConfig(scheduler="mocap", model=qwen, seq_len=262144,
                           batch=4, partition="lbcp", sa_iters=30))
    assert r.feasible
