"""Fleet-layer tests (ISSUE 9): the multi-cell serving fabric behind the
narrow ``CellHandle`` protocol.

- the lease/cost-aware router (jsf) strictly beats round-robin on p99 TTFT
  over a heterogeneous hot/cold cell pair at equal offered load,
- drain semantics: a draining cell admits ZERO new requests but completes
  everything in flight; the fabric retires it from routing,
- heterogeneous kv_dtype cells price their KV leases independently,
- the 2-cell sim end-to-end: shared arrival stream, fleet roll-up metrics,
  ONE merged trace with per-cell process rows, elastic resize,
- protocol hygiene: serve.py and repro/fleet touch engines ONLY through
  ``CellHandle`` (source scan, same idiom as the PR 5 transport grep),
- ServeOptions: JSON round-trip, explicit-flags-as-overrides, fleet spec,
- the deprecated ContinuousEngine kwargs still work and warn.

Everything here is sim-executor / stdlib-only: no jax device state, no new
skip classes (tests/check_skips.py stays exact on both jaxlib legs).
"""
import json
import math
import os
import re
import subprocess
import sys
import warnings
from dataclasses import replace as dc_replace

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.fleet import (CellSignals, FleetFabric, FleetRouter,
                         PlacementDecision, score_cells)
from repro.runtime.engine import (CellHandle, ContinuousEngine, EngineConfig,
                                  Request, SimExecutor)
from repro.sched import fleet_summary, poisson_arrivals
from repro.sched.metrics import RequestRecord

ROOT = os.path.join(os.path.dirname(__file__), "..")
CFG = get_config("llama3-70b")

SLOW_HW = dc_replace(cm.WSC_PAPER, name="wsc-degraded",
                     gemm_eff=cm.WSC_PAPER.gemm_eff * 0.55,
                     attn_eff=cm.WSC_PAPER.attn_eff * 0.55)


def _cell(hw=cm.WSC_PAPER, *, kv_dtype="auto", trace=False, slo=None,
          buckets=(32768,), inflight=2):
    ec = EngineConfig(model=CFG, hw=hw, num_stages=16, tp=1, num_chunks=16,
                      buckets=buckets, partition="uniform", sa_iters=8,
                      kv_dtype=kv_dtype, trace=trace, slo=slo,
                      inflight=inflight)
    return ContinuousEngine(ec, SimExecutor(CFG, hw))


def _pair(policy, *, trace=False):
    return FleetFabric({"fast": _cell(trace=trace),
                        "slow": _cell(SLOW_HW, trace=trace)},
                       FleetRouter(policy))


def _drive(fab, n=24, rate=6.0, seq=30000, seed=0):
    for i, t in enumerate(poisson_arrivals(rate, n, seed=seed)):
        fab.submit(Request(rid=i, arrival=float(t), seq_len=seq))
    fab.pump()
    return fab.metrics()


# ------------------------------------------------------------ protocol seam

def test_continuous_engine_is_a_cell_handle():
    eng = _cell()
    assert isinstance(eng, CellHandle)


def test_estimate_admission_matches_realized_finish():
    """The jsf signal is honest: an empty cell's quoted ETA for a request
    IS the finish time the scheduler then realizes for it."""
    eng = _cell()
    eta, fits = eng.estimate_admission(30000, arrival=0.0)
    assert fits
    eng.submit(Request(rid=0, arrival=0.0, seq_len=30000))
    eng.run_until_drained()
    [done] = eng.poll()
    assert done.finish_time == pytest.approx(eta, rel=1e-9)


def test_protocol_only_access_source_scan():
    """serve.py and the whole fleet package must consume engines through
    the CellHandle protocol: no scheduler/lease/executor internals, no
    poking executor observability flags, no reading .done/.waves directly
    (the PR 5 transport-grep idiom applied to the engine seam)."""
    forbidden = re.compile(
        r"\.scheduler\.|\.lease\.|\.collect_telemetry|\.collect_measured"
        r"|\.stage_free|\.metrics\.records|\bexecutor\.[a-z_]+\s*="
        r"|eng\.done\b|cell\.done\b|\.executor\.")
    files = [os.path.join(ROOT, "src", "repro", "launch", "serve.py")]
    fleet_dir = os.path.join(ROOT, "src", "repro", "fleet")
    files += [os.path.join(fleet_dir, f) for f in sorted(os.listdir(fleet_dir))
              if f.endswith(".py")]
    for path in files:
        src = open(path).read()
        hits = [(i + 1, line) for i, line in enumerate(src.splitlines())
                if forbidden.search(line)]
        assert not hits, f"engine internals poked in {path}: {hits}"


def test_legacy_engine_kwargs_deprecated_but_work():
    ec = _cell().ec
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = ContinuousEngine(ec, SimExecutor(CFG, ec.hw), policy="edf",
                               slo=2.0, inflight=3, trace=True)
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    # the kwargs landed on the config
    assert eng.ec.policy == "edf" and eng.ec.slo == 2.0
    assert eng.ec.inflight == 3 and eng.ec.trace is True
    eng.submit(Request(rid=0, arrival=0.0, seq_len=30000))
    eng.run_until_drained()
    assert eng.metrics()["completed"] == 1
    # config-only construction warns nothing
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ContinuousEngine(dc_replace(ec, policy="edf"),
                         SimExecutor(CFG, ec.hw))
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)]


# ------------------------------------------------------------------ routing

def test_jsf_beats_round_robin_on_hot_cold_pair():
    """Equal offered load, one fast + one degraded cell: the lease/cost-
    aware router must strictly beat round-robin on p99 TTFT (the ISSUE 9
    acceptance criterion; same construction as the gated bench row)."""
    m_jsf = _drive(_pair("jsf"))
    m_rr = _drive(_pair("rr"))
    assert m_jsf["completed"] == m_rr["completed"] == 24
    assert m_jsf["p99_ttft"] < m_rr["p99_ttft"], (
        f"jsf {m_jsf['p99_ttft']:.3f}s vs rr {m_rr['p99_ttft']:.3f}s")
    # jsf steers the bulk of the stream at the fast cell
    assert (m_jsf["per_cell"]["fast"]["completed"]
            > m_jsf["per_cell"]["slow"]["completed"])


def test_least_loaded_routes_by_queue_depth():
    m = _drive(_pair("least-loaded"))
    assert m["completed"] == 24
    assert all(pc["completed"] > 0 for pc in m["per_cell"].values())


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError):
        FleetRouter("fifo")
    with pytest.raises(ValueError):
        score_cells("rr", [])


def test_placement_decisions_record_all_candidates():
    fab = _pair("jsf")
    fab.submit(Request(rid=0, arrival=0.0, seq_len=30000))
    [dec] = fab.router.decisions
    assert isinstance(dec, PlacementDecision)
    assert {s.name for s in dec.signals} == {"fast", "slow"}
    assert math.isfinite(dec.eta)


# -------------------------------------------------------------------- drain

def test_drain_admits_zero_completes_inflight():
    """drain() on a cell: everything already submitted finishes; any later
    submit raises; the fabric retires it and routes around it."""
    fab = _pair("jsf")
    for i in range(6):
        fab.submit(Request(rid=i, arrival=0.1 * i, seq_len=30000),
                   pump=False)
    placed_fast = [r for r, c in fab.placements.items() if c == "fast"]
    done = fab.drain_cell("fast")
    assert sorted(r.rid for r in done) == sorted(placed_fast)
    assert all(r.state == "done" and math.isfinite(r.finish_time)
               for r in done)
    with pytest.raises(RuntimeError):
        fab.retired["fast"].submit(Request(rid=99, arrival=9., seq_len=100))
    # routing continues on the surviving cell only
    dec = fab.submit(Request(rid=50, arrival=1.0, seq_len=30000))
    assert dec.cell == "slow"
    fab.pump()
    assert fab.metrics()["completed"] == 7


def test_all_cells_draining_closes_admission():
    fab = _pair("jsf")
    fab.drain_all()
    with pytest.raises(RuntimeError):
        fab.submit(Request(rid=0, arrival=0.0, seq_len=100))


# -------------------------------------------------------- heterogeneous kv

def test_heterogeneous_kv_dtype_cells_price_leases_independently():
    """An int8 cell's lease for the SAME request costs ~half the bytes of
    the bf16 cell's (stored-byte accounting is per-cell state)."""
    peaks = {}
    for kd in ("auto", "int8"):
        cell = _cell(kv_dtype=kd)
        base = cell.free_lease_bytes()
        cell.submit(Request(rid=0, arrival=0.0, seq_len=32768))
        cell.run_until_drained()
        peaks[kd] = base - float(cell.lease.headroom(after=0.0).min())
    assert peaks["auto"] > 0
    ratio = peaks["int8"] / peaks["auto"]
    assert 0.45 < ratio < 0.60, ratio


# ------------------------------------------------------------------- e2e

def test_two_cell_e2e_metrics_trace_and_resize():
    """2-cell sim fleet end-to-end: every request of the shared stream
    completes exactly once, the fleet summary reconciles with per-cell
    counts, the merged trace shows BOTH cells' process rows, and resize()
    grows/drains the fleet mid-stream."""
    fab = _pair("jsf", trace=True)
    _drive(fab, n=16)
    m = fab.metrics()
    assert m["completed"] == 16 and m["rejected"] == 0
    assert sum(pc["completed"] for pc in m["per_cell"].values()) == 16
    evs = fab.merged_trace().chrome_trace()["traceEvents"]
    pids = {str(e["pid"]) for e in evs}
    assert any(p.startswith("fast/stage") for p in pids)
    assert any(p.startswith("slow/stage") for p in pids)
    assert any(p == "fast/requests" for p in pids)
    # elastic resize: fast+slow -> fast+extra (slow drains, extra joins)
    fab.resize(["fast", "extra"], factory=lambda name: _cell(trace=True))
    assert set(fab.cells) == {"fast", "extra"}
    assert "slow" in fab.retired
    for i in range(16, 24):
        fab.submit(Request(rid=i, arrival=3.0 + 0.1 * i, seq_len=30000))
    fab.pump()
    m2 = fab.metrics()
    assert m2["completed"] == 24 and m2["cells"] == 3
    # retired cells keep their history in the roll-up
    assert m2["per_cell"]["slow"]["completed"] == m["per_cell"]["slow"]["completed"]


def test_fleet_summary_merges_records():
    recs = {
        "a": [RequestRecord(rid=0, arrival=0.0, seq_len=10, bucket=16,
                            admit=0.0, finish=1.0, deadline=2.0)],
        "b": [RequestRecord(rid=1, arrival=0.0, seq_len=10, bucket=16,
                            admit=0.5, finish=4.0, deadline=2.0),
              RequestRecord(rid=2, arrival=1.0, seq_len=10, bucket=16,
                            rejected=True)],
    }
    s = fleet_summary(recs)
    assert s["cells"] == 2 and s["completed"] == 2 and s["rejected"] == 1
    assert s["makespan"] == 4.0
    assert s["throughput"] == pytest.approx(0.5)
    assert s["slo_total"] == 2 and s["slo_met"] == 1
    assert s["per_cell"]["b"]["rejected"] == 1


# ------------------------------------------------------------ serve options

def test_serve_options_json_round_trip():
    from repro.launch.options import ServeOptions
    opts = ServeOptions(arch="llama3-70b", executor="sim", cells=3,
                        router="least-loaded", buckets=(8192, 32768),
                        slo_ms=750.0, scheduler="continuous")
    back = ServeOptions.from_json(opts.to_json())
    assert back == opts
    assert back.buckets == (8192, 32768)
    with pytest.raises(ValueError):
        ServeOptions.from_dict({"archh": "typo"})


def test_serve_options_cli_flags_are_overrides():
    """SUPPRESS-default parser: only explicitly typed flags override the
    --options-in base; everything else survives untouched."""
    import argparse
    from repro.launch.options import (ServeOptions, add_serve_args,
                                      options_from_args)
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    base = ServeOptions(executor="sim", requests=40, seq=30000,
                        scheduler="continuous")
    ns = ap.parse_args(["--requests", "8", "--router", "rr"])
    opts = options_from_args(ns, base)
    assert opts.requests == 8 and opts.router == "rr"      # overridden
    assert opts.executor == "sim" and opts.seq == 30000    # inherited
    assert opts.scheduler == "continuous"


def test_fleet_spec_per_cell_overrides(tmp_path):
    from repro.launch.options import ServeOptions, resolve_fleet
    spec = tmp_path / "spec.json"
    spec.write_text(json.dumps({
        "router": "least-loaded",
        "cells": [{"kv_dtype": "int8"}, {"buckets": [8192]}],
    }))
    base = ServeOptions(executor="sim", fleet_spec=str(spec))
    router, cells = resolve_fleet(base)
    assert router == "least-loaded"
    assert len(cells) == 2
    assert cells[0].kv_dtype == "int8" and cells[0].buckets is None
    assert cells[1].buckets == (8192,) and cells[1].kv_dtype == "auto"
    # --cells N replication path
    router2, cells2 = resolve_fleet(ServeOptions(cells=3, router="rr"))
    assert router2 == "rr" and len(cells2) == 3


def test_serve_fleet_subprocess_smoke(tmp_path):
    """The CLI fleet path end-to-end: 2 sim cells, jsf router, merged
    multi-cell trace + fleet metrics JSON on disk."""
    trace = tmp_path / "fleet_trace.json"
    metrics = tmp_path / "fleet_metrics.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--executor", "sim",
         "--scheduler", "continuous", "--cells", "2", "--router", "jsf",
         "--requests", "8", "--seq", "30000", "--arrival-rate", "6",
         "--trace-out", str(trace), "--metrics-out", str(metrics)],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "[fleet jsf x2]" in r.stdout
    assert "trace ->" in r.stdout and "metrics ->" in r.stdout
    m = json.load(open(metrics))
    assert m["completed"] == 8 and m["cells"] == 2
    pids = {str(e["pid"]) for e in json.load(open(trace))["traceEvents"]}
    assert any(p.startswith("cell0/") for p in pids)
    assert any(p.startswith("cell1/") for p in pids)
