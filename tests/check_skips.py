"""Assert the tier-1 SKIP matrix matches the installed jax capabilities.

The CI ``tier1`` job runs on a jax matrix (current release + the oldest
supported jaxlib, which predates ``jax.shard_map`` and therefore takes the
``compat.supports_partial_auto_spmd`` fallback path everywhere). A compat
drift — a test silently skipping on NEW jax, or the old-jaxlib leg skipping
more/less than the two known kv_split/EP tests — should fail CI, not
surface on user machines. This script parses a ``pytest -rs`` log and
asserts the exact expected skip counts per reason class:

- "old jaxlib"/PartitionId skips: exactly 2 (test_perf_variants kv_split +
  EP) when partial-auto SPMD is unsupported, exactly 0 otherwise.
- hypothesis skips: exactly 0 when hypothesis is importable (CI installs
  it), exactly 4 otherwise (3 importorskip modules + the guarded
  ragged-occupancy property test).
- anything else: unknown skip reason -> fail.

Usage:
  PYTHONPATH=src python -m pytest -q -rs 2>&1 | tee pytest-report.log
  PYTHONPATH=src python tests/check_skips.py pytest-report.log
"""
from __future__ import annotations

import re
import sys

SKIP_RE = re.compile(r"^SKIPPED \[(\d+)\] [^:]+(?::\d+)?: (.*)$", re.M)

# the whisper-encoder case inside a hypothesis property test
_ALLOWED_CONDITIONAL = ("causal-only",)


def main(path: str) -> int:
    from repro import compat
    try:
        import hypothesis  # noqa: F401
        have_hyp = True
    except ImportError:
        have_hyp = False

    text = open(path).read()
    skips = [(int(m.group(1)), m.group(2).strip())
             for m in SKIP_RE.finditer(text)]
    n_partial = sum(c for c, r in skips
                    if "old jaxlib" in r or "PartitionId" in r)
    n_hyp = sum(c for c, r in skips if "hypothesis" in r)
    unknown = [(c, r) for c, r in skips
               if "old jaxlib" not in r and "PartitionId" not in r
               and "hypothesis" not in r
               and not any(a in r for a in _ALLOWED_CONDITIONAL)]

    exp_partial = 0 if compat.supports_partial_auto_spmd() else 2
    exp_hyp = 0 if have_hyp else 4
    ok = True
    if n_partial != exp_partial:
        ok = False
        print(f"FAIL: {n_partial} partial-auto-SPMD skips, expected "
              f"{exp_partial} (supports_partial_auto_spmd()="
              f"{compat.supports_partial_auto_spmd()}) — compat drift: "
              "either a fallback path regressed or a new gated test wasn't "
              "registered here")
    if n_hyp != exp_hyp:
        ok = False
        print(f"FAIL: {n_hyp} hypothesis skips, expected {exp_hyp} "
              f"(hypothesis importable={have_hyp})")
    if unknown:
        ok = False
        print(f"FAIL: unknown skip reasons: {unknown}")
    if ok:
        print(f"skip matrix OK: partial-auto={n_partial} "
              f"hypothesis={n_hyp} (jax capabilities match expectations)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1]))
