"""Assert the tier-1 SKIP matrix matches the installed jax capabilities.

The CI ``tier1`` job runs on a jax matrix (current release + the oldest
supported jaxlib, which predates ``jax.shard_map``). Since the manual TP
lowering landed (``compat.resolve_tp_lowering`` / DESIGN.md §3.6) the
old-jaxlib leg runs TP=2 and the kv_split / EP perf-variant tests instead
of skipping them — the partial-auto skip count is 0 on BOTH legs, and a
reappearing "old jaxlib"/PartitionId skip means the manual-lowering
fallback regressed. This script parses a ``pytest -rs`` log and asserts the
exact expected skip counts per reason class:

- "old jaxlib"/PartitionId skips: exactly 0 on every leg (the manual
  lowering replaced the tp=1 fallback).
- hypothesis skips: exactly 0 when hypothesis is importable (CI installs
  it), exactly 5 otherwise (4 importorskip modules — including the prefix
  radix property tests — + the guarded ragged-occupancy property test).
- anything else: unknown skip reason -> fail. Notably the paged pool
  kernel (DESIGN.md §3.7) introduces NO TPU-only skip class: its manual-
  DMA path runs under interpret mode on every supported jaxlib, and the
  deterministic ragged cases in test_pool_batched.py run unconditionally
  (no hypothesis needed).

It also asserts the resolved TP lowering matches ``REPRO_EXPECT_TP_LOWERING``
when the CI matrix sets it (the old-jaxlib leg pins "manual"), so a compat
drift that silently flips the lowering fails CI instead of shipping.

Usage:
  PYTHONPATH=src python -m pytest -q -rs 2>&1 | tee pytest-report.log
  PYTHONPATH=src python tests/check_skips.py pytest-report.log
"""
from __future__ import annotations

import os
import re
import sys

SKIP_RE = re.compile(r"^SKIPPED \[(\d+)\] [^:]+(?::\d+)?: (.*)$", re.M)

# the whisper-encoder case inside a hypothesis property test
_ALLOWED_CONDITIONAL = ("causal-only",)


def main(path: str) -> int:
    from repro import compat
    try:
        import hypothesis  # noqa: F401
        have_hyp = True
    except ImportError:
        have_hyp = False

    text = open(path).read()
    skips = [(int(m.group(1)), m.group(2).strip())
             for m in SKIP_RE.finditer(text)]
    n_partial = sum(c for c, r in skips
                    if "old jaxlib" in r or "PartitionId" in r)
    n_hyp = sum(c for c, r in skips if "hypothesis" in r)
    unknown = [(c, r) for c, r in skips
               if "old jaxlib" not in r and "PartitionId" not in r
               and "hypothesis" not in r
               and not any(a in r for a in _ALLOWED_CONDITIONAL)]

    exp_hyp = 0 if have_hyp else 5
    ok = True
    if n_partial != 0:
        ok = False
        print(f"FAIL: {n_partial} partial-auto-SPMD skips, expected 0 on "
              "every leg (supports_partial_auto_spmd()="
              f"{compat.supports_partial_auto_spmd()}) — the manual TP "
              "lowering should have replaced the tp=1 fallback; either it "
              "regressed or a new gated test wasn't registered here")
    if n_hyp != exp_hyp:
        ok = False
        print(f"FAIL: {n_hyp} hypothesis skips, expected {exp_hyp} "
              f"(hypothesis importable={have_hyp})")
    if unknown:
        ok = False
        print(f"FAIL: unknown skip reasons: {unknown}")
    expect_tl = os.environ.get("REPRO_EXPECT_TP_LOWERING")
    resolved_tl = compat.resolve_tp_lowering("auto")
    if expect_tl and resolved_tl != expect_tl:
        ok = False
        print(f"FAIL: tp_lowering resolves to {resolved_tl!r} but this CI "
              f"leg expects {expect_tl!r} (REPRO_EXPECT_TP_LOWERING) — the "
              "matrix env and compat.resolve_tp_lowering disagree")
    if ok:
        print(f"skip matrix OK: partial-auto={n_partial} "
              f"hypothesis={n_hyp} tp_lowering={resolved_tl} "
              "(jax capabilities match expectations)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1]))
