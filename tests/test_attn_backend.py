"""Pluggable attention backends: pallas (interpret) vs jnp parity through
the full chunked pipeline, backend-registry unit behavior, and
``kernels.ops.chunk_attention`` edge cases the pipeline path leans on
(non-128-multiple head dims, kv lengths not divisible by block_k,
causal_offset > 0, return_state residual consistency)."""
import numpy as np
import pytest

from tests.helpers.subproc import run_pipeline_check


def _run(arch, mode, remote):
    run_pipeline_check(arch, mode, remote, deep=True, backend="both",
                       expect="PASS backend-parity")


# ------------------------------------------------- pipeline-level parity
# deep mode (8 stages): p2 < M-1, so the remote fetch/qship VALUES flow
# through the backend under test, not just their masking.

@pytest.mark.parametrize("arch,remote", [
    ("qwen3-8b", "qship"),      # tfm family
    ("qwen3-8b", "fetch"),
    ("zamba2-7b", "qship"),     # hybrid family (shared attn block + SSD knob)
    ("zamba2-7b", "fetch"),
])
def test_backend_parity_pipeline(arch, remote):
    _run(arch, "mocap", remote)


def test_backend_parity_whisper_cross_attention():
    """encdec: under attn_backend=pallas the decoder cross-attention routes
    through ``ops.full_attention`` (the non-causal chunk_attention wrapper)
    instead of layers.flash_attention_xla — jnp/pallas must still agree."""
    run_pipeline_check("whisper-small", "mocap", "qship", backend="both",
                       expect="PASS backend-parity")


def test_backend_parity_ssm_ssd_kernel():
    """ssm family: backend=both routes ``ssm_stage_step`` through
    ``kernels.ops.ssd`` (RunConfig.ssm_backend) on the pallas side."""
    run_pipeline_check("mamba2-130m", "terapipe", "qship", backend="both",
                       expect="PASS backend-parity")


# ------------------------------------------------------ registry behavior

def test_backend_registry():
    from repro.core import attention as A
    assert set(A.available_backends()) >= {"jnp", "pallas"}
    assert A.get_backend("jnp").name == "jnp"
    assert A.get_backend("pallas").name == "pallas"
    with pytest.raises(KeyError, match="unknown attention backend"):
        A.get_backend("nope")


@pytest.mark.parametrize("dtype,tol", [("float32", 2e-5), ("bfloat16", 2e-2)])
def test_backend_block_parity_direct(dtype, tol):
    """self_block + gated chunk_block agree between backends without the
    pipeline around them (fast, in-process). The bf16 case guards the fp32
    accumulator path: the pallas backend must combine at full precision,
    not through the dtype-rounded normalized output."""
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    b, c, kvh, g, d = 2, 32, 2, 3, 24
    ks = jax.random.split(jax.random.key(3), 5)
    dt = jnp.dtype(dtype)
    qg = jax.random.normal(ks[0], (b, c, kvh, g, d)).astype(dt)
    k_self = jax.random.normal(ks[1], (b, c, kvh, d)).astype(dt)
    v_self = jax.random.normal(ks[2], (b, c, kvh, d)).astype(dt)
    k_pool = jax.random.normal(ks[3], (b, c, kvh, d)).astype(dt)
    v_pool = jax.random.normal(ks[4], (b, c, kvh, d)).astype(dt)
    scale = 0.17

    outs = {}
    for name in ("jnp", "pallas"):
        be = A.get_backend(name)
        st = A.attn_init(b, c, kvh, g, d)
        st = be.chunk_block(qg, k_pool, v_pool, jnp.bool_(True), scale, st)
        st = be.chunk_block(qg, v_pool, k_pool, jnp.bool_(False), scale, st)
        st = be.self_block(qg, k_self, v_self, scale, st)
        outs[name] = np.asarray(A.attn_finish(st, jnp.float32))
    np.testing.assert_allclose(outs["jnp"], outs["pallas"],
                               atol=tol, rtol=tol)


# ------------------------------------------------- kernel edge cases

def _kernel_case(b, c, h, kvh, d, p, block_k=128):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    t = p + c
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (b, c, h, d))
    k = jax.random.normal(ks[1], (b, t, kvh, d))
    v = jax.random.normal(ks[2], (b, t, kvh, d))
    out = ops.chunk_attention(q, k, v, causal_offset=p, block_k=block_k)
    want = ref.chunk_attention_ref(q, k, v, causal_offset=p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_kernel_nonlane_head_dim():
    # d = 40: wrapper pads to the 128-lane width and slices back
    _kernel_case(2, 32, 4, 2, 40, 64)


def test_kernel_kv_not_block_multiple():
    # t = 96 + 32 = 128? no: pick p so t is NOT divisible by block_k
    _kernel_case(1, 32, 4, 4, 32, 69, block_k=64)  # t = 101 -> padded to 128


def test_kernel_causal_offset_positive():
    _kernel_case(2, 64, 8, 2, 32, 192)


def test_kernel_return_state_consistency():
    """finish(state) from return_state must reproduce the kernel output."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    b, c, h, kvh, d, p = 2, 32, 6, 3, 24, 40
    t = p + c
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (b, c, h, d))
    k = jax.random.normal(ks[1], (b, t, kvh, d))
    v = jax.random.normal(ks[2], (b, t, kvh, d))
    out, m, l, acc = ops.chunk_attention(q, k, v, causal_offset=p,
                                         return_state=True)
    plain = ops.chunk_attention(q, k, v, causal_offset=p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(plain), atol=1e-6)
    assert m.shape == (b, h, c) and l.shape == (b, h, c)
    assert acc.shape == (b, c, h, d) and acc.dtype == jnp.float32
    assert np.all(np.asarray(l) > 0)  # causal_offset>0: no fully-masked rows
    # the fp32 accumulator re-finished through the state algebra must
    # reproduce the kernel's own normalized output
    from repro.core import attention as A
    st = A.PallasBackend._to_state(m, l, acc, kvh)
    redo = np.asarray(A.attn_finish(st, jnp.float32))
    np.testing.assert_allclose(redo, np.asarray(plain), atol=1e-5, rtol=1e-5)


def test_kernel_fully_masked_rows_finite():
    """causal_offset=0 with a kv prefix of length 0 and masked tail: rows
    with no visible keys must produce zeros, not NaN, and identity state."""
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    # valid=False chunk_block must leave the state untouched
    b, c, kvh, g, d = 1, 16, 1, 2, 16
    qg = jax.random.normal(jax.random.key(0), (b, c, kvh, g, d))
    kv = jax.random.normal(jax.random.key(1), (b, c, kvh, d))
    be = A.get_backend("pallas")
    st0 = A.attn_init(b, c, kvh, g, d)
    st1 = be.chunk_block(qg, kv, kv, jnp.bool_(False), 0.3, st0)
    for a, b_ in zip(st0, st1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_))
    out = A.attn_finish(st1, jnp.float32)
    assert np.isfinite(np.asarray(out)).all()
