"""Property tests for the radix prefix index (ISSUE 10 satellite).

Random interleavings of acquire / release / divergent-chain traffic against
a capacity-bounded ``PrefixPageCache`` must preserve, at EVERY step:

- no page is ever freed (recycled through the free list) while any live
  lease still references its node — refcounts equal live-lease membership,
- no two live leases ever WRITE the same physical page (copy-on-write at
  chunk granularity: divergent suffixes always get fresh handles),
- node pages + the free list partition the allocated handle space exactly
  (no double grant, no leak), and resident bytes equal the analytic
  node-count model,

all of which ``verify_prefix_index`` asserts wholesale — the property test
drives it through arbitrary schedules the deterministic tests in
test_prefix.py cannot enumerate.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip the
#   module cleanly instead of erroring out the whole collection
from hypothesis import given, settings, strategies as st

from repro.kvstore.prefix import (PrefixPageCache, chunk_hashes,
                                  verify_prefix_index)

# a small universe of chains with heavy shared structure: every chain is a
# prefix-sharing variant of one of two root token streams, so random
# traffic constantly hits, diverges mid-chunk, and re-converges. Built with
# the REAL chained hash so the index's key contract (equal key => equal
# full prefix) holds by construction.
_CHAINS = []
for root in (0, 1):
    base_toks = np.arange(24) + root * 1000
    _CHAINS.append(chunk_hashes(base_toks, 4))
    for d in range(1, 6):
        toks = np.r_[base_toks[:4 * d],
                     np.arange(24 - 4 * d) + 9000 + root * 100 + d * 17]
        _CHAINS.append(chunk_hashes(toks, 4))

_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("acquire"), st.integers(0, len(_CHAINS) - 1)),
        st.tuples(st.just("release"), st.integers(0, 31)),
    ),
    min_size=1, max_size=60)


@settings(max_examples=120, deadline=None)
@given(ops=_OPS, ppc=st.integers(1, 3),
       cap_chunks=st.one_of(st.none(), st.integers(2, 12)))
def test_random_traffic_preserves_index_invariants(ops, ppc, cap_chunks):
    cache = PrefixPageCache(
        pages_per_chunk=ppc, page_bytes=64.0,
        capacity_pages=None if cap_chunks is None else cap_chunks * ppc)
    live = []
    rid = 0
    for op, arg in ops:
        if op == "acquire":
            lease = cache.acquire(rid, _CHAINS[arg])
            # the lease never claims more than the chain, and its hit/new
            # split is consistent with the accounting geometry
            assert lease.hit_chunks <= len(_CHAINS[arg])
            assert lease.hit_pages == lease.hit_chunks * ppc
            assert len(lease.new_pages) % ppc == 0
            live.append(lease)
            rid += 1
        elif live:
            cache.release(live.pop(arg % len(live)))
        verify_prefix_index(cache)
        if cache.capacity_pages is not None:
            assert cache.resident_pages() <= cache.capacity_pages
    # full teardown: releasing everything leaves a verifiable, fully
    # unreferenced index whose every page is still accounted for
    for lease in live:
        cache.release(lease)
    verify_prefix_index(cache)
    assert all(n.refs == 0 for n in cache._nodes.values())
    # saved bytes is exactly the closed-form over recorded hits
    st_ = cache.stats()
    assert st_["prefix_saved_bytes"] == pytest.approx(
        st_["prefix_hit_pages"] * cache.page_bytes)
    assert st_["prefix_hits"] + st_["prefix_misses"] == st_["prefix_requests"]
