"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("b,c,h,kvh,d,p", [
    (2, 64, 4, 2, 32, 128),
    (1, 128, 8, 8, 64, 0),
    (2, 32, 4, 1, 16, 96),
    (1, 256, 2, 2, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_attention(b, c, h, kvh, d, p, dtype):
    t = p + c
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, c, h, d), dtype)
    k = _rand(ks[1], (b, t, kvh, d), dtype)
    v = _rand(ks[2], (b, t, kvh, d), dtype)
    out = ops.chunk_attention(q, k, v, causal_offset=p)
    want = ref.chunk_attention_ref(q, k, v, causal_offset=p)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_chunk_attention_blocks():
    """Block-shape invariance: different tilings, same result."""
    b, c, h, kvh, d, p = 1, 128, 4, 2, 64, 64
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, c, h, d), jnp.float32)
    k = _rand(ks[1], (b, p + c, kvh, d), jnp.float32)
    v = _rand(ks[2], (b, p + c, kvh, d), jnp.float32)
    o1 = ops.chunk_attention(q, k, v, causal_offset=p, block_q=32, block_k=32)
    o2 = ops.chunk_attention(q, k, v, causal_offset=p, block_q=128, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("b,t,h,p_,g,n,ck", [
    (2, 64, 4, 8, 1, 16, 16),
    (1, 128, 2, 16, 2, 8, 32),
    (1, 96, 4, 8, 4, 8, 32),  # uneven chunk fallback (96 % 32 == 0)
])
def test_ssd(b, t, h, p_, g, n, ck):
    ks = jax.random.split(KEY, 4)
    x = _rand(ks[0], (b, t, h, p_), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (b, t, h), jnp.float32))
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    bb = _rand(ks[2], (b, t, g, n), jnp.float32)
    cc = _rand(ks[3], (b, t, g, n), jnp.float32)
    dsk = jnp.ones((h,))
    y, st = ops.ssd(x, dt, a_log, bb, cc, dsk, chunk=ck)
    yw, stw = ref.ssd_ref(x, dt, a_log, bb, cc, dsk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(stw), atol=3e-4)


def test_ssd_state_carry():
    """Sequential kernel calls with carried state == one long call."""
    b, t, h, p_, g, n = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(KEY, 4)
    x = _rand(ks[0], (b, t, h, p_), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (b, t, h), jnp.float32))
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    bb = _rand(ks[2], (b, t, g, n), jnp.float32)
    cc = _rand(ks[3], (b, t, g, n), jnp.float32)
    dsk = jnp.ones((h,))
    y_full, st_full = ops.ssd(x, dt, a_log, bb, cc, dsk, chunk=16)
    y1, st1 = ops.ssd(x[:, :32], dt[:, :32], a_log, bb[:, :32], cc[:, :32],
                      dsk, chunk=16)
    y2, st2 = ops.ssd(x[:, 32:], dt[:, 32:], a_log, bb[:, 32:], cc[:, 32:],
                      dsk, chunk=16, init_state=st1)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2), atol=3e-4)


@pytest.mark.parametrize("b,h,kvh,d,s", [
    (2, 8, 2, 64, 256),
    (3, 4, 4, 32, 100),
    (1, 16, 2, 128, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, kvh, d, s, dtype):
    ks = jax.random.split(KEY, 4)
    q = _rand(ks[0], (b, h, d), dtype)
    k = _rand(ks[1], (b, s, kvh, d), dtype)
    v = _rand(ks[2], (b, s, kvh, d), dtype)
    kvl = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = ops.decode_attention(q, k, v, kvl)
    want = ref.decode_attention_ref(q, k, v, kvl)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_decode_attention_ragged_lengths():
    """kv_len masking: garbage beyond the valid length must not leak."""
    b, h, kvh, d, s = 2, 4, 2, 32, 128
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, kvh, d), jnp.float32)
    v = _rand(ks[2], (b, s, kvh, d), jnp.float32)
    kvl = jnp.array([17, 64], jnp.int32)
    out1 = ops.decode_attention(q, k, v, kvl)
    # poison the invalid region
    k2 = k.at[0, 17:].set(1e4)
    v2 = v.at[0, 17:].set(-1e4)
    out2 = ops.decode_attention(q, k2, v2, kvl)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ------------------------------------------ ssm backend knob (stage program)

def test_ssd_backend_registry_and_block_parity():
    """``models.ssm.block_apply`` routes the SSD inner loop through the
    ``SSD_IMPLS`` registry (RunConfig.ssm_backend): pallas (interpret) must
    match the jnp reference through a full Mamba2 block, with and without
    carried state."""
    from repro.configs.base import get_smoke_config, replace as cfg_replace
    from repro.models import ssm as S
    assert set(S.SSD_IMPLS) >= {"jnp", "pallas"}
    cfg = cfg_replace(get_smoke_config("mamba2-130m"), dtype="float32")
    params = S.init(cfg, jax.random.key(0))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = _rand(jax.random.key(1), (2, 64, cfg.d_model), jnp.float32)
    y_j, st_j = S.block_apply(cfg, lp, x, ssd_impl="jnp")
    y_p, st_p = S.block_apply(cfg, lp, x, ssd_impl="pallas")
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_p), atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_j["ssd"]), np.asarray(st_p["ssd"]),
                               atol=3e-4)
    # carried state (the tick-to-tick path the ssm stage program uses)
    st = {"conv": st_j["conv"], "ssd": st_j["ssd"]}
    y_j2, _ = S.block_apply(cfg, lp, x, state=st, ssd_impl="jnp")
    y_p2, _ = S.block_apply(cfg, lp, x, state=st, ssd_impl="pallas")
    np.testing.assert_allclose(np.asarray(y_j2), np.asarray(y_p2), atol=3e-4)
    with pytest.raises(KeyError, match="unknown ssm backend"):
        S.block_apply(cfg, lp, x, ssd_impl="nope")


# ----------------------------------------- non-causal (full-visibility) attn

@pytest.mark.parametrize("b,c,h,kvh,d,t", [
    (2, 32, 4, 2, 40, 96),     # non-lane head dim, prefix-free kv
    (1, 16, 6, 3, 64, 150),    # kv not a block multiple (pad + kv_len mask)
])
def test_full_attention_matches_bidirectional_oracle(b, c, h, kvh, d, t):
    """``ops.full_attention`` (the encdec cross-attention wrapper): every
    query sees every key — must match the naive oracle with masking off."""
    from repro.models import layers as L
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, c, h, d), jnp.float32)
    k = _rand(ks[1], (b, t, kvh, d), jnp.float32)
    v = _rand(ks[2], (b, t, kvh, d), jnp.float32)
    out = ops.full_attention(q, k, v)
    want = L.naive_attention(q, k, v, causal_offset=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
