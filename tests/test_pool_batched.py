"""Batched slot-grid pool attention (`kernels.ops.pool_attention`): the
single-launch pool scan must reproduce the per-slot scan's state — the
combine algebra is associative, but batched (online across slots inside the
kernel) and scanned (per-slot state + traced-level `attn_combine`) evaluate
in different floating-point orders, so the reconciliation is asserted
explicitly here: within 1e-6 (fp32 combine) on float pages, < 2e-3 headroom
on int8 pages (both paths read IDENTICAL quantized pages, so the observed
divergence stays at fp32-rounding level).

Also: the launch-counting hook (`ops.count_launches`) pins the O(1)-in-pool-
depth property, and a hypothesis property test sweeps ragged occupancy
(random slot subsets, mixed chunk ids vs. limit, empty pool, single slot).
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI installs hypothesis; bare containers may not
    given = None


def _require_jax():
    import jax  # noqa: F401
    return jax


def _build_pool(nslots, kv_dtype, b, c, kvh, d, page_tokens, seed=7):
    """Paged pool with ``nslots`` random chunks scattered under the table."""
    import jax
    import jax.numpy as jnp
    from repro.kvstore import pages as PG
    from repro.kvstore import quant as Q
    geom = PG.page_geometry(c, nslots, page_tokens)
    tbl = PG.build_slot_pages(geom)
    codec = Q.get_codec(kv_dtype, "float32")
    pool = PG.alloc_pool(geom, codec, 1, b, kvh, d)
    keys = jax.random.split(jax.random.key(seed), max(2 * nslots, 1))
    for s in range(nslots):
        k = jax.random.normal(keys[2 * s], (1, b, c, kvh, d), jnp.float32)
        v = jax.random.normal(keys[2 * s + 1], (1, b, c, kvh, d), jnp.float32)
        pool = PG.scatter_chunk(pool, jnp.asarray(tbl[s]), k, v, codec)
    sl = lambda a: None if a is None else a[:, 0]
    pool_l = (sl(pool.k), sl(pool.v), sl(pool.k_scale), sl(pool.v_scale))
    return geom, tbl, pool_l


def _scan_states(pool_l, tbl, slot_chunk, limit, qg, slots=None):
    """(jnp per-slot, pallas per-slot, pallas batched) finished outputs +
    raw states for one occupancy pattern."""
    import jax.numpy as jnp
    from repro.core import attention as A
    b, c, kvh, g, d = qg.shape
    scale = 1.0 / math.sqrt(d)
    sc = np.asarray(slot_chunk, np.int32)
    outs, states = {}, {}
    per_slot_pallas = A.PallasBackend()
    per_slot_pallas.batched_pool = False  # force the reference order
    for name, be in (("jnp", A.get_backend("jnp")),
                     ("pallas_scan", per_slot_pallas),
                     ("pallas_batched", A.get_backend("pallas")),
                     ("paged", A.get_backend("paged"))):
        stt = A.pool_scan(be, qg, pool_l, tbl, sc, jnp.int32(limit), scale,
                          A.attn_init(b, c, kvh, g, d), slots=slots)
        states[name] = tuple(np.asarray(x) for x in stt)
        outs[name] = np.asarray(A.attn_finish(stt, jnp.float32))
    return outs, states


def _assert_parity(outs, states, tol):
    ref = outs["pallas_scan"]
    for name in ("pallas_batched", "paged", "jnp"):
        np.testing.assert_allclose(outs[name], ref, atol=tol, rtol=tol)
    # state-level reconciliation (m exact-ish, l/acc to fp32 rounding —
    # the paged kernel sums per PAGE, the gathered kernel per block_k, so
    # both get the same rounding-order headroom vs the per-slot scan)
    for name in ("pallas_batched", "paged"):
        for i in range(3):
            np.testing.assert_allclose(states[name][i],
                                       states["pallas_scan"][i],
                                       atol=tol, rtol=max(tol, 1e-5))


@pytest.mark.parametrize("kv_dtype,tol", [
    ("float32", 1e-6), ("bfloat16", 1e-6), ("int8", 2e-3), ("fp8", 2e-3),
])
def test_batched_pool_matches_per_slot_scan(kv_dtype, tol):
    """Full-pool traversal: batched kernel state == per-slot scan state.
    bfloat16/float32 pages sit at the 1e-6 fp32-combine floor; int8 pages
    get the quantized headroom (both paths read identical pages, so the
    observed error is still rounding-level)."""
    import jax
    import jax.numpy as jnp
    jax  # imported for device init
    b, c, kvh, g, d = 1, 32, 2, 2, 24
    _, tbl, pool_l = _build_pool(4, kv_dtype, b, c, kvh, d, page_tokens=8)
    qg = jax.random.normal(jax.random.key(3), (b, c, kvh, g, d), jnp.float32)
    outs, states = _scan_states(pool_l, tbl, [0, 1, 2, 3, -1], limit=3, qg=qg)
    _assert_parity(outs, states, tol)


@pytest.mark.parametrize("kv_dtype,tol", [("bfloat16", 1e-6), ("int8", 2e-3)])
def test_batched_pool_creditor_subset(kv_dtype, tol):
    """The creditor-side ``slots=`` subset path (qship) through the batched
    kernel: only the listed slots are visited, in listed order."""
    import jax
    import jax.numpy as jnp
    b, c, kvh, g, d = 1, 16, 1, 2, 16
    _, tbl, pool_l = _build_pool(5, kv_dtype, b, c, kvh, d, page_tokens=0)
    qg = jax.random.normal(jax.random.key(5), (b, c, kvh, g, d), jnp.float32)
    outs, states = _scan_states(pool_l, tbl, [4, 2, 0, 1, 3, -1], limit=4,
                                qg=qg, slots=np.asarray([1, 3, 4]))
    _assert_parity(outs, states, tol)


@pytest.mark.parametrize("backend", ["pallas", "paged"])
def test_batched_pool_all_invalid_is_identity(backend):
    """limit=0 invalidates every slot: the batched/paged kernels must
    contribute the EXACT identity state (m=-inf, l=0, acc=0), like the
    gated scan — the paged kernel additionally issues ZERO page copies."""
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    b, c, kvh, g, d = 1, 16, 1, 2, 16
    _, tbl, pool_l = _build_pool(3, "float32", b, c, kvh, d, page_tokens=0)
    qg = jax.random.normal(jax.random.key(1), (b, c, kvh, g, d), jnp.float32)
    st0 = A.attn_init(b, c, kvh, g, d)
    stt = A.pool_scan(A.get_backend(backend), qg, pool_l, tbl,
                      np.asarray([0, 1, 2, -1], np.int32), jnp.int32(0),
                      0.25, st0)
    for a, b_ in zip(st0, stt):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_launch_count_is_o1_in_pool_depth():
    """The acceptance hook: kernel launches per pool scan must be 1 under
    the batched path regardless of pool depth, vs one per slot in the
    per-slot order (counted at RUNTIME via ops.count_launches, so scan
    iterations are counted, not trace sites)."""
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    from repro.kernels import ops
    b, c, kvh, g, d = 1, 16, 1, 2, 16

    def run(be, nslots):
        _, tbl, pool_l = _build_pool(nslots, "float32", b, c, kvh, d, 0)
        qg = jax.random.normal(jax.random.key(0), (b, c, kvh, g, d))
        sc = np.concatenate([np.arange(nslots), [-1]]).astype(np.int32)
        fn = jax.jit(lambda q: A.attn_finish(A.pool_scan(
            be, q, pool_l, tbl, sc, jnp.int32(nslots), 0.25,
            A.attn_init(b, c, kvh, g, d)), jnp.float32))
        with ops.count_launches() as launches:
            fn(qg).block_until_ready()
        return dict(launches)

    batched = A.get_backend("pallas")
    paged = A.get_backend("paged")
    per_slot = A.PallasBackend()
    per_slot.batched_pool = False
    assert run(batched, 3)["count"] == 1
    assert run(batched, 6)["count"] == 1  # O(1): depth-independent
    assert run(per_slot, 3)["count"] == 3
    assert run(per_slot, 6)["count"] == 6  # O(slots): the launch tax
    assert run(A.get_backend("jnp"), 6)["count"] == 0
    # paged: O(1) too, and every launch carries the paged tag — the
    # gathered pool kernel never runs under this backend
    for nslots in (3, 6):
        lc = run(paged, nslots)
        assert lc["count"] == 1, lc
        assert lc["pool_attention_paged"] == 1, lc
        assert "pool_attention" not in lc, lc


def test_pool_backend_plan_resolution():
    """RunConfig.pool_backend: "auto" follows attn_backend; an explicit
    value mixes per source and reaches the plan unchanged."""
    from repro.configs.base import ModelConfig, RunConfig
    from repro.core.plan import build_plan
    cfg = ModelConfig(arch="t", family="dense", num_layers=4, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      head_dim=8, dtype="float32")
    run = RunConfig(num_chunks=8, num_stages=4, attn_backend="pallas")
    assert build_plan(cfg, 4, 128, run).pool_backend == "pallas"
    run = RunConfig(num_chunks=8, num_stages=4, attn_backend="pallas",
                    pool_backend="jnp")
    assert build_plan(cfg, 4, 128, run).pool_backend == "jnp"
    gp = build_plan(cfg, 4, 128, run, mode="gpipe")
    assert gp.pool_backend == "jnp"
    run = RunConfig(num_chunks=8, num_stages=4, attn_backend="pallas",
                    pool_backend="paged")
    assert build_plan(cfg, 4, 128, run).pool_backend == "paged"


# --------------------------------------------------- ragged-occupancy sweep

def _check_occupancy(nslots, chunk_ids, limit, subset_mask, kv_dtype):
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    b, c, kvh, g, d = 1, 16, 1, 2, 16
    _, tbl, pool_l = _build_pool(nslots, kv_dtype, b, c, kvh, d,
                                 page_tokens=8)
    qg = jax.random.normal(jax.random.key(2), (b, c, kvh, g, d), jnp.float32)
    if nslots == 0:  # empty pool: pool_scan must be a no-op on every path
        st0 = A.attn_init(b, c, kvh, g, d)
        for name in ("jnp", "pallas", "paged"):
            stt = A.pool_scan(A.get_backend(name), qg, pool_l, tbl,
                              np.asarray([-1], np.int32), jnp.int32(limit),
                              0.25, st0)
            assert stt is st0
        return
    slots = np.nonzero(subset_mask[:nslots])[0].astype(np.int32)
    sc = list(chunk_ids[:nslots]) + [-1]
    tol = 2e-3 if kv_dtype == "int8" else 1e-6
    outs, states = _scan_states(pool_l, tbl, sc, limit, qg)
    _assert_parity(outs, states, tol)
    if len(slots):
        outs, states = _scan_states(pool_l, tbl, sc, limit, qg, slots=slots)
        _assert_parity(outs, states, tol)


if given is not None:
    @settings(max_examples=12, deadline=None)
    @given(
        nslots=st.integers(min_value=0, max_value=5),
        chunk_ids=st.lists(st.integers(min_value=-1, max_value=7),
                           min_size=5, max_size=5),
        limit=st.integers(min_value=0, max_value=8),
        subset_mask=st.lists(st.booleans(), min_size=5, max_size=5),
        kv_dtype=st.sampled_from(["bfloat16", "int8"]),
    )
    def test_ragged_occupancy_property(nslots, chunk_ids, limit, subset_mask,
                                       kv_dtype):
        """Random slot subsets x mixed chunk ids vs. limit x empty/single-
        slot edges: batched-kernel state == per-slot-scan state on both
        page codecs and both backends."""
        _check_occupancy(nslots, np.asarray(chunk_ids), limit,
                         np.asarray(subset_mask), kv_dtype)
else:
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_ragged_occupancy_property():
        pass


# ------------------------------------------- deterministic ragged coverage

RAGGED_CASES = [
    # (nslots, chunk_ids, limit, subset_mask, kv_dtype) — hand-picked rows
    # of the hypothesis space above, run unconditionally (no hypothesis
    # needed): empty pool, single slot, limit-0, mixed ids, full house
    (0, [-1, -1, -1, -1, -1], 3, [False] * 5, "bfloat16"),
    (1, [0, -1, -1, -1, -1], 1, [True] * 5, "int8"),
    (3, [0, 1, 2, -1, -1], 0, [True] * 5, "bfloat16"),
    (5, [0, 1, -1, 3, 7], 4, [True, False, True, True, False], "bfloat16"),
    (4, [2, 0, 5, 1, -1], 2, [False, False, True, True, False], "int8"),
    (5, [6, 7, 5, 4, 3], 8, [True] * 5, "int8"),
]


@pytest.mark.parametrize("nslots,chunk_ids,limit,subset_mask,kv_dtype",
                         RAGGED_CASES)
def test_ragged_occupancy_cases(nslots, chunk_ids, limit, subset_mask,
                                kv_dtype):
    """Deterministic ragged-occupancy sweep (all four traversal orders,
    incl. the paged kernel): random slot subsets, mixed chunk ids vs.
    limit, empty pool, single slot, all-invalid."""
    _check_occupancy(nslots, np.asarray(chunk_ids), limit,
                     np.asarray(subset_mask), kv_dtype)


@pytest.mark.parametrize("use_dma", [True, False])
def test_paged_partial_last_page(use_dma):
    """``kv_len`` < C: the paged kernel masks the partial page's tail AND
    statically drops trailing all-dead pages (np_eff), on both buffering
    schemes (manual double-buffered DMA and the BlockSpec fallback) —
    parity vs the gathered kernel on token-truncated stacks."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kvstore import pages as PG
    b, c, kvh, g, d = 1, 32, 2, 2, 32
    nslots, kv_len = 3, 20  # pt=8: pages 0-1 full, page 2 partial, page 3 dead
    _, tbl, pool_l = _build_pool(nslots, "float32", b, c, kvh, d,
                                 page_tokens=8)
    k_l, v_l, ks_l, vs_l = pool_l
    rows = PG.handle_rows(tbl)
    assert rows.shape == (nslots, 4)
    handles = jnp.asarray(rows, jnp.int32).reshape(-1)
    valid = jnp.ones((nslots,), jnp.int32)
    q = jax.random.normal(jax.random.key(9), (b, c, kvh * g, d), jnp.float32)
    m, l, acc = ops.pool_attention_paged(q, k_l, v_l, handles, valid,
                                         ppc=rows.shape[1], kv_len=kv_len,
                                         use_dma=use_dma)
    kq, vq, _, _ = PG.gather_chunks(k_l, v_l, ks_l, vs_l, jnp.asarray(rows))
    mr, lr, accr = ops.pool_attention(q, kq[:, :, :kv_len], vq[:, :, :kv_len],
                                      valid)
    for got, ref in ((m, mr), (l, lr), (acc, accr)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-6, rtol=1e-5)


def test_paged_pool_scan_has_no_gather_intermediate():
    """Acceptance: the lowered paged pool scan contains NO dense
    [S, B, C, KVH, *] slot-stack intermediate and no [S*ppc, B, pt, KVH, *]
    page-take — the HBM copies the paged kernel exists to delete — while
    the gathered batched trace DOES carry the slot stack."""
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    b, c, kvh, g, d = 1, 32, 2, 2, 32
    nslots, pt, ppc = 4, 8, 4
    _, tbl, pool_l = _build_pool(nslots, "float32", b, c, kvh, d,
                                 page_tokens=pt)
    qg = jax.random.normal(jax.random.key(4), (b, c, kvh, g, d), jnp.float32)
    sc = np.asarray([0, 1, 2, 3, -1], np.int32)

    def all_shapes(backend):
        fn = lambda q: A.attn_finish(A.pool_scan(
            A.get_backend(backend), q, pool_l, tbl, sc, jnp.int32(4), 0.25,
            A.attn_init(b, c, kvh, g, d)), jnp.float32)
        jaxpr = jax.make_jaxpr(fn)(qg)
        shapes = set()

        def walk(jx):
            for eqn in jx.eqns:
                for var in list(eqn.invars) + list(eqn.outvars):
                    aval = getattr(var, "aval", None)
                    shp = getattr(aval, "shape", None)
                    if shp is not None:
                        shapes.add(tuple(shp))
                for val in eqn.params.values():
                    sub(val)

        def sub(val):
            if hasattr(val, "jaxpr"):       # ClosedJaxpr
                sub(val.jaxpr)
            elif hasattr(val, "eqns"):      # Jaxpr
                walk(val)
            elif isinstance(val, (list, tuple)):
                for item in val:
                    sub(item)

        walk(jaxpr.jaxpr)
        return shapes

    def gathers(shapes):
        slot_stack = [s for s in shapes
                      if len(s) == 5 and s[:4] == (nslots, b, c, kvh)]
        page_take = [s for s in shapes
                     if len(s) == 5 and s[:4] == (nslots * ppc, b, pt, kvh)]
        return slot_stack + page_take

    assert gathers(all_shapes("pallas")), "oracle lost its gather?"
    leaked = gathers(all_shapes("paged"))
    assert not leaked, f"paged trace materializes a gather: {leaked}"
