"""Per-architecture smoke tests: reduced config of the same family, one
forward (and decode) step on CPU, asserting shapes + finite outputs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, get_config, get_smoke_config, list_archs
from repro.models.api import build_model

ASSIGNED = (
    "whisper-small", "qwen3-8b", "stablelm-3b", "granite-3-2b", "qwen3-14b",
    "granite-moe-3b-a800m", "qwen2-moe-a2.7b", "llava-next-34b",
    "zamba2-7b", "mamba2-130m",
)
PAPER_MODELS = ("llama3-70b", "mistral-123b", "qwen3-235b", "llama3-405b")


def _inputs(cfg, b=2, s=32):
    kw = {}
    toks = jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) % cfg.vocab_size
    if cfg.frontend.kind in ("vision_stub", "audio_stub") or cfg.family == "encdec":
        kw["embeds"] = jnp.full((b, 8, cfg.d_model), 0.01, jnp.bfloat16)
    return toks, kw


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_MODELS)
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks, kw = _inputs(cfg)
    logits = model.forward(params, toks, **kw)
    b, s = toks.shape
    s_out = s + (kw["embeds"].shape[1] if cfg.frontend.kind == "vision_stub" else 0)
    assert logits.shape[0] == b and logits.shape[1] == s_out
    assert logits.shape[2] >= cfg.vocab_size  # padded vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 64)
    logits, cache2 = model.decode_step(params, cache,
                                       jnp.zeros((2,), jnp.int32))
    assert logits.shape[0] == 2
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["pos"][0]) == 1
    # second step advances
    logits2, cache3 = model.decode_step(params, cache2,
                                        jnp.ones((2,), jnp.int32))
    assert int(cache3["pos"][0]) == 2


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The exact assigned numbers (layer count, width, heads, vocab)."""
    spec = {
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"


def test_moe_details():
    g = get_config("granite-moe-3b-a800m")
    assert (g.moe.num_experts, g.moe.top_k) == (40, 8)
    q = get_config("qwen2-moe-a2.7b")
    assert (q.moe.num_experts, q.moe.top_k, q.moe.num_shared_experts) == (60, 4, 4)


def test_ssm_details():
    m = get_config("mamba2-130m")
    assert m.ssm.d_state == 128 and m.family == "ssm"
    z = get_config("zamba2-7b")
    assert z.ssm.d_state == 64 and z.hybrid.total_layers == 81


def test_loss_vlm_label_alignment():
    """VLM: embeds splice in front; loss scores token positions only."""
    cfg = get_smoke_config("llava-next-34b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks, kw = _inputs(cfg)
    labels = toks
    loss = model.loss(params, toks, labels, **kw)
    assert bool(jnp.isfinite(loss))


def test_shape_registry():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert len(list_archs()) >= 14
