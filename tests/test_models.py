"""Layer-level tests: attention impl agreement (naive / xla_flash / pallas),
RoPE/RMSNorm, MoE dispatch exactness, SSD chunk invariance, decode vs prefill
consistency for the KV cache path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip the
#   module cleanly instead of erroring out the whole collection
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_smoke_config, replace
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.api import build_model

KEY = jax.random.key(3)


# --------------------------------------------------------------- attention

@pytest.mark.parametrize("impl", ["xla_flash", "pallas"])
@pytest.mark.parametrize("offset", [0, 37, None])
def test_attention_impls_agree(impl, offset):
    if impl == "pallas" and offset is None:
        pytest.skip("pallas kernel is causal-only (encoder uses xla_flash)")
    b, sq, skv, h, kvh, d = 2, 16, 48, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, skv, kvh, d))
    v = jax.random.normal(ks[2], (b, skv, kvh, d))
    if offset == 0:
        k2, v2 = k[:, :sq], v[:, :sq]
    else:
        k2, v2 = k, v
    want = L.naive_attention(q, k2, v2, causal_offset=offset)
    got = L.attention(q, k2, v2, causal_offset=offset, impl=impl,
                      block_k=16 if impl == "xla_flash" else 1024)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_blocked_matches_naive_long():
    b, sq, h, d = 1, 64, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, 512, h, d))
    v = jax.random.normal(ks[2], (b, 512, h, d))
    want = L.naive_attention(q, k, v, causal_offset=448)
    got = L.flash_attention_xla(q, k, v, causal_offset=448, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(sq=st.integers(1, 24), p=st.integers(0, 64),
       h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]))
def test_attention_property(sq, p, h, g):
    kvh = h // g
    d = 16
    ks = jax.random.split(jax.random.key(sq * 100 + p), 3)
    q = jax.random.normal(ks[0], (1, sq, h, d))
    k = jax.random.normal(ks[1], (1, p + sq, kvh, d))
    v = jax.random.normal(ks[2], (1, p + sq, kvh, d))
    want = L.naive_attention(q, k, v, causal_offset=p)
    got = L.flash_attention_xla(q, k, v, causal_offset=p, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


# ------------------------------------------------------------------- rope

def test_rope_relative_shift():
    """RoPE: scores depend only on relative positions."""
    d = 32
    q = jax.random.normal(KEY, (1, 4, 1, d))
    k = jax.random.normal(jax.random.key(9), (1, 4, 1, d))
    def scores(off):
        pos = jnp.arange(4)[None, :] + off
        cos, sin = L.rope_angles(pos, d, 10000.0)
        qr, kr = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
        return jnp.einsum("bqhd,bkhd->bqk", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(0)), np.asarray(scores(100)),
                               atol=1e-4)


def test_rms_norm():
    x = jax.random.normal(KEY, (2, 3, 8)) * 5
    w = jnp.full((8,), 2.0)
    y = L.rms_norm(x, w, 1e-6)
    ms = np.mean(np.asarray(y / 2) ** 2, axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=1e-3)


# -------------------------------------------------------------------- moe

def test_moe_exact_at_high_capacity():
    """With capacity >= tokens, token-choice MoE == dense per-expert mix."""
    b, s, dm, e, k, f = 2, 8, 16, 4, 2, 32
    ks = jax.random.split(KEY, 4)
    params = {
        "router": jax.random.normal(ks[0], (dm, e)),
        "wg": jax.random.normal(ks[1], (e, dm, f)) * 0.1,
        "wu": jax.random.normal(ks[2], (e, dm, f)) * 0.1,
        "wd": jax.random.normal(ks[3], (e, f, dm)) * 0.1,
    }
    x = jax.random.normal(jax.random.key(42), (b, s, dm))
    got = L.moe_layer(params, x, num_experts=e, top_k=k, capacity_factor=float(e))
    # reference: dense top-k mixture
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    w, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(w, axis=-1)
    def expert(i, xe):
        g = jnp.einsum("d,df->f", xe, params["wg"][i])
        u = jnp.einsum("d,df->f", xe, params["wu"][i])
        return jnp.einsum("f,fd->d", jax.nn.silu(g) * u, params["wd"][i])
    want = np.zeros((b, s, dm), np.float32)
    for bi in range(b):
        for si in range(s):
            for ki in range(k):
                want[bi, si] += float(w[bi, si, ki]) * np.asarray(
                    expert(int(idx[bi, si, ki]), x[bi, si]))
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    """Low capacity: output is a partial sum (never NaN, never amplified)."""
    b, s, dm, e = 1, 32, 8, 2
    ks = jax.random.split(KEY, 4)
    params = {
        "router": jnp.zeros((dm, e)).at[0, 0].set(10.0),  # all to expert 0
        "wg": jnp.ones((e, dm, 8)) * 0.1,
        "wu": jnp.ones((e, dm, 8)) * 0.1,
        "wd": jnp.ones((e, 8, dm)) * 0.1,
    }
    x = jnp.ones((b, s, dm))
    got = L.moe_layer(params, x, num_experts=e, top_k=1, capacity_factor=0.25)
    assert bool(jnp.isfinite(got).all())
    # ~ s/e*cf = 4 tokens kept of 32
    nz = int((jnp.abs(got).sum(-1) > 1e-9).sum())
    assert 0 < nz <= 8


# -------------------------------------------------------------------- ssd

def test_ssd_chunk_invariance():
    b, t, h, p, g, n = 1, 64, 2, 4, 1, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a_log = jnp.zeros((h,))
    bm = jax.random.normal(ks[2], (b, t, g, n))
    cm_ = jax.random.normal(ks[3], (b, t, g, n))
    dsk = jnp.ones((h,))
    y1, s1 = S.ssd_chunked(x, dt, a_log, bm, cm_, dsk, chunk=64)
    y2, s2 = S.ssd_chunked(x, dt, a_log, bm, cm_, dsk, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_matches_decode_recurrence():
    """Chunked SSD == token-by-token decode steps."""
    b, t, h, p, g, n = 1, 16, 2, 4, 1, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a_log = jnp.zeros((h,))
    bm = jax.random.normal(ks[2], (b, t, g, n))
    cm_ = jax.random.normal(ks[3], (b, t, g, n))
    dsk = jnp.ones((h,))
    y, st = S.ssd_chunked(x, dt, a_log, bm, cm_, dsk, chunk=8)
    state = jnp.zeros((b, h, p, n))
    outs = []
    for i in range(t):
        yi, state = S.ssd_decode_step(x[:, i], dt[:, i], a_log, bm[:, i],
                                      cm_[:, i], dsk, state)
        outs.append(yi)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dec), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(state), atol=1e-4)


# --------------------------------------------------- prefill/decode bridge

@pytest.mark.parametrize("arch", ["qwen3-8b", "zamba2-7b", "mamba2-130m"])
def test_decode_continues_prefill(arch):
    """forward(return_cache) then decode_step == forward on the longer seq."""
    cfg = replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    logits_all = model.forward(params, toks)
    logits_pre, cache = model.forward(params, toks[:, :16], return_cache=True)
    if "k" in cache:  # pad the KV seq dim so the decode write is in-bounds
        pad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, 16), (0, 0), (0, 0)))
        cache = {**cache, "k": pad(cache["k"]), "v": pad(cache["v"])}
    logits_dec, _ = model.decode_step(params, cache, toks[:, 16])
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_all[:, -1]), atol=2e-3)
