"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — unit/smoke tests see
the real single device; multi-device pipeline tests spawn subprocesses with
--xla_force_host_platform_device_count (see test_pipeline.py)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)
