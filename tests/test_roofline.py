"""HLO graph analyzer calibration: known FLOPs/bytes/collective cases run in
a subprocess with 8 fake devices (mesh collectives need > 1 device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SNIPPET = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.compat import AxisType
from repro.roofline.hlo_graph import analyze_text

mesh = compat.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
sh = NamedSharding(mesh, P("data", None))
rep = NamedSharding(mesh, P(None, None))
A = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

# 1. sharded matmul: per-device flops = 2*1024^3/8
g = analyze_text(jax.jit(lambda a, b: a @ b, in_shardings=(sh, rep))
                 .lower(A, A).compile().as_text())
assert abs(g.flops - 2 * 1024**3 / 8) < 1e4, g.flops

# 2. scan trip scaling: 10 * 2*256^3
def f(x):
    def body(c, _):
        return c @ c, None
    return jax.lax.scan(body, x, None, length=10)[0]
g2 = analyze_text(jax.jit(f).lower(jnp.ones((256, 256))).compile().as_text())
assert abs(g2.flops - 10 * 2 * 256**3) / g2.flops < 0.01, g2.flops

# 3. all-gather wire bytes: 4MB * 7/8
g3 = analyze_text(jax.jit(lambda x: jax.lax.with_sharding_constraint(x * 2, rep),
                          in_shardings=(sh,)).lower(A).compile().as_text())
ag = g3.coll.get("all-gather", 0)
assert abs(ag - 4 * 1024 * 1024 * 7 / 8) < 1e4, g3.coll

# 4. psum -> all-reduce wire bytes: 2 * size * 7/8
def h(x):
    return compat.shard_map(lambda y: jax.lax.psum(y, "data"), mesh=mesh,
                            in_specs=P("data", None), out_specs=P(None, None),
                            axis_names={"data"})(x)
g4 = analyze_text(jax.jit(h).lower(A).compile().as_text())
ar = g4.coll.get("all-reduce", 0)
want = 2 * (1024 * 1024 * 4 / 8) * 8 * 7 / 8  # out is full [1024,1024]? local psum output = [128*8...]
# out shape replicated [1024,1024]? psum over shard_map: out [128,1024] per dev -> wire = 2*out*(7/8)
assert ar > 0, g4.coll
print("CALIBRATION OK")
"""


def test_hlo_graph_calibration():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", SNIPPET], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "CALIBRATION OK" in r.stdout


def test_model_flops_formulas():
    from repro.configs.base import SHAPES, get_config
    from repro.roofline.analysis import model_flops
    cfg = get_config("qwen3-8b")
    n = cfg.param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6 * n * 4096 * 256, rel=1e-9)
    assert model_flops(cfg, SHAPES["prefill_32k"]) == pytest.approx(
        2 * n * 32768 * 32, rel=1e-9)
    moe = get_config("qwen2-moe-a2.7b")
    assert moe.active_param_count() < moe.param_count() * 0.35


def test_roofline_terms_math():
    from repro.roofline.analysis import RooflineTerms
    t = RooflineTerms(flops_dev=197e12, bytes_dev=819e9 / 2, coll_dev=0.0,
                      coll_by_kind={}, chips=2, model_flops=2 * 197e12)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.dominant == "compute"
    assert t.useful_ratio == pytest.approx(1.0)
    assert t.roofline_fraction == pytest.approx(1.0)
