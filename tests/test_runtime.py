"""Runtime substrate tests: checkpoint atomicity/integrity/elasticity, data
pipeline determinism, serving-engine fault tolerance and stragglers."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.data import SyntheticLM
from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)
from repro.runtime.engine import (EngineConfig, PrefillEngine, Request,
                                  SimExecutor)


# ------------------------------------------------------------- checkpoints

def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"w": jnp.ones((4,), jnp.bfloat16) * 1.5,
              "step": jnp.int32(7)},
        "c": [jnp.zeros((2, 2), jnp.int8)],
    }
    save_checkpoint(str(tmp_path), 3, tree, extra={"note": "x"})
    got, extra = restore_checkpoint(str(tmp_path))
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_and_latest(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 5, {"x": jnp.ones(2)})
    assert latest_step(str(tmp_path)) == 5
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    got, _ = restore_checkpoint(str(tmp_path), step=1)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.zeros(2))


def test_checkpoint_integrity_check(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(8)})
    # corrupt the leaf
    leaf = os.path.join(tmp_path, "step_00000001", "x.npy")
    with open(leaf, "r+b") as f:
        f.seek(-1, 2)
        f.write(b"\xff")
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path))


# ------------------------------------------------------------------- data

def test_data_determinism_and_sharding():
    a = SyntheticLM(1000, 64, 8, seed=1, shard=0, num_shards=2)
    b = SyntheticLM(1000, 64, 8, seed=1, shard=1, num_shards=2)
    a2 = SyntheticLM(1000, 64, 8, seed=1, shard=0, num_shards=2)
    ba, bb, ba2 = a.next_batch(), b.next_batch(), a2.next_batch()
    np.testing.assert_array_equal(ba["tokens"], ba2["tokens"])
    assert not np.array_equal(ba["tokens"], bb["tokens"])
    assert ba["tokens"].shape == (4, 64)
    assert (ba["labels"][:, :-1] == ba["tokens"][:, 1:]).all()


def test_data_resume():
    a = SyntheticLM(1000, 32, 4, seed=9)
    for _ in range(3):
        a.next_batch()
    ck = a.checkpoint()
    want = a.next_batch()
    b = SyntheticLM(1000, 32, 4, seed=9)
    b.restore(ck)
    np.testing.assert_array_equal(b.next_batch()["tokens"], want["tokens"])


def test_data_has_motif_structure():
    a = SyntheticLM(5000, 128, 2, seed=0)
    t = a.next_batch()["tokens"]
    # the copied motif exists: some 8-gram repeats within each row
    found = 0
    for row in t:
        s = row.tolist()
        for i in range(0, 56):
            if s[i:i + 8] == s[i + 64:i + 72]:
                found += 1
                break
    assert found >= 1


# ----------------------------------------------------------------- engine

def _engine(max_batch=2, **exkw):
    ec = EngineConfig(model=get_config("llama3-70b"), hw=cm.WSC_PAPER,
                      num_stages=16, tp=1, sa_iters=8, partition="uniform",
                      max_batch=max_batch)
    return PrefillEngine(ec, SimExecutor(ec.model, ec.hw, **exkw))


def test_engine_drains_queue():
    eng = _engine()
    for i in range(5):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=30000))
    eng.run_until_drained()
    m = eng.metrics()
    assert m["completed"] == 5 and m["throughput"] > 0


def test_engine_stage_failure_remesh_and_replay():
    eng = _engine(fail_at={2: 5})
    for i in range(6):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=30000))
    eng.run_until_drained()
    m = eng.metrics()
    assert m["completed"] == 6
    assert m["remeshes"] == 1 and m["num_stages"] == 14
    assert sum(r.replays for r in eng.done) == 2


def test_engine_straggler_eviction():
    eng = _engine(slow={7: 5.0})
    eng.ec = eng.ec  # evict_threshold = 3.0 < 5.0 skew after EWMA settles
    for i in range(8):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=30000))
    eng.run_until_drained()
    m = eng.metrics()
    assert m["completed"] == 8
    assert m["remeshes"] >= 1, "persistent straggler must be evicted"


def test_engine_state_roundtrip():
    eng = _engine()
    for i in range(4):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=30000))
    eng.step()
    sd = eng.state_dict()
    assert json.dumps(sd)  # JSON-serializable
    eng2 = _engine()
    eng2.load_state_dict(sd)
    assert eng2.clock == pytest.approx(eng.clock)     # state restored exactly
    assert len(eng2.done) == len(eng.done)
    eng2.run_until_drained()
    assert len(eng2.done) == 4                        # finishes the rest


def test_engine_bucketing():
    eng = _engine(max_batch=8)
    eng.submit(Request(rid=0, arrival=0.0, seq_len=5000))
    eng.submit(Request(rid=1, arrival=0.0, seq_len=30000))
    assert eng.queue[0].bucket == 8192
    assert eng.queue[1].bucket == 32768


def test_engine_no_head_of_line_blocking_across_buckets():
    """The batch bucket follows the OLDEST eligible request across buckets,
    not the first queue entry — one hot bucket cannot starve the others."""
    eng = _engine(max_batch=2)
    # queue order != arrival order: a late big-bucket request sits first
    eng.submit(Request(rid=0, arrival=5.0, seq_len=30000))
    eng.submit(Request(rid=1, arrival=0.0, seq_len=5000))
    eng.submit(Request(rid=2, arrival=1.0, seq_len=30000))
    eng.step()
    done = sorted(r.rid for r in eng.done)
    assert done == [1], "oldest arrival's bucket (8192) must run first"
    eng.run_until_drained()
    assert sorted(r.rid for r in eng.done) == [0, 1, 2]


def test_engine_batch_is_arrival_ordered_within_bucket():
    eng = _engine(max_batch=2)
    for rid, arr in ((0, 3.0), (1, 1.0), (2, 2.0)):
        eng.submit(Request(rid=rid, arrival=arr, seq_len=30000))
    eng.step()
    assert sorted(r.rid for r in eng.done) == [1, 2], \
        "the two oldest arrivals form the batch, not the first two submitted"


def test_engine_straggler_scales_only_affected_stage():
    """A slow stage inflates only its own tick latency; the makespan is
    recomputed from per-stage times, NOT multiplied wholesale by the worst
    factor (the old `max(slow.values())` behavior)."""
    eng_base = _engine(max_batch=1)
    eng_slow = _engine(max_batch=1, slow={3: 1.5})
    for eng in (eng_base, eng_slow):
        eng.submit(Request(rid=0, arrival=0.0, seq_len=30000))
        eng.run_until_drained()
    mk_b, mk_s = eng_base.clock, eng_slow.clock
    assert mk_s > mk_b, "a slow stage must still cost something"
    # chunks only transit stage 3 for M of the M+N-1 pipeline ticks, so the
    # blowup must be strictly below the stage's own 1.5x factor
    assert mk_s < mk_b * 1.5 * 0.95
    # and the per-stage observation the EWMA sees is scaled ONLY at stage 3
    lat = eng_slow.ewma
    assert lat[3] == pytest.approx(1.5 * lat[2], rel=1e-6)


def test_engine_checkpoint_roundtrip_field_fidelity():
    """state_dict round-trips the fields that must survive (see its
    docstring); tokens/result are intentionally dropped, queued finish_time
    resets to inf, and buckets are recomputed from seq_len."""
    eng = _engine(max_batch=2)
    eng.submit(Request(rid=0, arrival=0.5, seq_len=30000,
                       tokens=np.arange(4), replays=1))
    eng.submit(Request(rid=1, arrival=1.5, seq_len=5000))
    eng.step()   # completes the 8192 bucket (rid 1? no: oldest is rid 0)
    sd = eng.state_dict()
    assert json.dumps(sd)
    eng2 = _engine(max_batch=2)
    eng2.load_state_dict(sd)
    assert eng2.clock == pytest.approx(eng.clock)
    assert eng2.num_stages == eng.num_stages
    assert eng2.replans == eng.replans and eng2.remeshes == eng.remeshes
    by_rid = {r.rid: r for r in eng2.queue}
    for orig in eng.queue:
        got = by_rid[orig.rid]
        assert (got.arrival, got.seq_len, got.replays) == \
            (orig.arrival, orig.seq_len, orig.replays)
        assert got.bucket == orig.bucket        # recomputed, must agree
        assert got.tokens is None               # intentionally dropped
        assert got.finish_time == np.inf        # queued => not finished
    done2 = {r.rid: r for r in eng2.done}
    for orig in eng.done:
        got = done2[orig.rid]
        assert got.finish_time == pytest.approx(orig.finish_time)
        assert (got.arrival, got.seq_len) == (orig.arrival, orig.seq_len)
