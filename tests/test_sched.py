"""Continuous chunk-level scheduler tests: cross-request pipelining beats the
batch-synchronous engine, KV leases never exceed the MBKR slot budget under
concurrent requests, EDF beats FCFS on an adversarial deadline trace, and the
trace/metrics/arrival plumbing is sound."""
import json

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                  PrefillEngine, Request, SimExecutor)
from repro.sched import (KVLeaseManager, Lease, LeaseEvent, poisson_arrivals)

CFG = get_config("llama3-70b")


def _ec(buckets=(65536,), partition="uniform", max_batch=8, **kw):
    return EngineConfig(model=CFG, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                        num_chunks=16, max_batch=max_batch, buckets=buckets,
                        partition=partition, sa_iters=8, **kw)


def _continuous(ec, policy="fcfs", slo=None, inflight=2, trace=False,
                executor=None):
    from dataclasses import replace as dc_replace
    ec = dc_replace(ec, policy=policy, slo=slo, inflight=inflight, trace=trace)
    return ContinuousEngine(ec, executor or SimExecutor(CFG, ec.hw))


def _submit_burst(eng, n, seq_len, arrival=0.0):
    for i in range(n):
        eng.submit(Request(rid=i, arrival=arrival, seq_len=seq_len))


# ------------------------------------------------- throughput (acceptance)

def test_continuous_beats_batch_sync_1_5x():
    """16 stages x 16 chunks x 8 requests closed loop: continuous chunk-level
    scheduling must deliver >= 1.5x the batch-synchronous req/s."""
    ec = _ec()
    batch = PrefillEngine(ec, SimExecutor(CFG, ec.hw))
    _submit_burst(batch, 8, 65536)
    batch.run_until_drained()

    cont = _continuous(ec)
    _submit_burst(cont, 8, 65536)
    cont.run_until_drained()

    rb = batch.metrics()["throughput"]
    rc = cont.metrics()["throughput"]
    assert rc >= 1.5 * rb, f"continuous {rc:.3f} vs batch {rb:.3f} req/s"
    assert cont.metrics()["completed"] == 8


def test_chunk0_injected_when_stage0_vacated():
    """The next request's chunk 0 starts on stage 0 exactly when the previous
    request's tail chunk vacates it — no refill bubble."""
    ec = _ec()
    eng = _continuous(ec, trace=True)
    _submit_burst(eng, 2, 65536)
    eng.run_until_drained()
    tasks = eng.trace.tasks
    tail_vacate = max(t.finish for t in tasks
                      if t.rid == 0 and t.stage == 0)
    head_start = min(t.start for t in tasks
                     if t.rid == 1 and t.stage == 0)
    assert head_start == pytest.approx(tail_vacate, rel=1e-9)


def test_incremental_request_cost_is_bubble_free():
    """Adding requests costs ~M chunk-ticks each, not a full fill+drain."""
    ec = _ec()
    mk = {}
    for n in (1, 4):
        eng = _continuous(ec)
        _submit_burst(eng, n, 65536)
        eng.run_until_drained()
        mk[n] = eng.metrics()["makespan"]
    incr = (mk[4] - mk[1]) / 3.0
    assert incr < mk[1] * 0.85, "per-request increment must beat fill+drain"


# -------------------------------------------------------- KV lease manager

def test_lease_never_exceeds_budget_concurrent_mixed_buckets():
    """Acceptance (a): under concurrent in-flight requests across buckets,
    no per-stage KV lease occupancy ever exceeds the MBKR slot budget."""
    ec = _ec(buckets=(16384, 65536, 131072))
    eng = _continuous(ec)
    arrivals = poisson_arrivals(6.0, 24, seed=3)
    rng = np.random.default_rng(3)
    seqs = rng.choice([12000, 50000, 120000], size=24)
    for i in range(24):
        eng.submit(Request(rid=i, arrival=float(arrivals[i]),
                           seq_len=int(seqs[i])))
    eng.run_until_drained()
    lease = eng.lease
    assert lease.hwm.max() > 0, "lease accounting must have observed traffic"
    assert np.all(lease.hwm <= lease.budget * (1 + 1e-9)), (
        f"lease hwm {lease.hwm} exceeds budget {lease.budget}")
    assert eng.metrics()["completed"] == 24


def test_lease_tight_budget_defers_but_never_overflows():
    """With a pool that fits one in-flight request (the event-driven solo
    peak is 13 slots for M=N=16) but NOT the full uniform-chunk cross-request
    overlap (~15 slots), admissions must be DEFERRED (refusals observed) yet
    the budget is never exceeded and every request still completes."""
    ec = _ec()
    eng = _continuous(ec)
    eng.lease.budget[:] = 14 * eng._chunk_plan(65536).kvb[0]
    _submit_burst(eng, 6, 65536)
    eng.run_until_drained()
    assert eng.lease.refusals > 0
    assert np.all(eng.lease.hwm <= eng.lease.budget * (1 + 1e-9))
    assert eng.metrics()["completed"] == 6


def test_page_granular_lease_admits_long_tail_sooner():
    """Page-granular lease events (kvlease.chunk_page_bytes): a request
    filling only part of its bucket leases only the pages its valid tokens
    touch — the unused bucket tail stops reserving phantom bytes. Under the
    same tight budget, the longer-tail workload must run with a lower
    occupancy peak, fewer deferrals, and earlier final admission than the
    bucket-filling workload."""
    ec = _ec(kv_page_tokens=256)
    runs = {}
    for name, seq in (("full", 65536), ("tail", 40000)):
        eng = _continuous(ec)
        eng.lease.budget[:] = 14 * eng._chunk_plan(65536).kvb[0]
        _submit_burst(eng, 6, seq)
        eng.run_until_drained()
        assert np.all(eng.lease.hwm <= eng.lease.budget * (1 + 1e-9))
        assert eng.metrics()["completed"] == 6
        runs[name] = {
            "refusals": eng.lease.refusals,
            "hwm": float(eng.lease.hwm.max()),
            "last_admit": max(sr.admit_time for sr in eng.scheduler.admitted),
        }
    assert runs["full"]["refusals"] > 0  # the tight budget actually bites
    assert runs["tail"]["hwm"] < runs["full"]["hwm"]
    assert runs["tail"]["refusals"] < runs["full"]["refusals"]
    assert runs["tail"]["last_admit"] < runs["full"]["last_admit"]


def test_chunk_page_bytes_unit():
    """Per-chunk page accounting: rounds UP to whole pages, zeroes chunks
    beyond seq_len, caps at the whole-chunk figure, and preserves the
    legacy whole-bucket totals when seq_len is None."""
    from repro.sched.kvlease import chunk_page_bytes
    kvb = [4096.0, 4096.0, 4096.0, 4096.0]
    chunks = [1024, 1024, 1024, 1024]
    # full bucket: identical to legacy
    assert chunk_page_bytes(kvb, chunks, 4096, 256) == kvb
    assert chunk_page_bytes(kvb, chunks, None, 256) == kvb
    # 2.5 chunks valid: tail chunk rounds up to pages, last chunk drops
    got = chunk_page_bytes(kvb, chunks, 2560, 256)
    assert got[0] == got[1] == 4096.0
    assert got[2] == 4096.0 * 2 / 4  # 512 tokens -> 2 of 4 pages
    assert got[3] == 0.0
    # page rounding: 1 token into a page still leases the whole page
    got = chunk_page_bytes(kvb, chunks, 1025, 256)
    assert got[1] == 4096.0 / 4
    # page_tokens=0 -> one page per chunk (touched = fully leased)
    got = chunk_page_bytes(kvb, chunks, 1025, 0)
    assert got[:2] == [4096.0, 4096.0] and got[2:] == [0.0, 0.0]


def test_lease_manager_unit():
    mgr = KVLeaseManager(2, [10.0, 10.0])
    l1 = Lease(0, (LeaseEvent(0, 1.0, 8.0), LeaseEvent(0, 5.0, -8.0)), 5.0)
    assert mgr.admit(l1)
    # 8 + 8 > 10 while overlapping -> refused
    l2 = Lease(1, (LeaseEvent(0, 2.0, 8.0), LeaseEvent(0, 6.0, -8.0)), 6.0)
    assert not mgr.admit(l2)
    assert mgr.refusals == 1
    # disjoint in time -> fits
    l3 = Lease(2, (LeaseEvent(0, 5.0, 8.0), LeaseEvent(0, 9.0, -8.0)), 9.0)
    assert mgr.admit(l3)
    assert mgr.next_release(0.0) == 5.0
    assert mgr.hwm[0] <= 10.0
    mgr.prune(before=7.0)
    assert 0 not in mgr.leases and 2 in mgr.leases


def test_infeasible_request_rejected_not_hung():
    """A request whose lease cannot fit even an empty pool is rejected."""
    ec = _ec()
    eng = _continuous(ec)
    eng.lease.budget[:] = 1.0  # 1 byte: nothing fits
    _submit_burst(eng, 2, 65536)
    eng.run_until_drained()
    m = eng.metrics()
    assert m["rejected"] == 2 and m["completed"] == 0


# -------------------------------------------------------------- policies

def _adversarial_trace(eng, l_small, l_big):
    """One huge loose-deadline request (rid 0) plus five small tight-deadline
    requests, all arriving in the same burst; FCFS's rid tiebreak runs the
    big one first and blows every small deadline."""
    eng.submit(Request(rid=0, arrival=0.0, seq_len=131072,
                       deadline=2 * l_big + 10 * l_small))
    for i in range(5):
        eng.submit(Request(rid=1 + i, arrival=0.0, seq_len=16384,
                           deadline=(i + 2.5) * l_small))


def _solo_latency(ec, seq_len):
    eng = _continuous(ec)
    eng.submit(Request(rid=0, arrival=0.0, seq_len=seq_len))
    eng.run_until_drained()
    return eng.done[0].finish_time


def test_edf_meets_strictly_more_deadlines_than_fcfs():
    """Acceptance (b): EDF meets strictly more deadlines than FCFS on an
    adversarial arrival trace."""
    ec = _ec(buckets=(16384, 131072))
    l_small = _solo_latency(ec, 16384)
    l_big = _solo_latency(ec, 131072)
    assert l_big > 3 * l_small  # the trace is only adversarial if big >> small

    met = {}
    for policy in ("fcfs", "edf"):
        eng = _continuous(ec, policy=policy)
        _adversarial_trace(eng, l_small, l_big)
        eng.run_until_drained()
        m = eng.metrics()
        assert m["completed"] == 6
        met[policy] = m["slo_met"]
    assert met["edf"] > met["fcfs"], met
    assert met["edf"] == 6


def test_sjf_orders_short_jobs_first():
    ec = _ec(buckets=(16384, 131072))
    eng = _continuous(ec, policy="sjf")
    eng.submit(Request(rid=0, arrival=0.0, seq_len=131072))
    eng.submit(Request(rid=1, arrival=0.0, seq_len=16384))
    eng.submit(Request(rid=2, arrival=0.0, seq_len=16384))
    eng.run_until_drained()
    order = [sr.rid for sr in eng.scheduler.admitted]
    assert order == [1, 2, 0]


def test_unknown_policy_raises():
    ec = _ec()
    with pytest.raises(ValueError):
        _continuous(ec, policy="wfq")


def test_fcfs_respects_arrival_order():
    ec = _ec()
    eng = _continuous(ec, policy="fcfs")
    eng.submit(Request(rid=0, arrival=1.0, seq_len=65536))
    eng.submit(Request(rid=1, arrival=0.0, seq_len=65536))
    eng.run_until_drained()
    assert [sr.rid for sr in eng.scheduler.admitted] == [1, 0]


# ------------------------------------------------------- metrics / trace

def test_slo_stamping_and_attainment():
    ec = _ec()
    eng = _continuous(ec, slo=1e9)
    _submit_burst(eng, 3, 65536)
    eng.run_until_drained()
    m = eng.metrics()
    assert m["slo_total"] == 3 and m["slo_met"] == 3
    assert m["slo_attainment"] == pytest.approx(1.0)


def test_metrics_decomposition():
    """TTFT = queue wait + pipeline execution; waits grow down the burst."""
    ec = _ec()
    eng = _continuous(ec)
    _submit_burst(eng, 4, 65536)
    eng.run_until_drained()
    recs = sorted(eng.scheduler.metrics.records, key=lambda r: r.rid)
    waits = [r.queue_wait for r in recs]
    assert waits == sorted(waits) and waits[0] == pytest.approx(0.0)
    for r in recs:
        assert r.ttft >= r.queue_wait > -1e-12


def test_trace_export_chrome_format(tmp_path):
    ec = _ec()
    eng = _continuous(ec, trace=True)
    _submit_burst(eng, 2, 65536)
    eng.run_until_drained()
    path = eng.trace.export(str(tmp_path / "t.json"))
    with open(path) as f:
        doc = json.load(f)
    ev = doc["traceEvents"]
    tasks = [e for e in ev if e["ph"] == "X"]
    assert len(tasks) == 2 * 16 * 16      # 2 requests x 16 chunks x 16 stages
    assert {e["pid"] for e in tasks} == set(range(16))
    marks = [e for e in ev if e["ph"] == "i"]
    assert {m["name"] for m in marks} == {"arrival", "admit", "finish"}


def test_poisson_arrivals_shape():
    a = poisson_arrivals(10.0, 2000, seed=1)
    assert len(a) == 2000
    assert all(b >= a_ for a_, b in zip(a, a[1:]))
    mean_gap = (a[-1] - a[0]) / (len(a) - 1)
    assert mean_gap == pytest.approx(0.1, rel=0.15)
    assert poisson_arrivals(0.0, 3) == [0.0, 0.0, 0.0]


# -------------------------------------------- engine integration details

def test_continuous_engine_reentrant_submit_drain_cycles():
    """submit -> drain -> submit -> drain must work (continuous serving)."""
    ec = _ec()
    eng = _continuous(ec)
    eng.submit(Request(rid=0, arrival=0.0, seq_len=65536))
    eng.run_until_drained()
    assert [r.rid for r in eng.done] == [0]
    eng.submit(Request(rid=1, arrival=0.0, seq_len=65536))
    eng.run_until_drained()
    assert sorted(r.rid for r in eng.done) == [0, 1]
    assert eng.queue == []
    assert eng.metrics()["completed"] == 2


def test_continuous_open_loop_idle_pipeline():
    """At a low arrival rate the pipeline idles between requests: queue waits
    stay ~0 and TTFT ~ the solo latency (no batching-induced inflation)."""
    ec = _ec()
    solo = _solo_latency(ec, 65536)
    eng = _continuous(ec)
    for i in range(4):
        eng.submit(Request(rid=i, arrival=i * 10.0 * solo, seq_len=65536))
    eng.run_until_drained()
    m = eng.metrics()
    assert m["avg_queue_wait"] == pytest.approx(0.0, abs=1e-9)
    assert m["avg_ttft"] == pytest.approx(solo, rel=1e-6)


def test_continuous_with_straggler_scale():
    """A slow stage folds into the continuous schedule via stage_scale."""
    ec = _ec()
    base = _continuous(ec)
    _submit_burst(base, 4, 65536)
    base.run_until_drained()
    slow = _continuous(ec, executor=SimExecutor(CFG, ec.hw, slow={3: 2.0}))
    _submit_burst(slow, 4, 65536)
    slow.run_until_drained()
    mk_b = base.metrics()["makespan"]
    mk_s = slow.metrics()["makespan"]
    assert mk_b < mk_s < mk_b * 2.0  # slower, but NOT scaled wholesale
