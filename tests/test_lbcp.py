"""LBCP (Alg. 1) tests: DP vs brute force on small instances, SA refinement,
and the balance/shrinking-chunk structure the paper predicts."""
import itertools
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip the
#   module cleanly instead of erroring out the whole collection
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.core import lbcp


def brute_force(sq, m, n, eval_chunk):
    best, best_obj = None, math.inf
    for cuts in itertools.combinations(range(1, sq), m - 1):
        bounds = (0,) + cuts + (sq,)
        ks = [bounds[i + 1] - bounds[i] for i in range(m)]
        ts = [eval_chunk(k, s) for k, s in zip(ks, bounds[:-1])]
        obj = sum(ts) + (n - 1) * max(ts)
        if obj < best_obj:
            best, best_obj = ks, obj
    return best, best_obj


@pytest.mark.parametrize("sq,m,n", [(12, 3, 4), (10, 4, 2), (14, 2, 8)])
def test_dp_matches_brute_force(sq, m, n):
    # quadratic-in-prefix cost, like attention
    def ec(k, s):
        return k * (s + k / 2) + 3.0 * k

    def ec_vec(ks, s):
        return np.array([ec(int(k), s) for k in ks], float)

    chunks, obj = lbcp.dp_partition(sq, m, n, ec_vec)
    want, want_obj = brute_force(sq, m, n, ec)
    assert obj == pytest.approx(want_obj, rel=1e-9)
    assert sum(chunks) == sq


@settings(max_examples=25, deadline=None)
@given(sq=st.integers(6, 16), m=st.integers(2, 4), n=st.integers(2, 8),
       a=st.floats(0.1, 5.0), b=st.floats(0.0, 3.0))
def test_dp_optimal_property(sq, m, n, a, b):
    if m > sq:
        return

    def ec(k, s):
        return a * k * (s + k / 2) + b * k

    def ec_vec(ks, s):
        return np.array([ec(int(k), s) for k in ks], float)

    chunks, obj = lbcp.dp_partition(sq, m, n, ec_vec)
    _, want_obj = brute_force(sq, m, n, ec)
    assert obj <= want_obj * (1 + 1e-9)


def test_plan_partition_structure():
    """Attention growth => strictly easier later chunks (sizes shrink)."""
    cfg = get_config("llama3-70b")
    p = lbcp.plan_partition(cfg, 65536, 8, 16, cm.WSC_PAPER, sa_iters=60)
    assert sum(p.chunks) == 65536
    assert p.chunks[0] > p.chunks[-1]
    # chunk times under the analytic model are more balanced than uniform
    sm = cm.StageModel.build(cfg, 16, 1)
    t_lbcp = [cm.chunk_compute_time(sm, c, sum(p.chunks[:i]), cm.WSC_PAPER)
              for i, c in enumerate(p.chunks)]
    u = lbcp.uniform_partition(65536, 8)
    t_uni = [cm.chunk_compute_time(sm, c, sum(u[:i]), cm.WSC_PAPER)
             for i, c in enumerate(u)]
    cv = lambda t: np.std(t) / np.mean(t)
    assert cv(t_lbcp) < cv(t_uni)


def test_linear_cost_gives_uniform():
    """Attention-free (SSM): chunk cost is linear => uniform is optimal."""
    def ec_vec(ks, s):
        return ks.astype(float) * 2.0

    chunks, _ = lbcp.dp_partition(16, 4, 8, ec_vec)
    assert chunks == [4, 4, 4, 4]


def test_sa_never_worse_than_dp_init():
    cfg = get_config("llama3-70b")
    p = lbcp.plan_partition(cfg, 32768, 8, 16, cm.WSC_PAPER, sa_iters=120,
                            seed=1)
    # re-evaluate the DP-only (uniform-free) baseline through the same model
    from repro.core.lbcp import _evaluate_full
    sm = cm.StageModel.build(cfg, 16, 1)
    _, _, e2e_best, _ = _evaluate_full(p.chunks, sm, 16, cm.WSC_PAPER,
                                       p.mbkr_plan, 8)
    assert e2e_best <= p.t_e2e * (1 + 1e-6)


def test_uniform_partition_sums():
    for s, m in [(100, 7), (4096, 16), (65536, 3)]:
        u = lbcp.uniform_partition(s, m)
        assert sum(u) == s and len(u) == m and max(u) - min(u) <= 1
